"""Step builders: train / prefill / decode, with shardings derived from the
logical rules. Used identically by the real trainer, the server, and the
dry-run (which lowers these very functions with ShapeDtypeStructs).

Every builder accepts an optional ``policy``
(:class:`~repro.core.program.PipePolicy`): the step body then runs under
the mesh-tagged session policy (``repro.policy`` context, tagged with the
ambient :class:`~repro.runtime.sharding.ShardingContext`'s topology via
:func:`repro.runtime.streams.mesh_policy`), so every stream-kernel call
site inside the model — attention, decode attention, scans — resolves its
pipe plan under that policy with topology-keyed plan caches. The serving
decode loop and the trainer thereby run the same tuned stream kernels as
the single-device paths, under the mesh.
"""

from __future__ import annotations

import contextlib
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.optim import adafactor, adamw
from repro.optim.compression import QuantizedAccumulator
from repro.runtime import sharding as shlib


def _policy_scope(policy):
    """Session-policy context for one step body (no-op without a policy).
    Entered inside the step function, so it is active at trace time
    whenever the jitted step (re)traces — the moment the model's kernel
    call sites read the session policy."""
    if policy is None:
        return contextlib.nullcontext()
    from repro.core.program import policy as policy_ctx
    from repro.runtime.streams import mesh_policy
    return policy_ctx(mesh_policy(policy))


def opt_init_and_update(optimizer: str, opt_cfg=None):
    if optimizer == "adafactor":
        cfg = opt_cfg or adafactor.AdafactorConfig()
        return (lambda p: adafactor.init(p),
                lambda g, s, p: adafactor.update(cfg, g, s, p))
    cfg = opt_cfg or adamw.AdamWConfig()
    return (lambda p: adamw.init(p),
            lambda g, s, p: adamw.update(cfg, g, s, p))


def opt_state_axes(optimizer: str, param_axes):
    """Logical axes for the optimizer state (mirrors param axes)."""
    if optimizer == "adafactor":
        def st(ax):
            if len(ax) >= 2:
                return {"vr": tuple(ax[:-1]), "vc": tuple(ax[:-2]) + (ax[-1],)}
            return {"v": tuple(ax)}
        return {"v": jax.tree.map(st, param_axes,
                                  is_leaf=lambda x: isinstance(x, tuple)),
                "step": ()}
    return {"m": param_axes, "v": param_axes, "step": ()}


def make_train_step(model, *, optimizer: str = "adamw", opt_cfg=None,
                    accum_steps: int = 1, quantized_accum: bool = False,
                    policy=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). With accum_steps > 1 the batch splits into microbatches along
    dim 0 and gradients accumulate (optionally in int8 w/ error feedback)
    before one optimizer update — collective-frugal: the DP all-reduce
    happens once per step, not per microbatch. ``policy`` installs the
    mesh-tagged session PipePolicy around the step body (see module
    docstring)."""
    _, opt_update = opt_init_and_update(optimizer, opt_cfg)
    grad_fn = jax.value_and_grad(model.loss, has_aux=True)

    def train_step(params, opt_state, batch):
        with _policy_scope(policy):
            return _train_step(params, opt_state, batch)

    def _train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]), batch)

            if quantized_accum:
                acc0 = QuantizedAccumulator.init(params)

                def body(acc, mb):
                    (l, m), g = grad_fn(params, mb)
                    return QuantizedAccumulator.add(acc, g), (l, m)

                acc, (losses, metricses) = jax.lax.scan(body, acc0, micro)
                grads = jax.tree.map(lambda g: g / accum_steps,
                                     QuantizedAccumulator.read(acc))
            else:
                acc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def body(acc, mb):
                    (l, m), g = grad_fn(params, mb)
                    return jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32), acc, g), \
                        (l, m)

                acc, (losses, metricses) = jax.lax.scan(body, acc0, micro)
                grads = jax.tree.map(lambda g: g / accum_steps, acc)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricses)
        new_params, new_opt, opt_metrics = opt_update(grads, opt_state, params)
        return new_params, new_opt, {**metrics, **opt_metrics}

    return train_step


def make_prefill_step(model, *, policy=None):
    def prefill_step(params, batch):
        with _policy_scope(policy):
            return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model, *, policy=None):
    def decode_step(params, batch, cache):
        with _policy_scope(policy):
            logits, new_cache = model.decode_step(params, batch, cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache
    return decode_step


# ---------------------------------------------------------------------------
# Sharding assembly for the jit entry points
# ---------------------------------------------------------------------------


def shardings_for_cell(model, shape: ShapeConfig, ctx, *,
                       optimizer: str = "adamw"):
    """(in_shardings pytrees per entry point) for the given mesh context."""
    tupleish = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    sh = lambda axes_tree: jax.tree.map(
        lambda ax: shlib.sharding_for(ax, ctx), axes_tree, is_leaf=tupleish)

    p_sh = sh(model.param_axes())
    batch_sh = sh(model.input_axes(shape))
    out = {"params": p_sh, "batch": batch_sh}
    if shape.kind == "train":
        out["opt"] = sh(opt_state_axes(optimizer, model.param_axes()))
    if shape.kind == "decode":
        _, cache_axes = model.cache_spec(shape)
        out["cache"] = sh(cache_axes)
    return out
