"""Pipe: the on-chip FIFO connecting a memory (producer) stage to a compute
(consumer) stage.

This is the TPU realization of the paper's OpenCL pipe / Intel channel:

* FPGA: a BRAM FIFO of configurable depth, one scalar word per read/write.
* TPU (here): a VMEM ring buffer of ``depth`` slots, each slot holding one
  *tile* (the TPU "word" is a VREG-aligned block, not a scalar), with one DMA
  semaphore per (slot, stream).

``streams`` models the paper's multiple-producers/multiple-consumers (M2C2):
each tile is split into ``streams`` disjoint sub-copies issued as concurrent
DMAs, exactly like the paper's static index-parity load balancing.

The pipe's "resource utilization" analogue (paper: BRAM / logic) is VMEM
bytes, exposed as :meth:`Pipe.vmem_bytes` and budget-checked by the planner.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np

# TPU tiling granularity for f32: (8 sublanes, 128 lanes). Smaller dtypes pack
# more sublanes; we keep the conservative f32 granule for validation.
_SUBLANE = 8
_LANE = 128


@dataclasses.dataclass(frozen=True)
class Pipe:
    """Configuration of one producer→consumer pipe.

    Attributes:
      tile: block shape carried per pipe word (last two dims TPU-aligned).
      dtype: element dtype carried by the pipe.
      depth: ring-buffer slots (paper: channel depth). depth=1 degenerates to
        the synchronous copy-then-compute baseline (no lookahead); depth>=2
        enables the feed-forward overlap (double/multi-buffering).
      streams: concurrent producer DMAs per word (paper: #producers). The
        tile's leading dim is split ``streams`` ways.
    """

    tile: Tuple[int, ...]
    dtype: jnp.dtype = jnp.float32
    depth: int = 2
    streams: int = 1

    def __post_init__(self):
        if self.depth < 1:
            raise ValueError(f"pipe depth must be >= 1, got {self.depth}")
        if self.streams < 1:
            raise ValueError(f"pipe streams must be >= 1, got {self.streams}")
        if len(self.tile) < 2:
            raise ValueError(f"pipe tile must be >= 2-D for TPU, got {self.tile}")
        if self.tile[0] % self.streams != 0:
            raise ValueError(
                f"tile leading dim {self.tile[0]} not divisible by streams={self.streams}"
            )
        # Full 128-lane tiles are the efficient case; narrower pipes are legal
        # (VMEM pads lanes physically) but must stay 8-aligned so the DMA
        # stays a whole-sublane copy. The planner prefers >=128-lane words.
        if self.tile[-1] % _SUBLANE != 0:
            raise ValueError(f"tile lane dim {self.tile[-1]} must be a multiple of {_SUBLANE}")
        if self.tile[-2] % _SUBLANE != 0:
            raise ValueError(f"tile sublane dim {self.tile[-2]} must be a multiple of {_SUBLANE}")

    # -- resource accounting (the BRAM analogue) ---------------------------

    @property
    def word_bytes(self) -> int:
        return int(np.prod(self.tile)) * jnp.dtype(self.dtype).itemsize

    @property
    def vmem_bytes(self) -> int:
        """VMEM consumed by the ring buffer (depth slots of one word)."""
        return self.depth * self.word_bytes

    # -- derived shapes ----------------------------------------------------

    @property
    def buffer_shape(self) -> Tuple[int, ...]:
        """Scratch shape for the ring buffer: [depth, *tile]."""
        return (self.depth, *self.tile)

    @property
    def stream_tile(self) -> Tuple[int, ...]:
        """Per-stream sub-copy shape (tile split on the leading dim)."""
        return (self.tile[0] // self.streams, *self.tile[1:])

    def with_depth(self, depth: int) -> "Pipe":
        return dataclasses.replace(self, depth=depth)

    def with_streams(self, streams: int) -> "Pipe":
        return dataclasses.replace(self, streams=streams)


# Single source of the planning VMEM budget (v5e has ~128 MiB; keep slack
# for Mosaic's own buffers). The planner, the autotuner, and the graph
# compiler's split-budget logic all key off this one constant.
DEFAULT_VMEM_BUDGET_BYTES = 96 * 1024 * 1024


def vmem_budget_ok(pipes,
                   budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES) -> bool:
    """Check a set of pipes against a VMEM budget (v5e ~128MiB, keep slack)."""
    return sum(p.vmem_bytes for p in pipes) <= budget_bytes


def required_depth(dma_latency_s: float, word_service_time_s: float, cap: int = 8) -> int:
    """Min ring depth that hides DMA issue latency behind word service time.

    Paper finding ("channel depth does not significantly affect performance")
    holds when service time >= latency, i.e. required depth saturates at 2.
    """
    if word_service_time_s <= 0:
        return cap
    need = 1 + math.ceil(dma_latency_s / word_service_time_s)
    return max(2, min(cap, need))
