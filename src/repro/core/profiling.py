"""Call-site traffic recording: the hook side of the plan service.

The fleet-scale plan pipeline (:mod:`repro.plans`) needs to know what the
*real* traffic looks like — which ops resolve plans, at which shapes, under
which policies and mesh topologies — rather than tuning against fixed
benchmark shapes. This module is the core-side half of that contract: a
process-global recorder callback that :func:`repro.core.autotune.resolve_call`
and :func:`repro.core.planner.resolve_policy` invoke with one
:class:`CallSite` per resolution.

Core stays dependency-free: nothing here imports :mod:`repro.plans` (the
profile/plandb layer installs itself via :func:`set_recorder`), and with no
recorder installed every hook is a cheap no-op, so serving/training paths
pay nothing unless ``--record-profile`` is active.

Double-count suppression: ``resolve_call`` internally funnels into
``planner.resolve_policy`` (for the analytic reference and fallbacks), so a
single kernel call would otherwise record twice. ``resolve_call`` emits its
richer autotune-origin record first and wraps the rest of the resolution in
:func:`suppress_planner`; planner-origin records are only emitted for call
sites that reach the planner *directly* (legacy callers, graph planning).
The suppression flag is thread-local, so concurrent tuning threads cannot
mask each other's records.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import warnings
from typing import Any, Callable, Mapping, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class CallSite:
    """One recorded plan resolution.

    ``workload`` is the exact :class:`~repro.core.pipeline_model.Workload`
    the call site planned for; ``site`` is the kernel-specific shape
    kwargs (mirroring the kernel's workload builder signature) that the
    offline sweep uses to synthesize concrete operands, with
    ``site_dynamic`` naming the keys that vary with traffic (and are
    therefore shape-bucketed by :class:`repro.plans.TrafficProfile`).
    ``policy`` is a plain-dict summary (mode/depth/streams/stream_options/
    interpret) — enough to rebuild an equivalent search policy offline.
    """

    origin: str                       # "autotune" | "planner"
    op: str
    workload: Any
    tile: Tuple[int, ...]
    dtype: str
    hw: str
    mesh_axes: Tuple[Tuple[str, int], ...]
    policy: Mapping[str, Any]
    extra_key: str = ""
    site: Optional[Mapping[str, Any]] = None
    site_dynamic: Tuple[str, ...] = ()


_recorder: Optional[Callable[[CallSite], None]] = None


class _TLS(threading.local):
    def __init__(self):
        self.suppress = 0


_tls = _TLS()


def set_recorder(fn: Optional[Callable[[CallSite], None]]):
    """Install (or clear, with None) the process-global recorder; returns
    the previous recorder so scopes can nest and restore."""
    global _recorder
    prev = _recorder
    _recorder = fn
    return prev


def recording() -> bool:
    """True when a recorder is installed (hooks short-circuit otherwise)."""
    return _recorder is not None


@contextlib.contextmanager
def suppress_planner():
    """Scope in which planner-origin emits are dropped (resolve_call has
    already recorded the richer autotune-origin CallSite)."""
    _tls.suppress += 1
    try:
        yield
    finally:
        _tls.suppress -= 1


def policy_summary(policy) -> dict:
    """The rebuildable subset of a PipePolicy (duck-typed)."""
    return {
        "mode": policy.mode,
        "depth": policy.depth,
        "streams": policy.streams,
        "stream_options": tuple(int(s) for s in policy.stream_options),
        "interpret": bool(policy.interpret),
    }


def _emit(cs: CallSite) -> None:
    rec = _recorder
    if rec is None:
        return
    try:
        rec(cs)
    except Exception as e:   # noqa: BLE001 — recording must never break serving
        set_recorder(None)
        warnings.warn(
            f"traffic recorder raised ({type(e).__name__}: {e}); recording "
            f"disabled for the rest of the process", RuntimeWarning,
            stacklevel=2)


def emit_call(*, op, policy, workload, tile, dtype, mesh, extra_key="",
              site=None, site_dynamic=()) -> None:
    """Autotune-origin record (one per ``resolve_call``)."""
    if _recorder is None:
        return
    _emit(CallSite(
        origin="autotune", op=op, workload=workload, tile=tuple(tile),
        dtype=str(dtype), hw=policy.hw.name, mesh_axes=tuple(mesh.axes),
        policy=policy_summary(policy), extra_key=extra_key,
        site=dict(site) if site else None,
        site_dynamic=tuple(site_dynamic)))


def emit_planner(*, op, policy, workload, tile, dtype, mesh) -> None:
    """Planner-origin record — dropped inside :func:`suppress_planner`
    (the owning ``resolve_call`` already recorded the call site)."""
    if _recorder is None or _tls.suppress:
        return
    _emit(CallSite(
        origin="planner", op=op, workload=workload, tile=tuple(tile),
        dtype=str(dtype), hw=policy.hw.name, mesh_axes=tuple(mesh.axes),
        policy=policy_summary(policy)))
