"""Model registry: one Model class per family, a single interface for the
trainer, server, dry-run, and tests.

Entry points per shape kind:
  train   -> loss(params, batch)                 batch: tokens/labels (+frontend)
  prefill -> prefill(params, batch)              -> (last-token logits, cache)
  decode  -> decode_step(params, batch, cache)   -> (logits, new cache)

``input_specs(shape)`` returns ShapeDtypeStruct stand-ins for every input of
the entry point (weak-type-correct, shardable, no allocation) — the dry-run
contract. ``cache_spec(shape)`` ditto for KV/state caches.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig, get_config
from repro.models import encdec, hybrid, layers as L, mla, moe, rwkv6, transformer
from repro.runtime.sharding import constrain


def _token_specs(batch: int, seq: int) -> Dict[str, Any]:
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }


_TOKEN_AXES = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}


class BaseLM:
    """Decoder-only LM; mixer/ffn hooks cover dense, MoE, and MLA."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.stack = transformer.DecoderStack(
            cfg,
            mixer_specs=self._mixer_specs(),
            mixer_apply=self._mixer_apply(),
            mixer_cache_spec=self._mixer_cache_spec(),
            ffn_specs=self._ffn_specs(),
            ffn_apply=self._ffn_apply(),
        )

    # hooks ------------------------------------------------------------------
    def _mixer_specs(self):
        return transformer.attn_specs

    def _mixer_apply(self):
        return transformer.attn_apply

    def _mixer_cache_spec(self):
        return transformer.attn_cache_spec

    def _ffn_specs(self):
        return transformer.ffn_specs

    def _ffn_apply(self):
        return transformer.ffn_apply

    # params -------------------------------------------------------------------
    def param_specs(self):
        cfg = self.cfg
        s = {
            "embed": L.embed_specs(cfg.padded_vocab, cfg.d_model),
            "stack": self.stack.specs(),
            "final_norm": L.norm_specs(cfg.norm, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            s["unembed"] = L.ParamSpec((cfg.padded_vocab, cfg.d_model),
                                       ("vocab", "embed"))
        return s

    def init(self, key):
        return L.init_params(self.param_specs(), key)

    def abstract_params(self):
        return L.abstract_params(self.param_specs())

    def param_axes(self):
        return L.param_axes(self.param_specs())

    def param_count(self) -> int:
        return L.param_count(self.param_specs())

    def active_param_count(self) -> int:
        cfg = self.cfg
        n = self.param_count()
        if cfg.n_experts and cfg.top_k:
            per_expert = cfg.d_model * 3 * cfg.moe_d_ff
            inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * per_expert
            n -= inactive
        return n

    # forward ------------------------------------------------------------------
    def _extra_embeds(self, params, batch) -> Optional[jnp.ndarray]:
        return None

    def _trunk(self, params, batch, *, want_cache: bool):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"], cfg.cdtype)
        extra = self._extra_embeds(params, batch)
        n_extra = 0
        if extra is not None:
            x = jnp.concatenate([extra.astype(cfg.cdtype), x], axis=1)
            n_extra = extra.shape[1]
        positions = jnp.arange(x.shape[1])
        x, caches, aux = self.stack(params["stack"], x, positions=positions,
                                    want_cache=want_cache)
        x = L.norm_apply(cfg.norm, x, params["final_norm"])
        return x, caches, aux, n_extra

    def _unembed(self, params):
        return params["embed"] if self.cfg.tie_embeddings else params["unembed"]

    def loss(self, params, batch):
        cfg = self.cfg
        x, _, aux, n_extra = self._trunk(params, batch, want_cache=False)
        if n_extra:
            x = x[:, n_extra:]
        if cfg.loss_chunk > 1:
            loss = L.chunked_unembed_loss(x, self._unembed(params),
                                          batch["labels"], cfg.loss_chunk)
        else:
            logits = L.unembed_logits(x, self._unembed(params))
            loss = L.cross_entropy(logits, batch["labels"])
        loss = loss + 0.01 * aux
        return loss, {"loss": loss, "aux": aux}

    def prefill(self, params, batch):
        x, caches, _, _ = self._trunk(params, batch, want_cache=True)
        logits = L.unembed_logits(x[:, -1:], self._unembed(params))[:, 0]
        return logits, caches

    def decode_step(self, params, batch, caches):
        cfg = self.cfg
        tok = batch["token"]
        lengths = batch["lengths"].astype(jnp.int32)
        x = L.embed_lookup(params["embed"], tok[:, None], cfg.cdtype)
        positions = lengths[:, None]
        x, new_caches, _ = self.stack(params["stack"], x, positions=positions,
                                      caches=caches, lengths=lengths)
        x = L.norm_apply(cfg.norm, x, params["final_norm"])
        logits = L.unembed_logits(x, self._unembed(params))[:, 0]
        return logits, new_caches

    # specs ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return _token_specs(b, s)
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        return {"token": jax.ShapeDtypeStruct((b,), jnp.int32),
                "lengths": jax.ShapeDtypeStruct((b,), jnp.int32)}

    def input_axes(self, shape: ShapeConfig):
        if shape.kind == "train":
            return dict(_TOKEN_AXES)
        if shape.kind == "prefill":
            return {"tokens": ("batch", "seq")}
        return {"token": ("batch",), "lengths": ("batch",)}

    def cache_spec(self, shape: ShapeConfig):
        return self.stack.cache_spec(shape.global_batch, shape.seq_len)


class DenseLM(BaseLM):
    pass


class MoELM(BaseLM):
    def _ffn_specs(self):
        return moe.moe_ffn_specs

    def _ffn_apply(self):
        return moe.moe_ffn_apply


class MLAMoELM(MoELM):
    """deepseek-v2: MLA mixer + MoE FFN."""

    def _mixer_specs(self):
        return mla.mla_specs

    def _mixer_apply(self):
        return mla.mla_apply

    def _mixer_cache_spec(self):
        return mla.mla_cache_spec


class VLM(DenseLM):
    """internvl2: stubbed ViT patch embeddings prepended to the LM."""

    def param_specs(self):
        s = super().param_specs()
        d = self.cfg.d_model
        s["vision_proj"] = L.ParamSpec((d, d), ("embed", None))
        return s

    def _extra_embeds(self, params, batch):
        if "image_embeds" not in batch:
            return None
        x = batch["image_embeds"].astype(self.cfg.cdtype)
        return x @ params["vision_proj"].astype(x.dtype)

    def input_specs(self, shape: ShapeConfig):
        s = super().input_specs(shape)
        cfg = self.cfg
        if shape.kind in ("train", "prefill"):
            s["image_embeds"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.n_patches, cfg.d_model), cfg.cdtype)
        return s

    def input_axes(self, shape: ShapeConfig):
        a = super().input_axes(shape)
        if shape.kind in ("train", "prefill"):
            a["image_embeds"] = ("batch", "patches", "embed")
        return a

    def cache_spec(self, shape: ShapeConfig):
        # cache covers patches + tokens
        return self.stack.cache_spec(shape.global_batch,
                                     shape.seq_len + self.cfg.n_patches)


class ZambaLM(BaseLM):
    """zamba2 hybrid (Mamba2 + shared attention)."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": L.embed_specs(cfg.padded_vocab, cfg.d_model),
            "stack": hybrid.specs(cfg),
            "final_norm": L.norm_specs(cfg.norm, cfg.d_model),
            "unembed": L.ParamSpec((cfg.padded_vocab, cfg.d_model),
                                   ("vocab", "embed")),
        }

    def _trunk(self, params, batch, *, want_cache, caches=None, lengths=None):
        cfg = self.cfg
        if "token" in batch:
            x = L.embed_lookup(params["embed"], batch["token"][:, None],
                               cfg.cdtype)
            positions = lengths[:, None]
        else:
            x = L.embed_lookup(params["embed"], batch["tokens"], cfg.cdtype)
            positions = jnp.arange(x.shape[1])
        x, new_caches, aux = hybrid.forward(
            cfg, params["stack"], x, positions=positions, caches=caches,
            lengths=lengths, want_cache=want_cache)
        x = L.norm_apply(cfg.norm, x, params["final_norm"])
        return x, new_caches, aux

    def loss(self, params, batch):
        x, _, aux = self._trunk(params, batch, want_cache=False)
        if self.cfg.loss_chunk > 1:
            loss = L.chunked_unembed_loss(x, params["unembed"],
                                          batch["labels"],
                                          self.cfg.loss_chunk)
        else:
            logits = L.unembed_logits(x, params["unembed"])
            loss = L.cross_entropy(logits, batch["labels"])
        return loss, {"loss": loss, "aux": aux}

    def prefill(self, params, batch):
        x, caches, _ = self._trunk(params, batch, want_cache=True)
        logits = L.unembed_logits(x[:, -1:], params["unembed"])[:, 0]
        return logits, caches

    def decode_step(self, params, batch, caches):
        lengths = batch["lengths"].astype(jnp.int32)
        x, new_caches, _ = self._trunk(params, batch, want_cache=True,
                                       caches=caches, lengths=lengths)
        logits = L.unembed_logits(x, params["unembed"])[:, 0]
        return logits, new_caches

    def cache_spec(self, shape: ShapeConfig):
        return hybrid.cache_spec(self.cfg, shape.global_batch, shape.seq_len)


class RWKVLM(BaseLM):
    """rwkv6: token-shift time/channel mixing, attention-free."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def param_specs(self):
        cfg = self.cfg
        one = {
            "ln1": L.norm_specs("layernorm", cfg.d_model),
            "tm": rwkv6.time_mix_specs(cfg),
            "ln2": L.norm_specs("layernorm", cfg.d_model),
            "cm": rwkv6.channel_mix_specs(cfg),
        }
        stacked = jax.tree.map(
            lambda s: L.ParamSpec((cfg.n_layers, *s.shape),
                                  ("layers", *s.axes), s.dtype, s.init,
                                  s.scale),
            one, is_leaf=L.is_spec)
        return {
            "embed": L.embed_specs(cfg.padded_vocab, cfg.d_model),
            "ln0": L.norm_specs("layernorm", cfg.d_model),
            "layers": stacked,
            "final_norm": L.norm_specs("layernorm", cfg.d_model),
            "unembed": L.ParamSpec((cfg.padded_vocab, cfg.d_model),
                                   ("vocab", "embed")),
        }

    def _layer(self, p, x, cache):
        cfg = self.cfg
        h = L.norm_apply("layernorm", x, p["ln1"])
        tm_out, tm_cache = rwkv6.time_mix_apply(cfg, p["tm"], h, cache=cache)
        x = x + tm_out
        h = L.norm_apply("layernorm", x, p["ln2"])
        cm_out, cm_cache = rwkv6.channel_mix_apply(cfg, p["cm"], h,
                                                   cache=cache)
        x = x + cm_out
        x = constrain(x, ("batch", "seq_sp", "embed"))
        return x, {**tm_cache, **cm_cache}

    def _trunk(self, params, x, caches, want_cache):
        cfg = self.cfg
        layer = self._layer
        if cfg.remat != "none":
            layer = jax.checkpoint(
                layer, policy=jax.checkpoint_policies.nothing_saveable)
        if not cfg.scan_layers:
            outs = []
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                c = (jax.tree.map(lambda a: a[i], caches)
                     if caches is not None else None)
                x, nc = layer(p, x, c)
                outs.append(nc if (want_cache or caches is not None) else None)
            new_caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
                          if outs[0] is not None else None)
        elif caches is None:
            def body(xx, p):
                xx, nc = layer(p, xx, None)
                return xx, (nc if want_cache else None)
            x, new_caches = jax.lax.scan(body, x, params["layers"])
        else:
            def body(xx, xs):
                p, c = xs
                xx, nc = layer(p, xx, c)
                return xx, nc
            x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
        return L.norm_apply("layernorm", x, params["final_norm"]), new_caches

    def loss(self, params, batch):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"], cfg.cdtype)
        x = L.norm_apply("layernorm", x, params["ln0"])
        x, _ = self._trunk(params, x, None, want_cache=False)
        if cfg.loss_chunk > 1:
            loss = L.chunked_unembed_loss(x, params["unembed"],
                                          batch["labels"], cfg.loss_chunk)
        else:
            logits = L.unembed_logits(x, params["unembed"])
            loss = L.cross_entropy(logits, batch["labels"])
        return loss, {"loss": loss}

    def prefill(self, params, batch):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"], cfg.cdtype)
        x = L.norm_apply("layernorm", x, params["ln0"])
        x, caches = self._trunk(params, x, None, want_cache=True)
        logits = L.unembed_logits(x[:, -1:], params["unembed"])[:, 0]
        return logits, caches

    def decode_step(self, params, batch, caches):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["token"][:, None],
                           cfg.cdtype)
        x = L.norm_apply("layernorm", x, params["ln0"])
        x, new_caches = self._trunk(params, x, caches, want_cache=True)
        logits = L.unembed_logits(x, params["unembed"])[:, 0]
        return logits, new_caches

    def cache_spec(self, shape: ShapeConfig):
        cfg = self.cfg
        one, one_axes = rwkv6.rwkv_cache_spec(cfg, shape.global_batch)
        spec = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_layers, *s.shape), s.dtype),
            one)
        axes = jax.tree.map(lambda a: ("layers", *a), one_axes,
                            is_leaf=lambda x: isinstance(x, tuple))
        return spec, axes


class EncDecLM(BaseLM):
    """whisper-tiny: stubbed conv frontend + enc-dec transformer."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": L.embed_specs(cfg.padded_vocab, cfg.d_model),
            "encdec": encdec.specs(cfg),
            "unembed": L.ParamSpec((cfg.padded_vocab, cfg.d_model),
                                   ("vocab", "embed")),
        }

    def loss(self, params, batch):
        cfg = self.cfg
        enc_out = encdec.encode(cfg, params["encdec"], batch["frames"])
        x = L.embed_lookup(params["embed"], batch["tokens"], cfg.cdtype)
        pos = params["encdec"]["dec_pos"][:x.shape[1]].astype(x.dtype)
        x = x + pos[None]
        positions = jnp.arange(x.shape[1])
        x, _ = encdec.decode_stack(cfg, params["encdec"], x, enc_out,
                                   positions=positions)
        logits = L.unembed_logits(x, params["unembed"])
        loss = L.cross_entropy(logits, batch["labels"])
        return loss, {"loss": loss}

    def prefill(self, params, batch):
        cfg = self.cfg
        enc_out = encdec.encode(cfg, params["encdec"], batch["frames"])
        x = L.embed_lookup(params["embed"], batch["tokens"], cfg.cdtype)
        x = x + params["encdec"]["dec_pos"][:x.shape[1]].astype(x.dtype)[None]
        positions = jnp.arange(x.shape[1])
        x, caches = encdec.decode_stack(cfg, params["encdec"], x, enc_out,
                                        positions=positions, want_cache=True)
        logits = L.unembed_logits(x[:, -1:], params["unembed"])[:, 0]
        return logits, caches

    def decode_step(self, params, batch, caches):
        cfg = self.cfg
        lengths = batch["lengths"].astype(jnp.int32)
        x = L.embed_lookup(params["embed"], batch["token"][:, None],
                           cfg.cdtype)
        pos = jnp.take(params["encdec"]["dec_pos"], lengths, axis=0)
        x = x + pos[:, None, :].astype(x.dtype)
        x, new_caches = encdec.decode_stack(
            cfg, params["encdec"], x, None, positions=lengths[:, None],
            caches=caches, lengths=lengths)
        logits = L.unembed_logits(x, params["unembed"])[:, 0]
        return logits, new_caches

    def input_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        frames = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model),
                                      cfg.cdtype)
        if shape.kind == "train":
            return {**_token_specs(b, s), "frames": frames}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                    "frames": frames}
        return {"token": jax.ShapeDtypeStruct((b,), jnp.int32),
                "lengths": jax.ShapeDtypeStruct((b,), jnp.int32)}

    def input_axes(self, shape: ShapeConfig):
        a = super().input_axes(shape)
        if shape.kind in ("train", "prefill"):
            a["frames"] = ("batch", "frames", "embed")
        return a

    def cache_spec(self, shape: ShapeConfig):
        return encdec.cache_spec(self.cfg, shape.global_batch, shape.seq_len)


_FAMILIES = {
    "dense": DenseLM,
    "moe": MoELM,
    "moe_mla": MLAMoELM,
    "hybrid": ZambaLM,
    "ssm": RWKVLM,
    "encdec": EncDecLM,
    "vlm": VLM,
}


def build_model(cfg: ArchConfig):
    family = cfg.family
    if family == "moe" and cfg.kv_lora_rank:
        family = "moe_mla"
    return _FAMILIES[family](cfg)


def build_model_by_id(arch_id: str):
    return build_model(get_config(arch_id))
