"""Ring-pipe emitter: the shared runtime every ff_* kernel emits through.

The paper splits each kernel into a *memory kernel* (address generation +
loads) and a *compute kernel*, connected by an on-chip pipe. On TPU the pipe
is a VMEM ring buffer fed by async DMAs, and historically each Pallas kernel
hand-rolled the same idiom: slot rotation, a depth-word warmup prologue,
paired ``start``/``wait`` calls, and a refill after consumption. MKPipe
(arXiv 2002.01614) argues this duplication belongs in a compiler/runtime
layer; this module is that layer for the repo.

A :class:`RingPipe` is constructed at trace time from a :class:`core.Pipe`
spec. It *owns* the scratch shapes its ring needs (VMEM buffer + DMA
semaphore array), is bound to the concrete scratch refs inside the kernel,
and then exposes the four emission primitives:

  start(word)        producer: issue the async copies for ``word``
  wait(word)         consumer: block until ``word`` has landed
  slot(word)         VMEM ref of the landed word (the pipe read endpoint)
  prologue(g, n)     warmup: at grid step 0, fill the ring ``depth`` deep
                     (``depth == 1`` degenerates to the synchronous
                     copy-then-compute baseline: start word ``g`` now)

plus the release primitive ``refill(g, n)`` (word ``g`` consumed; start
``g + depth``) and the whole-schedule conveniences ``acquire``/``release``
that iterate a set of pipes. Two access patterns are covered:

* :class:`RingPipe` — regular block copies. ``streams > 1`` splits each
  word into disjoint row ranges issued as concurrent DMAs (the paper's
  multi-producer M2C2 design, static load balancing).
* :class:`GatherRingPipe` — irregular per-row gathers. Each word is a
  bundle of ``tile[0]`` single-row DMAs whose source rows come from a
  dynamically-indexed slicer (scalar-prefetched indices); the row bundle is
  the stream decomposition, giving ``depth x rows`` outstanding requests of
  memory-level parallelism (the burst-coalesced-LSU analogue).

The source slicer can depend only on the word index (and scalar-prefetch
values), never on consumer state — the feed-forward restriction, enforced
structurally.

Kernel skeleton::

    ring = RingPipe(pipe_spec)                      # trace time
    pl.pallas_call(kernel, ...,
                   scratch_shapes=[..., *ring.scratch_shapes])

    def kernel(..., buf, sems):                     # inside the kernel
        p = ring.bind(buf, sems, lambda word: hbm.at[...])
        acquire(g, n_words, [p])
        compute(p.slot(g)[...])
        release(g, n_words, [p])
"""

from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pipe import Pipe


class RingPipe:
    """Emitter for one regular (block-copy) ring pipe.

    Constructed from the :class:`Pipe` spec at trace time; bound to its
    scratch refs and HBM slicer inside the kernel via :meth:`bind`.
    """

    def __init__(self, spec: Pipe):
        self.spec = spec
        self._buf = None
        self._sems = None
        self._slicer: Callable | None = None

    # -- scratch ownership (trace time) ------------------------------------

    @property
    def n_dmas(self) -> int:
        """Concurrent DMAs per word (one semaphore each)."""
        return self.spec.streams

    @property
    def scratch_shapes(self) -> Tuple:
        """The scratch this pipe owns: (VMEM ring buffer, DMA semaphores)."""
        return (
            pltpu.VMEM(self.spec.buffer_shape, self.spec.dtype),
            pltpu.SemaphoreType.DMA((self.spec.depth, self.n_dmas)),
        )

    # -- binding (in kernel) ------------------------------------------------

    def bind(self, buf, sems, src_slicer: Callable) -> "RingPipe":
        """Attach the scratch refs and the memory kernel's address stream.

        ``src_slicer(word) -> hbm-ref-slice`` names the HBM region of pipe
        word ``word`` and may depend only on the word index.
        """
        self._buf = buf
        self._sems = sems
        self._slicer = src_slicer
        return self

    # -- emission primitives -------------------------------------------------

    def _copies(self, word):
        """The async-copy descriptors of one word (one per stream)."""
        slot = word % self.spec.depth
        src = self._slicer(word)
        rows = self.spec.tile[0] // self.spec.streams
        for s in range(self.spec.streams):
            lo = s * rows
            yield pltpu.make_async_copy(
                src.at[pl.ds(lo, rows)],
                self._buf.at[slot, pl.ds(lo, rows)],
                self._sems.at[slot, s],
            )

    def start(self, word) -> None:
        """Producer: issue the (possibly multi-stream) copy for ``word``."""
        for c in self._copies(word):
            c.start()

    def wait(self, word) -> None:
        """Consumer: block until ``word`` landed (paper: blocking read)."""
        for c in self._copies(word):
            c.wait()

    def slot(self, word):
        """VMEM ref of the landed word (the pipe read endpoint)."""
        return self._buf.at[word % self.spec.depth]

    def prologue(self, g, n_words: int) -> None:
        """Warmup fill at grid step ``g`` of ``n_words``.

        depth == 1: start word ``g`` (synchronous baseline, no lookahead).
        depth >= 2: at g == 0, start the first ``depth`` words (the pipe's
        full lookahead); later steps issue nothing here (refill happens in
        :meth:`refill`).
        """
        if self.spec.depth == 1:
            self.start(g)
            return

        @pl.when(g == 0)
        def _():
            for d in range(self.spec.depth):
                @pl.when(d < n_words)
                def _(d=d):
                    self.start(d)

    def refill(self, g, n_words: int) -> None:
        """Word ``g`` consumed; refill its slot with word ``g + depth``.

        Must run *after* the compute that reads ``slot(g)`` — refilling
        earlier would let the DMA clobber the word being consumed.
        """
        if self.spec.depth == 1:
            return

        @pl.when(g + self.spec.depth < n_words)
        def _():
            self.start(g + self.spec.depth)


class GatherRingPipe(RingPipe):
    """Emitter for one irregular (per-row gather) ring pipe.

    Each pipe word is a bundle of ``tile[0]`` rows fetched from dynamically
    indexed locations; ``bind`` takes a *row* slicer
    ``row_slicer(word, r) -> hbm-ref-slice`` (one source row), typically
    indexed through a scalar-prefetched index vector. Rows are the stream
    decomposition (spec.streams is ignored for DMA splitting): a word issues
    ``rows`` concurrent single-row DMAs, so the ring sustains
    ``(depth-1) * rows`` outstanding irregular requests.
    """

    @property
    def rows(self) -> int:
        return self.spec.tile[0]

    @property
    def n_dmas(self) -> int:
        return self.rows

    def bind(self, buf, sems,
             row_slicer: Callable) -> "GatherRingPipe":
        return super().bind(buf, sems, row_slicer)

    def _copies(self, word):
        slot = word % self.spec.depth
        for r in range(self.rows):
            yield pltpu.make_async_copy(
                self._slicer(word, r),
                self._buf.at[slot, pl.ds(r, 1)],
                self._sems.at[slot, r],
            )


# -- whole-schedule helpers (the DAE word schedule) --------------------------


def acquire(g, n_words: int, pipes: Sequence[RingPipe]) -> None:
    """Acquire phase at grid step ``g``: prologue fills, then block on word
    ``g`` of every pipe. All starts issue before any wait, so multi-pipe
    warmups overlap. Pipes may have different depths."""
    for p in pipes:
        p.prologue(g, n_words)
    for p in pipes:
        p.wait(g)


def release(g, n_words: int, pipes: Sequence[RingPipe]) -> None:
    """Release phase: word ``g`` consumed on every pipe; refill the slots."""
    for p in pipes:
        p.refill(g, n_words)


# -- tiling utilities ---------------------------------------------------------


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def pad_to(x: jnp.ndarray, multiple: int, axis: int) -> jnp.ndarray:
    """Zero-pad ``axis`` of x up to a multiple (TPU tile alignment)."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)
