from repro.kernels.ff_matmul.ops import KernelCost, matmul, matmul_cost
from repro.kernels.ff_matmul.ref import matmul_ref

__all__ = ["KernelCost", "matmul", "matmul_cost", "matmul_ref"]
