"""Chaos harness: injected faults against real training subprocesses.

The four scenarios in ``repro.runtime.chaos`` each orchestrate worker
processes built on the live stream/plan stack:

* SIGKILL mid-run -> cold-cache restart resumes bitwise-identically with
  the tuned-plan chain pre-warmed from the checkpoint (zero re-measures);
* SIGTERM on a ``ckpt_every`` boundary -> drain, exactly one save, clean
  exit, bitwise-identical completion;
* pod eviction -> ``replace_host`` restores shard-exact state, drops
  stale-mesh plans, serves the new topology from the PlanDB;
* injected straggler -> MAD detection -> rebalance -> local pipes
  re-planned through ``shard_streams`` at the shrunk shard shape.

Plus ``survivable_mesh`` edge cases (satellite coverage): non-divisible
survivor counts raise, ``pod_axis > 1`` shapes, and scale-*up* 1 -> 2
pods restores shard-exact state.
"""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.runtime import chaos
from repro.runtime.elastic import survivable_mesh

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(body: str, n_dev: int = 8, timeout: int = 560) -> str:
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    env = {**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# survivable_mesh edge cases (fast: the raise paths never build a Mesh)
# ---------------------------------------------------------------------------


def test_survivable_mesh_non_divisible_model_axis_raises():
    devs = list(jax.devices()) * 7          # n=7 survivors
    with pytest.raises(ValueError, match="cannot host model_axis=2"):
        survivable_mesh(devs, model_axis=2)


def test_survivable_mesh_non_divisible_pod_groups_raise():
    devs = list(jax.devices()) * 8          # n=8: model ok, pods ragged
    with pytest.raises(ValueError, match="pod_axis=3"):
        survivable_mesh(devs, model_axis=2, pod_axis=3)


def test_survivable_mesh_pod_axis_shapes():
    out = run_sub("""
        from repro.runtime.elastic import survivable_mesh
        m = survivable_mesh(jax.devices(), model_axis=2, pod_axis=2)
        assert m.shape == {"pod": 2, "data": 2, "model": 2}, m.shape
        assert m.axis_names == ("pod", "data", "model")
        m = survivable_mesh(jax.devices(), model_axis=2)
        assert m.shape == {"data": 4, "model": 2}, m.shape
        m = survivable_mesh(jax.devices()[:4], model_axis=4, pod_axis=1)
        assert m.shape == {"data": 1, "model": 4}, m.shape
        print("shapes ok")
    """)
    assert "shapes ok" in out


def test_survivable_mesh_scale_up_one_to_two_pods(tmp_path):
    """Elasticity goes both ways: a checkpoint written by a 1-pod (4-dev)
    job restores shard-exact onto a 2-pod (8-dev) mesh."""
    out = run_sub(f"""
        from repro.checkpoint import save
        from repro.runtime.elastic import (last_remesh, remesh_restore,
                                           survivable_mesh)
        small = survivable_mesh(jax.devices()[:4], model_axis=2)
        params = {{"w": np.arange(256 * 8, dtype=np.float32).reshape(256, 8)}}
        save(r"{tmp_path}", 7, params)

        big = survivable_mesh(jax.devices(), model_axis=2, pod_axis=2)
        like = {{"w": jax.ShapeDtypeStruct((256, 8), jnp.float32)}}
        state, step = remesh_restore(r"{tmp_path}", like,
                                     {{"w": ("batch", None)}}, big)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(state["w"]), params["w"])
        rep = last_remesh()
        assert rep.mesh.token == "pod2.data2.model2", rep
        n_shards = len(set(state["w"].sharding.addressable_devices))
        assert n_shards == 8, n_shards
        print("scale-up ok")
    """)
    assert "scale-up ok" in out


# ---------------------------------------------------------------------------
# The chaos scenarios (subprocess-heavy -> slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_chaos_kill_restart_bitwise_and_prewarmed(tmp_path):
    r = chaos.scenario_kill_restart(str(tmp_path), steps=10, kill_at=7,
                                    ckpt_every=3)
    assert r["ok"], r
    assert r["killed"] and r["kill_rc"] == -9
    assert r["bitwise_identical"]
    assert r["resume_step"] == 6
    assert r["prewarmed"] >= 1
    stats = r["restart_plan_stats"]
    assert stats.get("measured", 0) == 0, stats     # zero re-measurements
    assert stats.get("memory", 0) >= 4, stats       # every step a warm hit
    assert r["recovery_s"] <= r["recovery_bound_s"]


@pytest.mark.slow
def test_chaos_sigterm_drain_saves_once(tmp_path):
    r = chaos.scenario_sigterm_drain(str(tmp_path), steps=12, sigterm_at=6,
                                     ckpt_every=3)
    assert r["ok"], r
    assert r["preempted"] and r["drained_at"] == 6
    # preemption landed exactly on the boundary: one save, not two
    assert r["save_count"] == r["expected_saves"] == 2
    assert r["resume_step"] == 6 and r["bitwise_identical"]


@pytest.mark.slow
def test_chaos_evict_remesh_plan_correctness(tmp_path):
    r = chaos.scenario_evict_remesh(str(tmp_path))
    assert r["ok"], r
    assert r["old_mesh"] == "pod2.data2.model2"
    assert r["new_mesh"] == "data2.model2"
    assert r["planner_dropped"] >= 1 and r["autotune_dropped"] >= 1
    # first post-remesh call site: swept PlanDB plan for the new topology
    assert r["post_remesh_source"] == "plandb"
    assert r["post_remesh_mesh"] == "data2.model2"
    assert r["post_remesh_stats"].get("measured", 0) == 0
    assert r["recovery_s"] <= r["recovery_bound_s"]


@pytest.mark.slow
def test_chaos_slow_host_rebalance_replans(tmp_path):
    r = chaos.scenario_slow_host(str(tmp_path))
    assert r["ok"], r
    assert r["mad_path"], r                  # detected via MAD, not fallback
    assert r["share_after"] < r["share_before"]
    # the re-plan ran through shard_streams at the shrunk shard shape
    assert r["replan_mesh"] == "data2"
    assert r["n_words_after"] < r["n_words_before"]
    assert any(m["action"] == "rebalance" for m in r["mitigations"])
