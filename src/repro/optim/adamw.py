"""Sharded AdamW (decoupled weight decay) with global-norm clipping.

Functional, pytree-native, fp32 master state; sharding follows the param
shardings (the optimizer state pytree inherits the params' NamedShardings
under pjit — fully-sharded ZeRO-style states come free from the rules).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr_peak * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(cfg: AdamWConfig, grads, state, params
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.unflatten(treedef, [t[0] for t in leaves])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in leaves])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in leaves])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
