"""Benchmark workload definitions mirroring the paper's Table 1 suite.

Each entry is a :class:`repro.core.Workload` for the analytic DAE model. A
*word* is one main-loop iteration (the paper pipes one scalar per load per
iteration). Published structure is used where the paper gives it —
FW: baseline II=285; BackProp: II=416; NW: true-MLCD rewritten then ~II
order 300; irregular kernels' divergence from Table 1 — and the remaining
constants (bytes/iteration, DLCD chain lengths) are calibrated once against
Table 2; deviations are reported side-by-side by the benchmark, not hidden.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import Workload


@dataclasses.dataclass(frozen=True)
class Bench:
    name: str
    workload: Workload
    paper_speedup: float            # Table 2: FF vs single work-item
    paper_m2c2: Optional[float]     # Fig. 4: M2C2 vs FF (≈, read off bars)
    note: str = ""


BENCHES: Dict[str, Bench] = {
    "BFS": Bench(
        "BFS",
        Workload(n_words=1 << 21, word_bytes=20, flops_per_word=16,
                 regular=False, divergence=0.8, dlcd_cycles=4,
                 false_mlcd_ii=96.0),
        paper_speedup=13.84, paper_m2c2=1.35,
        note="irregular graph traversal; frontier-dependent divergence"),
    "PageRank": Bench(
        "PageRank",
        Workload(n_words=1 << 21, word_bytes=256, flops_per_word=24,
                 regular=False, divergence=0.05, dlcd_cycles=2,
                 false_mlcd_ii=0.0),
        paper_speedup=0.96, paper_m2c2=1.02,
        note="already bandwidth-saturated; FF ~neutral (paper: 0.96x)"),
    "FW": Bench(
        "FW",
        Workload(n_words=1 << 22, word_bytes=24, flops_per_word=16,
                 regular=True, divergence=0.0, dlcd_cycles=4,
                 false_mlcd_ii=285.0),
        paper_speedup=64.95, paper_m2c2=1.25,
        note="paper: II=285 false MLCD; prefetch LSU after FF, 630->3130 MB/s"),
    "MIS": Bench(
        "MIS",
        Workload(n_words=1 << 21, word_bytes=24, flops_per_word=12,
                 regular=False, divergence=0.6, dlcd_cycles=4,
                 false_mlcd_ii=64.0),
        paper_speedup=6.47, paper_m2c2=1.4,
        note="paper: 208 -> 2116 MB/s bandwidth after FF"),
    "Color": Bench(
        "Color",
        Workload(n_words=1 << 21, word_bytes=128, flops_per_word=24,
                 regular=False, divergence=0.2, dlcd_cycles=16,
                 false_mlcd_ii=0.0),
        paper_speedup=1.02, paper_m2c2=1.3,
        note="no false MLCD; neutral FF, gains only from M2C2"),
    "Hotspot": Bench(
        "Hotspot",
        Workload(n_words=1 << 20, word_bytes=8192, flops_per_word=1024,
                 regular=True, divergence=0.0, dlcd_cycles=8,
                 false_mlcd_ii=0.0),
        paper_speedup=0.85, paper_m2c2=1.85,
        note="regular stencil, saturated baseline; M2C2 7.34->13.66 GB/s"),
    "Hotspot3D": Bench(
        "Hotspot3D",
        Workload(n_words=1 << 20, word_bytes=12288, flops_per_word=1536,
                 regular=True, divergence=0.0, dlcd_cycles=8,
                 false_mlcd_ii=0.0),
        paper_speedup=0.88, paper_m2c2=1.5,
        note="as Hotspot, 3D halo"),
    "BackProp": Bench(
        "BackProp",
        Workload(n_words=1 << 22, word_bytes=512, flops_per_word=64,
                 regular=True, divergence=0.0, dlcd_cycles=8,
                 false_mlcd_ii=416.0),
        paper_speedup=44.54, paper_m2c2=1.05,
        note="paper: II=416; FF baseline already at high bandwidth -> M2C2 flat"),
    "NW": Bench(
        "NW",
        Workload(n_words=1 << 22, word_bytes=32, flops_per_word=24,
                 regular=True, divergence=0.1, dlcd_cycles=6,
                 false_mlcd_ii=320.0),
        paper_speedup=50.95, paper_m2c2=1.2,
        note="true MLCD rewritten to private-register carry first (paper §4.2)"),
}

# Table 3 microbenchmarks: generated kernels (8 loads/iteration; AI 10 / 6;
# the for-if variants add a variable-trip inner loop + reduction DLCD).
MICRO: Dict[str, Bench] = {
    "M_AI10_R": Bench(
        "M_AI10_R",
        Workload(n_words=1 << 21, word_bytes=256, flops_per_word=2560,
                 regular=True, divergence=0.0, dlcd_cycles=0.0,
                 false_mlcd_ii=0.0),
        paper_speedup=1.55, paper_m2c2=1.55,
        note="8 loads, AI=10, regular"),
    "M_AI10_IR": Bench(
        "M_AI10_IR",
        Workload(n_words=1 << 21, word_bytes=256, flops_per_word=2560,
                 regular=False, divergence=0.0, dlcd_cycles=0.0,
                 false_mlcd_ii=0.0),
        paper_speedup=1.00, paper_m2c2=1.00,
        note="8 loads, AI=10, irregular: contention cancels M2C2"),
    "M_AI6_forif_R": Bench(
        "M_AI6_forif_R",
        Workload(n_words=1 << 21, word_bytes=256, flops_per_word=1536,
                 regular=True, divergence=0.5, dlcd_cycles=8.0,
                 false_mlcd_ii=0.0),
        paper_speedup=1.90, paper_m2c2=1.90,
        note="divergent for-if + reduction DLCD"),
    "M_AI6_forif_IR": Bench(
        "M_AI6_forif_IR",
        Workload(n_words=1 << 21, word_bytes=256, flops_per_word=1536,
                 regular=False, divergence=0.5, dlcd_cycles=8.0,
                 false_mlcd_ii=0.0),
        paper_speedup=1.84, paper_m2c2=1.84,
        note="divergent + irregular"),
}
