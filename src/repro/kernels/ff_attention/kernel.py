"""Feed-forward flash attention (prefill), GQA-aware.

Paper mapping: XLA's *un-fused* attention materializes the [S, S] score
matrix in HBM — the TPU analogue of the baseline kernel whose loads round-
trip global memory. The feed-forward version streams K/V tiles through VMEM
ring pipes (memory kernel) while the online-softmax consumer never touches
HBM for intermediates. The softmax running state (m, l, acc) is the DLCD of
the paper's Fig. 3: it is loop-carried in the *consumer only*, so the K/V
stream pipelines at full depth regardless.

Layout: q,k,v are [BH, S, D] with KV heads already broadcast-indexed by the
wrapper (GQA: q head h reads kv head h // group). Grid is 1-D over
(bh, qi, kj), kj innermost, causal blocks skipped via predication.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.emitter import RingPipe, acquire, release
from repro.core.pipe import Pipe

_NEG_INF = -1e30


def _kernel(q_ref, k_hbm, v_hbm, o_ref, m_sc, l_sc, acc,
            k_buf, k_sems, v_buf, v_sems,
            *, nq: int, nkv: int, kv_groups: int, bq: int, bkv: int, d: int,
            causal: bool, scale: float, k_ring: RingPipe, v_ring: RingPipe,
            out_dtype):
    g = pl.program_id(0)
    n_words = pl.num_programs(0)
    kj = g % nkv
    qi = (g // nkv) % nq

    def kv_slice(hbm):
        def f(word):
            w_kj = word % nkv
            w_bh = (word // (nkv * nq)) // kv_groups
            return hbm.at[w_bh, pl.ds(w_kj * bkv, bkv), :]
        return f

    pipes = [k_ring.bind(k_buf, k_sems, kv_slice(k_hbm)),
             v_ring.bind(v_buf, v_sems, kv_slice(v_hbm))]
    acquire(g, n_words, pipes)

    @pl.when(kj == 0)
    def _():
        m_sc[...] = jnp.full_like(m_sc, _NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc[...] = jnp.zeros_like(acc)

    q_end = (qi + 1) * bq - 1
    kv_start = kj * bkv
    live = (kv_start <= q_end) if causal else True

    @pl.when(live)
    def _():
        q = q_ref[0]                                  # [bq, d]
        k = k_ring.slot(g)[...]                       # [bkv, d]
        v = v_ring.slot(g)[...]                       # [bkv, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bkv]
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            cols = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        m_prev = m_sc[:, :1]                          # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                        # [bq, bkv]
        alpha = jnp.exp(m_prev - m_new)               # [bq, 1]
        l_new = l_sc[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc[...] = acc[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_sc[...] = jnp.broadcast_to(m_new, m_sc.shape)
        l_sc[...] = jnp.broadcast_to(l_new, l_sc.shape)

    @pl.when(kj == nkv - 1)
    def _():
        l = l_sc[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)               # fully-masked rows -> 0
        o_ref[0] = (acc[...] / l).astype(out_dtype)

    release(g, n_words, pipes)


@functools.partial(
    jax.jit,
    static_argnames=("kv_groups", "block_q", "block_kv", "depth", "streams",
                     "causal", "interpret"))
def flash_attention_ff(
    q: jnp.ndarray,               # [BH, S, D]
    k: jnp.ndarray,               # [BKVH, S, D]
    v: jnp.ndarray,               # [BKVH, S, D]
    *,
    kv_groups: int = 1,
    block_q: int = 128,
    block_kv: int = 128,
    depth: int = 2,
    streams: int = 1,
    causal: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    bh, s, d = q.shape
    kvbh, skv, dk = k.shape
    assert d == dk and v.shape == k.shape and bh == kvbh * kv_groups
    assert s % block_q == 0 and skv % block_kv == 0, (s, skv, block_q, block_kv)
    nq, nkv = s // block_q, skv // block_kv
    scale = 1.0 / (d ** 0.5)

    k_ring = RingPipe(Pipe(tile=(block_kv, d), dtype=k.dtype, depth=depth,
                           streams=streams))
    v_ring = RingPipe(Pipe(tile=(block_kv, d), dtype=v.dtype, depth=depth,
                           streams=streams))

    kernel = functools.partial(
        _kernel, nq=nq, nkv=nkv, kv_groups=kv_groups, bq=block_q,
        bkv=block_kv, d=d, causal=causal, scale=scale,
        k_ring=k_ring, v_ring=v_ring, out_dtype=q.dtype)
    return pl.pallas_call(
        kernel,
        grid=(bh * nq * nkv,),
        in_specs=[
            pl.BlockSpec((1, block_q, d),
                         lambda g: (g // (nkv * nq), (g // nkv) % nq, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, d), lambda g: (g // (nkv * nq), (g // nkv) % nq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
            *k_ring.scratch_shapes,
            *v_ring.scratch_shapes,
        ],
        interpret=interpret,
    )(q, k, v)
