"""Gradient compression: int8 quantization with error feedback.

Two deployment points:

* :class:`QuantizedAccumulator` — int8 gradient-accumulation buffers for the
  microbatch loop (4x accumulator memory saving; error feedback keeps the
  bias bounded).
* :func:`compressed_allreduce` — int8-on-the-wire DP gradient reduction for
  shard_map paths (all-gather int8 + local dequant-sum; wire bytes drop 4x
  vs f32 ring all-reduce at the cost of gather fan-in — the trade is
  analyzed in benchmarks/roofline_report.py).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8. Returns (q int8, scale f32)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


class QuantizedAccumulator:
    """Error-feedback int8 accumulator: acc += g, with the quantization
    residual carried forward so sum(decoded) -> sum(g) over steps."""

    @staticmethod
    def init(params):
        return {
            "q": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.int8), params),
            "scale": jax.tree.map(lambda p: jnp.ones((), jnp.float32), params),
            "err": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params),
        }

    @staticmethod
    def add(state, grads):
        def upd(q, scale, err, g):
            total = dequantize(q, scale) + g.astype(jnp.float32) + err
            nq, ns = quantize(total)
            nerr = total - dequantize(nq, ns)
            return nq, ns, nerr

        flat_q, treedef = jax.tree.flatten(state["q"])
        flat_s = treedef.flatten_up_to(state["scale"])
        flat_e = treedef.flatten_up_to(state["err"])
        flat_g = treedef.flatten_up_to(grads)
        outs = [upd(q, s, e, g)
                for q, s, e, g in zip(flat_q, flat_s, flat_e, flat_g)]
        return {
            "q": jax.tree.unflatten(treedef, [o[0] for o in outs]),
            "scale": jax.tree.unflatten(treedef, [o[1] for o in outs]),
            "err": jax.tree.unflatten(treedef, [o[2] for o in outs]),
        }

    @staticmethod
    def read(state):
        return jax.tree.map(dequantize, state["q"], state["scale"])


def compressed_allreduce(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-on-the-wire mean all-reduce (use under shard_map).

    Each device quantizes locally; int8 payloads + f32 scales are
    all-gathered; dequant-sum happens locally. Exact int8 semantics: the
    only loss is each device's own quantization error (bounded by
    max|x|/127 per element).
    """
    q, scale = quantize(x)
    qs = jax.lax.all_gather(q, axis_name)            # [n_dev, ...] int8
    ss = jax.lax.all_gather(scale, axis_name)        # [n_dev]
    n = qs.shape[0]
    total = jnp.tensordot(ss, qs.astype(jnp.float32), axes=1)
    return (total / n).astype(x.dtype)
