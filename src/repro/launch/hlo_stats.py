"""Collective statistics from compiled HLO text.

``cost_analysis()`` has no collective term, so the roofline's third term is
parsed from the (per-device, SPMD-partitioned) HLO: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op's result
bytes, its replica-group size, and a ring-model wire-time estimate:

    all-reduce          2 (g-1)/g * bytes / link_bw
    all-gather          (g-1)/g * bytes / link_bw      (bytes = gathered)
    reduce-scatter      (g-1)/g * bytes / link_bw      (bytes = input)
    all-to-all          (g-1)/g * bytes / link_bw
    collective-permute  bytes / link_bw

The dry-run applies this to *unrolled* L=1/L=2 program variants (no while
loops -> nothing hidden in loop bodies) and extrapolates per layer.
"""

from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


def collective_stats(hlo_text: str, link_bw: float = 50e9) -> Dict:
    """Returns {op: {count, bytes, seconds}, total_bytes, total_seconds}."""
    stats = {op: {"count": 0, "bytes": 0.0, "seconds": 0.0} for op in _OPS}
    for line in hlo_text.splitlines():
        for op in _OPS:
            token = f" {op}("
            token_start = f" {op}-start("
            if token not in line and token_start not in line:
                continue
            if f"{op}-done" in line:
                continue
            eq = line.find("= ")
            if eq < 0:
                continue
            opn = line.find(token_start if token_start in line else token)
            result_type = line[eq + 2:opn + 1]
            nbytes = _shape_bytes(result_type)
            g = max(_group_size(line), 1)
            if op == "all-reduce":
                sec = 2.0 * (g - 1) / g * nbytes / link_bw
            elif op == "collective-permute":
                sec = nbytes / link_bw
            else:
                sec = (g - 1) / g * nbytes / link_bw
            stats[op]["count"] += 1
            stats[op]["bytes"] += float(nbytes)
            stats[op]["seconds"] += sec
            break
    stats["total_bytes"] = sum(stats[o]["bytes"] for o in _OPS)
    stats["total_seconds"] = sum(stats[o]["seconds"] for o in _OPS)
    stats["total_count"] = sum(stats[o]["count"] for o in _OPS)
    return stats
