"""Mesh-aware streams: run the StreamProgram stack inside ``shard_map``.

This is the runtime bridge the distributed layer was missing: before it,
every sharded path (collectives, pipeline parallelism, the launch drivers)
bypassed the pipe machinery entirely and the planner only ever saw
single-device call sites. The bridge is deliberately thin:

* :func:`mesh_policy` tags a :class:`~repro.core.program.PipePolicy` with
  the ambient mesh topology (:class:`~repro.core.meshspec.MeshSpec`), so
  every plan and tuned-plan cache entry resolved under it is scoped to the
  topology — plans never leak across meshes;
* :func:`shard_streams` wraps any stream-kernel callable (a ``repro.ops``
  entrypoint, a compiled program, a whole model step) in ``shard_map``
  with the mesh-tagged policy installed as the session default inside the
  body. The body sees *local shard shapes*, so the planner automatically
  derives per-shard local workloads — the kernel running on 1/Nth of the
  batch plans 1/Nth of the word schedule, not the global one;
* :func:`shard_map_compat` papers over the ``jax.shard_map`` /
  ``jax.experimental.shard_map`` relocation (jax < 0.5), exactly like the
  distributed tests do, so every runtime module shares one shim.

Example — a registry kernel under an 8-way data mesh::

    mesh = jax.make_mesh((8,), ("data",))
    with sharding.use_sharding(mesh):
        f = shard_streams(repro.ops.matmul,
                          in_specs=(P("data"), P(None, None)),
                          out_specs=P("data"))
        y = f(a, b)       # each shard plans (and caches) at local shapes
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax

from repro import obs
from repro.core.meshspec import MeshSpec
from repro.core.program import PipePolicy, current_policy
from repro.core.program import policy as policy_ctx
from repro.runtime import sharding as shlib


def shard_map_compat(f: Callable[..., Any], mesh, in_specs, out_specs,
                     check: bool = False) -> Callable[..., Any]:
    """``jax.shard_map`` across jax versions (< 0.5 keeps it in
    jax.experimental with the replication-check kwarg named check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)


def mesh_policy(policy: Optional[PipePolicy] = None,
                ctx: Optional[shlib.ShardingContext] = None) -> PipePolicy:
    """Tag a policy with the mesh topology it will run under.

    ``policy`` defaults to the session policy, ``ctx`` to the ambient
    :class:`~repro.runtime.sharding.ShardingContext`. Without either mesh
    source the policy is returned unchanged (single-device call sites need
    no tag). The tag makes the topology explicit in every plan cache key
    even where the thread-local context is not visible (e.g. a policy
    captured at trace time and resolved later).
    """
    pol = current_policy() if policy is None else policy
    ctx = ctx or shlib.current()
    if pol.mesh is not None or ctx is None:
        return pol
    return pol.replace(mesh=MeshSpec.from_mesh(ctx.mesh))


def shard_streams(fn: Callable[..., Any], *, in_specs, out_specs,
                  ctx: Optional[shlib.ShardingContext] = None,
                  mesh=None, policy: Optional[PipePolicy] = None,
                  check: bool = False) -> Callable[..., Any]:
    """Wrap a stream-kernel callable in ``shard_map`` with mesh-aware
    planning inside the body.

    ``fn`` is any callable built on the StreamProgram stack (a
    ``repro.ops`` entrypoint, a ``compile_program`` result, a model step).
    The mesh comes from ``mesh``, else ``ctx``, else the ambient
    :func:`repro.runtime.sharding.use_sharding` context. Inside the body
    the session policy is the mesh-tagged ``policy`` (default: the current
    session policy), so:

    * the planner sizes pipes against the body's *local shard shapes*
      (per-shard word schedules — the shapes ``shard_map`` hands the body
      are already local), and
    * every plan / tuned plan is cache-keyed by the mesh topology.

    ``in_specs`` / ``out_specs`` are ordinary ``PartitionSpec`` pytrees.
    """
    ctx = ctx or shlib.current()
    if mesh is None:
        if ctx is None:
            raise ValueError(
                "shard_streams: no mesh — pass mesh=/ctx= or enter "
                "repro.runtime.sharding.use_sharding(mesh) first")
        mesh = ctx.mesh
    # the mesh actually running the body wins over the ambient context's
    # (they differ when an explicit mesh= overrides an installed context)
    pol = (policy or current_policy()).replace(
        mesh=MeshSpec.from_mesh(mesh))

    def body(*args):
        with policy_ctx(pol):
            return fn(*args)

    with obs.span("shard_streams", mesh=pol.mesh.token,
                  devices=pol.mesh.device_count):
        return shard_map_compat(body, mesh, in_specs, out_specs, check=check)
