"""whisper-tiny [audio] — encoder-decoder; conv frontend STUBBED to
precomputed frame embeddings per the assignment.
[arXiv:2212.04356; unverified]  4L d_model=384 6H (kv=6) d_ff=1536
vocab=51865."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper_tiny",
    family="encdec",
    n_layers=4,
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    act="gelu",
    norm="layernorm",
    n_frames=1500,
    rule_overrides={"heads": None, "kv_heads": None,   # 6 heads vs 16-way axis
                    "seq": "model"},                   # shard attention by seq instead
)

SMOKE = CONFIG.replace(
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    n_frames=16,
    compute_dtype="float32",
)
