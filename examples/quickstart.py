"""Quickstart: the feed-forward pipe stack in five minutes.

1. Plan a pipe for a workload (the paper's depth/streams decisions, automated).
2. Run a DAE Pallas kernel against its oracle (interpret mode on CPU),
   through the public ``repro.ops`` / ``repro.policy`` API.
3. Fuse a multi-kernel StreamGraph: MoE dispatch→expert-matmul in ONE
   pallas_call, the intermediate never touching HBM.
4. Build an assigned architecture, run a train step and a prefill+decode.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import (TPU_V5E, Pipe, Workload, estimate_baseline,
                        estimate_feedforward, plan_pipe)


def pipe_planning():
    print("== 1. pipe planning (paper §3, automated) ==")
    w = Workload(n_words=4096, word_bytes=128 * 128 * 4,
                 flops_per_word=2 * 128 * 128 * 128, regular=True)
    plan = plan_pipe(w, tile=(128, 128), dtype=jnp.float32)
    base = estimate_baseline(w, TPU_V5E)
    ff = estimate_feedforward(w, TPU_V5E, plan.pipe)
    print(f" plan: depth={plan.pipe.depth} streams={plan.pipe.streams} "
          f"vmem={plan.pipe.vmem_bytes >> 10} KiB")
    print(f" modeled: baseline {base.total_s * 1e3:.2f} ms -> "
          f"ff {ff.total_s * 1e3:.2f} ms ({base.total_s / ff.total_s:.1f}x); "
          f"{plan.rationale}")


def kernel_demo():
    print("== 2. DAE kernel vs oracle (interpret mode) ==")
    k = jax.random.key(0)
    a = jax.random.normal(k, (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(k, 1), (256, 256), jnp.float32)
    # the pure-jnp oracle is a policy mode too — no kernel-module imports
    with repro.policy(mode="ref"):
        ref = repro.ops.matmul(a, b)
    # explicit per-call policy (the paper's programmer-chosen sizing)
    out = repro.ops.matmul(a, b, policy=repro.PipePolicy(depth=3, streams=2))
    print(f" ops.matmul(depth=3, streams=2) max|err| = "
          f"{float(jnp.max(jnp.abs(out - ref))):.2e}")
    # session defaults: planner-sized ff vs the synchronous baseline
    with repro.policy(mode="baseline"):
        base = repro.ops.matmul(a, b)
    print(f" baseline (depth=1 via repro.policy) max|err| = "
          f"{float(jnp.max(jnp.abs(base - ref))):.2e}")


def graph_demo():
    print("== 3. fused StreamGraph: MoE dispatch -> expert matmul ==")
    from repro.kernels.registry import get_graph, run_graph_smoke

    # the registered two-stage-fusable MoE graph: an irregular gather
    # (dispatch) feeding a regular matmul (expert FFN), plus the combine
    # gather. compile_graph fuses dispatch->expert into ONE pallas_call —
    # the dispatched buffer lives in a VMEM ring, never in HBM — and
    # stages expert->combine (a gather edge can't fuse: its addresses are
    # data-dependent).
    spec = get_graph("moe_dispatch_ffn")
    out, ref, err, compiled = run_graph_smoke(spec)
    print(f" units: {[(u.kind, u.out_node) for u in compiled.units]}")
    for ep in compiled.plan.edges:
        print(f" edge {ep.edge.label}: {ep.mode}"
              + (f" (saves {ep.hbm_bytes_saved / 1024:.0f} KiB HBM)"
                 if ep.mode == "fused" else ""))
    est = compiled.plan.estimate
    print(f" modeled: unfused {est.unfused_s * 1e6:.1f} us -> graph "
          f"{est.total_s * 1e6:.1f} us ({est.overlap_speedup:.2f}x); "
          f"max|err| vs XLA = {err:.2e}")


def model_demo():
    print("== 4. assigned architecture: train + serve ==")
    from repro.configs.base import smoke_config
    from repro.launch import steps as steps_lib
    from repro.models import build_model
    from repro.optim import adamw

    cfg = smoke_config("llama3_2_1b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f" llama3.2-style smoke model: {model.param_count():,} params")

    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.key(2), (2, 32), 0, cfg.vocab),
    }
    train_step = jax.jit(steps_lib.make_train_step(model))
    params2, _, metrics = train_step(params, adamw.init(params), batch)
    print(f" one train step: loss={float(metrics['loss']):.4f} "
          f"gnorm={float(metrics['grad_norm']):.3f}")

    logits, cache = model.prefill(params, {"tokens": batch["tokens"]})
    tok = jnp.argmax(logits, axis=-1)
    print(f" prefill -> first sampled tokens: {np.asarray(tok)}")


if __name__ == "__main__":
    pipe_planning()
    kernel_demo()
    graph_demo()
    model_demo()
    print("quickstart done")
