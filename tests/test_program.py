"""StreamProgram / PipePolicy API tests.

Covers the declarative redesign end to end: the policy context manager and
deprecation shims, registry-enumerated old-API/new-API/ref equivalence for
every kernel, compile_program correctness on a from-scratch "sixth kernel",
the planner cache keyed by policy (hardware model), and the ff_gather
streams wiring.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import (
    ARRIA_CX,
    TPU_V5E,
    BlockIn,
    Pipe,
    PipePolicy,
    ScalarIn,
    ScratchSpec,
    Stream,
    StreamProgram,
    compile_program,
    current_policy,
    plan_cache_clear,
    plan_cache_info,
    policy,
)
from repro.core import program as program_mod
from repro.kernels.registry import all_kernels

KEY = jax.random.key(7)


def _smoke_call(spec, **op_kwargs):
    args, kw = spec.make_inputs(KEY)
    return np.float32(spec.op(*args, **kw, **op_kwargs))


# ---------------------------------------------------------------------------
# PipePolicy + policy() context manager
# ---------------------------------------------------------------------------

def test_default_policy_is_auto():
    pol = current_policy()
    assert pol == PipePolicy()
    assert pol.mode == "ff" and pol.depth == "auto" and pol.streams == "auto"
    assert pol.hw is TPU_V5E


def test_policy_context_nests_and_restores():
    base = current_policy()
    with policy(mode="baseline") as p1:
        assert current_policy() is p1
        assert p1.mode == "baseline"
        # untouched fields inherit from the enclosing policy
        assert p1.depth == base.depth and p1.hw is base.hw
        with policy(hw=ARRIA_CX, depth=3) as p2:
            assert current_policy().mode == "baseline"
            assert current_policy().hw is ARRIA_CX
            assert current_policy().depth == 3
        assert current_policy() is p1
    assert current_policy() == base


def test_policy_context_accepts_whole_policy():
    pol = PipePolicy(mode="ref", interpret=False)
    with policy(pol):
        assert current_policy() is pol
    with policy(pol, mode="ff"):
        assert current_policy().mode == "ff"
        assert current_policy().interpret is False


def test_pipe_policy_validation():
    with pytest.raises(ValueError, match="depth"):
        PipePolicy(depth="bogus")
    with pytest.raises(ValueError, match="streams"):
        PipePolicy(streams=0)
    with pytest.raises(TypeError, match="mode"):
        PipePolicy(mode=3)


def test_policy_and_legacy_kwargs_conflict():
    from repro.kernels.ff_matmul import matmul
    a = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(TypeError, match="not both"):
        matmul(a, a, policy=PipePolicy(), depth=2)


def test_legacy_kwargs_warn_once_per_op():
    from repro.kernels.ff_matmul import matmul
    a = jax.random.normal(KEY, (64, 64), jnp.float32)
    program_mod._warned_ops.discard("ff_matmul")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        matmul(a, a, depth=2, streams=1)
        first = [w for w in rec if issubclass(w.category, DeprecationWarning)]
        matmul(a, a, depth=2, streams=1)
        second = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(first) == 1 and "deprecated" in str(first[0].message)
    assert len(second) == 1       # no second warning for the same op


# ---------------------------------------------------------------------------
# Registry-enumerated equivalence: old API == new API == ref (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", all_kernels(), ids=lambda s: s.name)
def test_shim_and_policy_api_equivalent(spec):
    """The deprecated keyword plumbing and PipePolicy must hit the exact
    same compiled program, and both must match the oracle."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = _smoke_call(spec, mode="ff", depth=2, streams=1)
    new = _smoke_call(spec, policy=PipePolicy(mode="ff", depth=2, streams=1))
    ref = _smoke_call(spec, policy=PipePolicy(mode="ref"))
    np.testing.assert_array_equal(old, new)
    assert np.max(np.abs(new - ref)) <= spec.tol, spec.name


@pytest.mark.parametrize("spec", all_kernels(), ids=lambda s: s.name)
@pytest.mark.parametrize("mode", ["ff", "baseline"])
def test_every_program_matches_ref_under_auto(spec, mode):
    """compile_program property check: every registered program, planner-
    sized ("auto") pipes, both pipelined and synchronous-baseline modes."""
    out = _smoke_call(
        spec, policy=PipePolicy(mode=mode, depth="auto", streams="auto"))
    ref = _smoke_call(spec, policy=PipePolicy(mode="ref"))
    assert np.max(np.abs(out - ref)) <= spec.tol, (spec.name, mode)


def test_session_policy_reaches_kernels():
    spec = next(s for s in all_kernels() if s.name == "ff_matmul")
    ref = _smoke_call(spec, policy=PipePolicy(mode="ref"))
    with policy(mode="baseline", depth=5):     # depth ignored by baseline
        out = _smoke_call(spec)
    assert np.max(np.abs(out - ref)) <= spec.tol


def test_session_policy_reaches_model_layers():
    """Model layers must derive their policy from the session context, so
    `with repro.policy(mode="baseline")` A/B runs reach model code."""
    from repro.models import layers as L
    q = jax.random.normal(KEY, (1, 32, 2, 64), jnp.float32)
    kv = jax.random.normal(jax.random.fold_in(KEY, 9), (1, 32, 2, 64),
                           jnp.float32)
    ref = L.attention_op(q, kv, kv, causal=True, impl="xla")
    with policy(mode="baseline", depth=4, streams=1):
        out = L.attention_op(q, kv, kv, causal=True, impl="ff")
    assert np.max(np.abs(np.float32(out) - np.float32(ref))) < 2e-4


# ---------------------------------------------------------------------------
# Registered programs are StreamPrograms; repro.ops is registry-generated
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", all_kernels(), ids=lambda s: s.name)
def test_registered_program_declaration(spec):
    prog = spec.program(depth=2, streams=1)
    assert isinstance(prog, StreamProgram)
    assert prog.name == spec.name
    assert prog.n_words >= 1
    assert len(prog.streams) >= 1
    assert prog.vmem_bytes > 0
    for edge in prog.streams:
        assert edge.spec.depth == 2
    if spec.name == "ff_gather":
        assert prog.streams[0].gather
        assert prog.num_scalar_prefetch == 1


def test_ops_namespace_enumerates_registry():
    assert set(repro.ops.names()) == {
        "matmul", "attention", "decode_attention", "chunk_scan", "gather"}
    for spec in all_kernels():
        assert getattr(repro.ops, spec.alias) is spec.op
        assert getattr(repro.ops, spec.name) is spec.op
    with pytest.raises(AttributeError, match="registered"):
        repro.ops.nonexistent_op


# ---------------------------------------------------------------------------
# Plan cache keyed by policy (hardware model rides the cache key)
# ---------------------------------------------------------------------------

def test_plan_cache_hits_keyed_by_policy():
    from repro.kernels.ff_matmul import matmul
    a = jax.random.normal(KEY, (256, 256), jnp.float32)
    plan_cache_clear()
    pol = PipePolicy(depth="auto", streams="auto")
    matmul(a, a, policy=pol)
    info1 = plan_cache_info()
    assert info1.misses == 1
    matmul(a, a, policy=pol)
    info2 = plan_cache_info()
    assert info2.hits == info1.hits + 1 and info2.misses == info1.misses
    # a different hardware model is a different policy -> different plan key
    with policy(hw=ARRIA_CX):
        matmul(a, a)
    info3 = plan_cache_info()
    assert info3.misses == info2.misses + 1


# ---------------------------------------------------------------------------
# ff_gather streams wiring (satellite): planned streams widen the bundle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("streams", [1, 2, 4])
def test_gather_streams_wired_into_row_bundle(streams):
    from repro.kernels.ff_gather import gather, gather_ref
    from repro.kernels.ff_gather.kernel import build_program
    prog = build_program(32, 128, streams=streams)
    assert prog.streams[0].spec.tile[0] == 8 * streams
    assert prog.n_words == 32 // (8 * streams)

    tab = jax.random.normal(KEY, (64, 128), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (29,), 0, 64)
    out = gather(tab, idx, policy=PipePolicy(depth=2, streams=streams))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gather_ref(tab, idx)))


# ---------------------------------------------------------------------------
# Mixed-precision operands: each Stream edge keeps its own pipe dtype
# ---------------------------------------------------------------------------

def test_mixed_dtype_operands_stream_through_own_pipes():
    pol = PipePolicy(depth=2, streams=1)
    a = jax.random.normal(KEY, (128, 128), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (128, 128), jnp.bfloat16)
    out = repro.ops.matmul(a, b, policy=pol)
    ref = repro.ops.matmul(a, b, policy=PipePolicy(mode="ref"))
    assert np.max(np.abs(np.float32(out) - np.float32(ref))) < 2e-1

    q = jax.random.normal(KEY, (2, 128, 64), jnp.float32)
    kv = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 128, 64),
                           jnp.bfloat16)
    out = repro.ops.attention(q, kv, kv, block_q=64, block_kv=64, policy=pol)
    ref = repro.ops.attention(q, kv, kv, policy=PipePolicy(mode="ref"))
    assert np.max(np.abs(np.float32(out) - np.float32(ref))) < 5e-2


# ---------------------------------------------------------------------------
# compile_program on a from-scratch "sixth kernel" (the ~50-line claim)
# ---------------------------------------------------------------------------

def _prefix_sum_program(n_tiles, cols, depth):
    """Running sum of 8-row tiles: one stream edge, one scratch carry."""

    def slicer(ctx, word):
        return ctx.ref("x").at[jax.experimental.pallas.ds(word * 8, 8), :]

    def consumer(ctx):
        carry = ctx.scratch("carry")

        @jax.experimental.pallas.when(ctx.g == 0)
        def _():
            carry[...] = jnp.zeros_like(carry)

        carry[...] += ctx.word("x")[...]
        ctx.out[...] = carry[...]

    return StreamProgram(
        name="tile_prefix_sum",
        n_words=n_tiles,
        inputs=(Stream("x", Pipe(tile=(8, cols), depth=depth), slicer),),
        consumer=consumer,
        out_shape=(n_tiles * 8, cols),
        out_dtype=jnp.float32,
        out_block=(8, cols),
        out_index_map=lambda g: (g, 0),
        scratch=(ScratchSpec("carry", (8, cols), jnp.float32),),
    )


@pytest.mark.parametrize("depth", [1, 2, 4])
def test_compile_program_sixth_kernel(depth):
    import jax.experimental.pallas  # noqa: F401  (used inside the program)
    n_tiles, cols = 6, 128
    x = jax.random.normal(KEY, (n_tiles * 8, cols), jnp.float32)
    out = compile_program(_prefix_sum_program(n_tiles, cols, depth))(x)
    ref = jnp.cumsum(x.reshape(n_tiles, 8, cols), axis=0).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# StreamProgram declaration validation
# ---------------------------------------------------------------------------

def _dummy_stream(name="x"):
    return Stream(name, Pipe(tile=(8, 128)), lambda ctx, w: None)


def test_stream_program_validation():
    kwargs = dict(consumer=lambda ctx: None, out_shape=(8, 128),
                  out_dtype=jnp.float32, out_block=(8, 128),
                  out_index_map=lambda g: (0, 0))
    with pytest.raises(ValueError, match="duplicate"):
        StreamProgram(name="p", n_words=1,
                      inputs=(_dummy_stream("x"), _dummy_stream("x")), **kwargs)
    with pytest.raises(ValueError, match="ScalarIn"):
        StreamProgram(name="p", n_words=1,
                      inputs=(_dummy_stream("x"), ScalarIn("idx")), **kwargs)
    with pytest.raises(ValueError, match="Stream"):
        StreamProgram(name="p", n_words=1,
                      inputs=(BlockIn("b", (8, 128), lambda g: (0, 0)),),
                      **kwargs)
    with pytest.raises(ValueError, match="n_words"):
        StreamProgram(name="p", n_words=0, inputs=(_dummy_stream(),), **kwargs)


def test_policy_is_frozen_and_replaceable():
    pol = PipePolicy()
    with pytest.raises(dataclasses.FrozenInstanceError):
        pol.mode = "baseline"
    assert pol.replace(mode="baseline").mode == "baseline"
    assert pol.mode == "ff"
