"""repro.data — deterministic synthetic data + host producer/consumer pipe."""

from repro.data.pipeline import HostPipeline
from repro.data.synthetic import SyntheticSpec, batch_at

__all__ = ["HostPipeline", "SyntheticSpec", "batch_at"]
