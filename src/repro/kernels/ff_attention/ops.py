"""Public op wrapper + cost model for ff_attention (prefill)."""

from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.emitter import cdiv, pad_to
from repro.core.pipeline_model import Workload
from repro.core.planner import resolve_auto
from repro.kernels.ff_attention.kernel import flash_attention_ff
from repro.kernels.ff_attention.ref import attention_ref
from repro.kernels.registry import KernelCost, register_kernel


def attention_cost(bh: int, s: int, d: int, *, causal: bool = True,
                   block_kv: int = 128, depth: int = 2,
                   dtype=jnp.bfloat16) -> KernelCost:
    """Exact stream costs for one prefill attention call (per the kernel's
    tile schedule). Causal halves the live score blocks."""
    frac = 0.5 if causal else 1.0
    flops = 4.0 * bh * s * s * d * frac            # qk^T and pv matmuls
    itemsize = jnp.dtype(dtype).itemsize
    nq = cdiv(s, 128)
    # K and V are re-streamed once per live q block; q,o move once.
    kv_stream = 2 * s * d * itemsize * nq * frac
    hbm = bh * (kv_stream + 2 * s * d * itemsize)
    vmem = 2 * depth * block_kv * d * itemsize + 128 * d * 4 * 3
    return KernelCost(flops=flops, hbm_bytes=float(hbm), vmem_bytes=vmem)


def attention_workload(bh: int, s: int, d: int, *, causal: bool = True,
                       block_q: int = 128, block_kv: int = 128,
                       dtype=jnp.bfloat16) -> Tuple[Workload, Tuple[int, int]]:
    """One pipe word per (bh, qi, kj) grid step: a K and a V tile. Causal
    predication idles the consumer on dead blocks, not the stream."""
    itemsize = jnp.dtype(dtype).itemsize
    nq, nkv = cdiv(s, block_q), cdiv(s, block_kv)
    frac = 0.5 if causal else 1.0
    w = Workload(
        n_words=bh * nq * nkv,
        word_bytes=float(2 * block_kv * d * itemsize),
        flops_per_word=4.0 * block_q * block_kv * d * frac,
        regular=True,
        store_bytes_per_word=float(block_q * d * itemsize) / nkv,
    )
    return w, (block_kv, d)


def attention(q, k, v, *, kv_groups: int = 1, causal: bool = True,
              block_q: int = 128, block_kv: int = 128,
              depth: Union[int, str] = 2, streams: Union[int, str] = 1,
              mode: str = "ff", interpret: bool = True):
    """Flash attention over [BH, S, D] tensors (wrapper pads S to blocks).

    mode="ff"|"baseline"(depth=1)|"ref"; depth/streams accept "auto"
    (planner-sized per call-site shape).
    """
    if mode == "ref":
        return attention_ref(q, k, v, kv_groups=kv_groups, causal=causal)
    bh, s, d = q.shape
    skv = k.shape[1]
    w, tile = attention_workload(bh, s, d, causal=causal, block_q=block_q,
                                 block_kv=block_kv, dtype=q.dtype)
    depth, streams = resolve_auto("ff_attention", depth, streams,
                                  workload=w, tile=tile, dtype=q.dtype)
    qp = pad_to(q, block_q, 1)
    kp = pad_to(k, block_kv, 1)
    vp = pad_to(v, block_kv, 1)
    if kp.shape[1] > skv and not causal:
        raise ValueError(
            "non-causal attention requires Skv to be a block multiple "
            "(padded keys would receive softmax mass)")
    if mode == "baseline":
        depth = 1
    out = flash_attention_ff(
        qp, kp, vp, kv_groups=kv_groups, block_q=block_q, block_kv=block_kv,
        depth=depth, streams=streams, causal=causal, interpret=interpret)
    return out[:, :s, :]


def _make_inputs(key):
    q = jax.random.normal(key, (2, 192, 64), jnp.float32)
    kv = jax.random.normal(jax.random.fold_in(key, 1), (1, 192, 64),
                           jnp.float32)
    return (q, kv, kv), {"kv_groups": 2, "causal": True, "block_q": 64,
                         "block_kv": 64}


register_kernel(
    name="ff_attention",
    op=attention,
    ref=attention_ref,
    cost=attention_cost,
    workload=attention_workload,
    make_inputs=_make_inputs,
    bench_kwargs={"bh": 32, "s": 8192, "d": 128, "dtype": jnp.bfloat16},
    regular=True,
    tol=2e-4,
    doc="flash attention prefill, GQA, KV ring pipes",
)
