"""End-to-end training example: a ~100M-parameter llama-style model trained
for a few hundred steps on the synthetic Markov stream, through the full
driver stack (host data pipe -> jit train step -> AdamW -> checkpoints ->
auto-resume).

Full run (~100M params; several hours on this CPU container, minutes on a
real chip):
  PYTHONPATH=src python examples/train_tiny_lm.py

Reduced run (~10M params, a few minutes on CPU):
  PYTHONPATH=src python examples/train_tiny_lm.py --tiny
"""

import argparse
import sys

from repro.configs.base import get_config
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm")
    args = ap.parse_args()

    # ~100M params: 12 x 768 llama-style + 32k vocab (or ~10M with --tiny)
    import repro.configs.llama3_2_1b as base_mod
    if args.tiny:
        cfg = base_mod.CONFIG.replace(
            n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, d_ff=1024,
            vocab=1024, compute_dtype="float32")
    else:
        cfg = base_mod.CONFIG.replace(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
            vocab=32768, compute_dtype="float32")
    # install as a transient "arch" by monkey-patching the smoke config
    base_mod.SMOKE = cfg

    from repro.models import build_model
    n = build_model(cfg).param_count()
    print(f"training {n / 1e6:.1f}M-param model for {args.steps} steps")
    train_mod.main([
        "--arch", "llama3_2_1b", "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "256" if not args.tiny else "128",
        "--lr", "3e-3", "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100", "--log-every", "10",
    ])


if __name__ == "__main__":
    sys.exit(main())
