"""repro.checkpoint — atomic, resumable, reshardable checkpoints."""

from repro.checkpoint.checkpointer import (
    latest_step,
    restore,
    save,
    save_async,
)

__all__ = ["latest_step", "restore", "save", "save_async"]
