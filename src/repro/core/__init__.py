"""repro.core — the paper's contribution: feed-forward pipes for TPU.

Public API:
  Pipe                      on-chip FIFO spec (depth, streams, tile)
  StreamSpec / run_reference  the producer/consumer stream-program contract
  check_no_mlcd             legality (true-MLCD) checker
  Workload / HardwareModel  analytic DAE pipeline model
  estimate_baseline / estimate_feedforward / speedup
  plan_pipe                 roofline-driven (depth, streams) auto-tuner
"""

from repro.core.pipe import Pipe, required_depth, vmem_budget_ok
from repro.core.feedforward import (
    Footprint,
    StreamSpec,
    check_no_mlcd,
    reduction_stream,
    run_multistream_reference,
    run_reference,
    split_words_static,
)
from repro.core.pipeline_model import (
    ARRIA_CX,
    TPU_V5E,
    HardwareModel,
    PipelineEstimate,
    Workload,
    estimate_baseline,
    estimate_feedforward,
    speedup,
)
from repro.core.planner import Plan, plan_pipe

__all__ = [
    "ARRIA_CX",
    "Footprint",
    "HardwareModel",
    "Pipe",
    "PipelineEstimate",
    "Plan",
    "StreamSpec",
    "TPU_V5E",
    "Workload",
    "check_no_mlcd",
    "estimate_baseline",
    "estimate_feedforward",
    "plan_pipe",
    "reduction_stream",
    "required_depth",
    "run_multistream_reference",
    "run_reference",
    "speedup",
    "split_words_static",
    "vmem_budget_ok",
]
