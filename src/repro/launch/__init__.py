"""repro.launch — mesh construction, step builders, dry-run, roofline,
train/serve drivers."""
