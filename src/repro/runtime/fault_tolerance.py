"""Fault tolerance: checkpoint/restart supervision + preemption handling.

Designed for the 1000+ node regime where *something* is always failing:

* periodic atomic checkpoints (every N steps) + async host offload;
* SIGTERM/preemption -> drain current step, final checkpoint, clean exit
  (cluster schedulers send SIGTERM before eviction);
* on start, auto-resume from the newest complete checkpoint — a killed job
  restarted with the same command continues bitwise-identically (stateless
  data pipeline + pure-function batches make this exact; tested by killing
  a training subprocess mid-run);
* failure injection hooks for tests.
"""

from __future__ import annotations

import dataclasses
import signal
import threading
import time
from typing import Any, Callable, Dict, Optional

from repro.checkpoint import latest_step, restore, save


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_last: int = 3
    handle_sigterm: bool = True


class Supervisor:
    """Wraps a step function with checkpoint/restart semantics."""

    def __init__(self, cfg: FTConfig, state_like: Any,
                 fail_at_step: Optional[int] = None):
        self.cfg = cfg
        self.state_like = state_like
        self.fail_at_step = fail_at_step
        self._preempted = threading.Event()
        if cfg.handle_sigterm:
            try:
                signal.signal(signal.SIGTERM, self._on_sigterm)
            except ValueError:
                pass    # not on main thread (tests)

    def _on_sigterm(self, *_):
        self._preempted.set()

    def resume(self) -> tuple[Any, int]:
        """(state, start_step); fresh state_like if no checkpoint exists."""
        step = latest_step(self.cfg.ckpt_dir)
        if step is None:
            return self.state_like, 0
        state, step, _ = restore(self.cfg.ckpt_dir, self.state_like, step=step)
        return state, step

    def run(self, state: Any, start_step: int, n_steps: int,
            step_fn: Callable[[Any, int], Any],
            on_step: Optional[Callable[[int, Any], None]] = None) -> Any:
        step = start_step
        while step < n_steps:
            if self.fail_at_step is not None and step == self.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            state = step_fn(state, step)
            step += 1
            if on_step:
                on_step(step, state)
            if step % self.cfg.ckpt_every == 0 or self._preempted.is_set() \
                    or step == n_steps:
                save(self.cfg.ckpt_dir, step, state,
                     keep_last=self.cfg.keep_last)
            if self._preempted.is_set():
                break
        return state
