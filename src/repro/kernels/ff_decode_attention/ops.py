"""Public op wrapper + cost model for ff_decode_attention."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dae import cdiv
from repro.kernels.ff_decode_attention.kernel import decode_attention_ff
from repro.kernels.ff_decode_attention.ref import decode_attention_ref
from repro.kernels.ff_matmul.ops import KernelCost


def decode_attention_cost(b: int, h: int, kvh: int, s: int, d: int,
                          *, block_kv: int = 128, depth: int = 2,
                          dtype=jnp.bfloat16) -> KernelCost:
    itemsize = jnp.dtype(dtype).itemsize
    flops = 4.0 * b * h * s * d
    hbm = b * kvh * 2 * s * d * itemsize + 2 * b * h * d * itemsize
    g_pad = max(8, -(-(h // kvh) // 8) * 8)
    vmem = 2 * depth * block_kv * d * itemsize + g_pad * d * 4 * 3
    return KernelCost(flops=flops, hbm_bytes=float(hbm), vmem_bytes=vmem)


def decode_attention(q, k, v, lengths=None, *, kv_heads: int = None,
                     block_kv: int = 128, depth: int = 2, streams: int = 1,
                     mode: str = "ff", interpret: bool = True):
    """Decode attention for one new token.

    q: [B, H, D]; k, v: [B, KVH, S, D]; lengths: [B] int32 (defaults to S).
    Returns [B, H, D]. The wrapper regroups q heads per KV head and pads the
    group to the 8-sublane granule.
    """
    b, h, d = q.shape
    _, kvh, s, _ = k.shape
    assert h % kvh == 0
    group = h // kvh
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    if mode == "ref":
        qg = q.reshape(b, kvh, group, d)
        return decode_attention_ref(qg, k, v, lengths).reshape(b, h, d)
    g_pad = -(-group // 8) * 8
    qg = q.reshape(b, kvh, group, d)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - group), (0, 0)))
    if mode == "baseline":
        depth = 1
    out = decode_attention_ff(
        qg, k, v, lengths.astype(jnp.int32), block_kv=block_kv, depth=depth,
        streams=streams, interpret=interpret)
    return out[:, :, :group, :].reshape(b, h, d)
