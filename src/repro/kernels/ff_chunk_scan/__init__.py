from repro.kernels.ff_chunk_scan.ops import chunk_scan, chunk_scan_cost
from repro.kernels.ff_chunk_scan.ref import chunk_scan_ref, chunk_scan_xla

__all__ = ["chunk_scan", "chunk_scan_cost", "chunk_scan_ref", "chunk_scan_xla"]
