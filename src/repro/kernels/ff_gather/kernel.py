"""Feed-forward irregular gather: rows = table[idx].

The paper's *irregular memory access* case (Table 3, M-AI10-IR; MoE
dispatch / embedding lookup in our models). The index stream is scalar-
prefetched (TPU analogue of the FPGA burst-coalesced LSU's request buffer),
and each pipe word is a bundle of ``rows_per_word`` single-row DMAs issued
``depth-1`` words ahead — memory-level parallelism for a pattern the MXU
pipeline cannot prefetch on its own.

A true-MLCD variant of this op (gather from a table the same kernel is
scattering into) is *rejected* by core.check_no_mlcd and deliberately has no
kernel here — the paper's legality restriction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ROWS = 8   # rows per pipe word (one f32 sublane granule)


def _kernel(idx_ref, tab_hbm, o_ref, buf, sems, *, depth: int, cols: int):
    g = pl.program_id(0)
    n_words = pl.num_programs(0)

    def start(word):
        slot = word % depth
        for r in range(_ROWS):
            row = idx_ref[word * _ROWS + r]
            pltpu.make_async_copy(
                tab_hbm.at[pl.ds(row, 1), :],
                buf.at[slot, pl.ds(r, 1), :],
                sems.at[slot, r],
            ).start()

    def wait(word):
        slot = word % depth
        for r in range(_ROWS):
            row = idx_ref[word * _ROWS + r]
            pltpu.make_async_copy(
                tab_hbm.at[pl.ds(row, 1), :],
                buf.at[slot, pl.ds(r, 1), :],
                sems.at[slot, r],
            ).wait()

    if depth == 1:
        start(g)
        wait(g)
    else:
        @pl.when(g == 0)
        def _():
            for d in range(depth):
                @pl.when(d < n_words)
                def _(d=d):
                    start(d)

        wait(g)

    o_ref[...] = buf[g % depth]

    if depth > 1:
        @pl.when(g + depth < n_words)
        def _():
            start(g + depth)


@functools.partial(jax.jit, static_argnames=("depth", "interpret"))
def gather_ff(table: jnp.ndarray, idx: jnp.ndarray, *, depth: int = 4,
              interpret: bool = True) -> jnp.ndarray:
    """table: [R, C]; idx: [n] int32 with n % 8 == 0. Returns [n, C]."""
    r, c = table.shape
    n = idx.shape[0]
    assert n % _ROWS == 0, n
    kernel = functools.partial(_kernel, depth=depth, cols=c)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n // _ROWS,),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((_ROWS, c), lambda g, idx: (g, 0)),
            scratch_shapes=[
                pltpu.VMEM((depth, _ROWS, c), table.dtype),
                pltpu.SemaphoreType.DMA((depth, _ROWS)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n, c), table.dtype),
        interpret=interpret,
    )(idx, table)
