"""Production meshes.

Single pod: 16 x 16 = 256 chips (data x model).
Multi-pod:  2 x 16 x 16 = 512 chips (pod x data x model) — the "pod" axis is
data-parallel across ICI-connected pods (DCN at real scale); the sharding
rules map logical "batch" to ("pod", "data") so the same model code serves
both meshes.

``make_production_mesh`` is a *function* (never a module-level constant) so
importing this module touches no jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 2):
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
