"""Public op wrapper + cost model for ff_attention (prefill)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.emitter import cdiv, pad_to
from repro.core.pipeline_model import Workload
from repro.core.program import PipePolicy, make_entrypoint
from repro.kernels.ff_attention.kernel import build_program, \
    flash_attention_ff
from repro.kernels.ff_attention.ref import attention_ref
from repro.kernels.registry import KernelCost, register_kernel


def attention_cost(bh: int, s: int, d: int, *, causal: bool = True,
                   block_kv: int = 128, depth: int = 2,
                   dtype=jnp.bfloat16) -> KernelCost:
    """Exact stream costs for one prefill attention call (per the kernel's
    tile schedule). Causal halves the live score blocks."""
    frac = 0.5 if causal else 1.0
    flops = 4.0 * bh * s * s * d * frac            # qk^T and pv matmuls
    itemsize = jnp.dtype(dtype).itemsize
    nq = cdiv(s, 128)
    # K and V are re-streamed once per live q block; q,o move once.
    kv_stream = 2 * s * d * itemsize * nq * frac
    hbm = bh * (kv_stream + 2 * s * d * itemsize)
    vmem = 2 * depth * block_kv * d * itemsize + 128 * d * 4 * 3
    return KernelCost(flops=flops, hbm_bytes=float(hbm), vmem_bytes=vmem)


def attention_workload(bh: int, s: int, d: int, *, causal: bool = True,
                       block_q: int = 128, block_kv: int = 128,
                       dtype=jnp.bfloat16) -> Tuple[Workload, Tuple[int, int]]:
    """One pipe word per (bh, qi, kj) grid step: a K and a V tile. Causal
    predication idles the consumer on dead blocks, not the stream."""
    itemsize = jnp.dtype(dtype).itemsize
    nq, nkv = cdiv(s, block_q), cdiv(s, block_kv)
    frac = 0.5 if causal else 1.0
    w = Workload(
        n_words=bh * nq * nkv,
        word_bytes=float(2 * block_kv * d * itemsize),
        flops_per_word=4.0 * block_q * block_kv * d * frac,
        regular=True,
        store_bytes_per_word=float(block_q * d * itemsize) / nkv,
    )
    return w, (block_kv, d)


# tile candidates for mode="autotune": both KV ring word sizes and the
# q-block revisit factor move the modeled (and measured) word schedule
_TILE_OPTIONS = (
    {"block_q": 64, "block_kv": 64},
    {"block_q": 64, "block_kv": 128},
    {"block_q": 128, "block_kv": 256},
    {"block_q": 256, "block_kv": 128},
)


def _apply(q, k, v, *, kv_groups: int = 1, causal: bool = True,
           block_q: int = 128, block_kv: int = 128,
           policy: PipePolicy):
    """Flash attention over [BH, S, D] tensors (wrapper pads S to blocks).

    policy.mode="ff"|"autotune"(measured plan)|"baseline"(depth=1)|"ref";
    the policy's depth/streams "auto" are planner-sized per call-site shape
    against policy.hw, "measured" resolves through the autotuner's plan
    cache.
    """
    if policy.mode == "ref":
        return attention_ref(q, k, v, kv_groups=kv_groups, causal=causal)
    bh, s, d = q.shape
    skv = k.shape[1]

    def _run(bq, bkv, depth, streams):
        qp = pad_to(q, bq, 1)
        kp = pad_to(k, bkv, 1)
        vp = pad_to(v, bkv, 1)
        if kp.shape[1] > skv and not causal:
            raise ValueError(
                "non-causal attention requires Skv to be a block multiple "
                "(padded keys would receive softmax mass)")
        return flash_attention_ff(
            qp, kp, vp, kv_groups=kv_groups, block_q=bq, block_kv=bkv,
            depth=depth, streams=streams, causal=causal,
            interpret=policy.interpret)

    w, tile = attention_workload(bh, s, d, causal=causal, block_q=block_q,
                                 block_kv=block_kv, dtype=q.dtype)
    choice = autotune.resolve_call(
        "ff_attention", policy, workload=w, tile=tile, dtype=q.dtype,
        workload_fn=lambda tk: attention_workload(
            bh, s, d, causal=causal, block_q=tk.get("block_q", block_q),
            block_kv=tk.get("block_kv", block_kv), dtype=q.dtype),
        runner=None if autotune.has_tracers(q, k, v) else
        lambda tk, dep, st: lambda: _run(
            tk.get("block_q", block_q), tk.get("block_kv", block_kv),
            dep, st),
        tile_options=_TILE_OPTIONS,
        # the workload is built from the q shape only; skv/kv_groups
        # change the measured kernel
        extra_key=f"skv={skv}|groups={kv_groups}",
        site={"bh": bh, "s": s, "d": d, "skv": skv,
              "kv_groups": kv_groups, "causal": causal,
              "block_q": block_q, "block_kv": block_kv},
        site_dynamic=("bh", "s", "skv"))
    out = _run(choice.tile_kwargs.get("block_q", block_q),
               choice.tile_kwargs.get("block_kv", block_kv),
               choice.depth, choice.streams)
    return out[:, :s, :]


attention = make_entrypoint("ff_attention", _apply)


def _make_inputs(key):
    q = jax.random.normal(key, (2, 192, 64), jnp.float32)
    kv = jax.random.normal(jax.random.fold_in(key, 1), (1, 192, 64),
                           jnp.float32)
    return (q, kv, kv), {"kv_groups": 2, "causal": True, "block_q": 64,
                         "block_kv": 64}


def _sweep_inputs(key, site):
    # rebuild concrete operands at a recorded call-site shape (plan sweep).
    # The KV batch is bh/kv_groups, so bh snaps to the nearest multiple of
    # the recorded group count; causal self-attention keeps s == skv.
    groups = int(site.get("kv_groups", 1))
    kvb = max(1, int(site["bh"]) // groups)
    bh, s, d = kvb * groups, int(site["s"]), int(site["d"])
    skv = s if site.get("causal", True) else int(site.get("skv", s))
    dt = jnp.dtype(site.get("dtype", "float32"))
    q = jax.random.normal(key, (bh, s, d), dt)
    kv = jax.random.normal(jax.random.fold_in(key, 1), (kvb, skv, d), dt)
    return (q, kv, kv), {"kv_groups": groups,
                         "causal": bool(site.get("causal", True)),
                         "block_q": int(site.get("block_q", 128)),
                         "block_kv": int(site.get("block_kv", 128))}


def _smoke_program(*, depth: int = 2, streams: int = 1, tile=None):
    # the smoke shape point of _make_inputs (already block-aligned)
    tile = tile or {}
    return build_program(2, 192, 192, 64, kv_groups=2,
                         block_q=tile.get("block_q", 64),
                         block_kv=tile.get("block_kv", 64), causal=True,
                         dtype=jnp.float32, depth=depth, streams=streams)


register_kernel(
    name="ff_attention",
    alias="attention",
    op=attention,
    ref=attention_ref,
    cost=attention_cost,
    workload=attention_workload,
    program=_smoke_program,
    make_inputs=_make_inputs,
    bench_kwargs={"bh": 32, "s": 8192, "d": 128, "dtype": jnp.bfloat16},
    tile_options=_TILE_OPTIONS,
    regular=True,
    tol=2e-4,
    doc="flash attention prefill, GQA, KV ring pipes",
    shard_dims=(0, 0, 0),        # head-batch dim data-parallel (q and kv
    shard_out_dim=0,             # shard together, preserving kv_groups)
    sweep_inputs=_sweep_inputs,
)
