"""The feed-forward stage abstraction: a composable producer/consumer split.

This module is the JAX-facing embodiment of the paper's kernel transformation
(Section 3, steps 1-14): a kernel is re-expressed as a *stream program* —

  * a **producer** that, for word index ``i``, names the global-memory reads
    (and only the reads) needed by that word;
  * a **consumer** that folds each word into a carry (all arithmetic, DLCDs,
    and global stores live here);

— plus a :class:`~repro.core.pipe.Pipe` describing the FIFO between them.

Given a :class:`StreamSpec` you can:

  * run it with **reference semantics** (:func:`run_reference`) — the
    "single work-item" program order, one word fully loaded then fully
    consumed; this is the correctness oracle for every Pallas kernel;
  * **estimate** its baseline/FF/M2C2 timing via ``core.pipeline_model``;
  * hand it to a Pallas kernel in ``repro.kernels`` that implements the same
    word schedule with a real VMEM ring buffer (the hot paths specialize the
    schedule rather than interpreting the spec, so the MXU sees static
    shapes — the spec is the contract they are tested against).

The split is legal only when no word's loads depend on a *later or same*
word's stores through global memory (the paper's MLCD restriction).
:func:`check_no_mlcd` verifies this on a declared read/write footprint.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.pipe import Pipe


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """A feed-forward stream program.

    Attributes:
      n_words: trip count of the main loop (pipe words).
      producer: ``f(i, operands) -> word`` gathering word ``i``'s loads from
        the operand pytree. Must be free of stores and of any dependence on
        the consumer carry — this *is* the feed-forward restriction, and it
        is enforced structurally: the producer simply has no access to the
        carry.
      consumer: ``f(carry, word, i) -> carry`` folding one word.
      init: initial consumer carry.
      finalize: optional ``f(carry) -> out`` epilogue.
    """

    n_words: int
    producer: Callable[[int, Any], Any]
    consumer: Callable[[Any, Any, int], Any]
    init: Any
    finalize: Optional[Callable[[Any], Any]] = None


def run_reference(spec: StreamSpec, operands: Any) -> Any:
    """Oracle: execute the stream program in strict program order.

    Equivalent to the paper's single work-item kernel (Fig. 2a): each
    iteration loads its word then consumes it, no overlap. Every Pallas
    kernel in ``repro.kernels`` must be allclose to this.
    """

    def body(i, carry):
        word = spec.producer(i, operands)
        return spec.consumer(carry, word, i)

    carry = jax.lax.fori_loop(0, spec.n_words, body, spec.init)
    return spec.finalize(carry) if spec.finalize is not None else carry


def run_multistream_reference(spec: StreamSpec, operands: Any, streams: int,
                              combine: Callable[[Sequence[Any]], Any]) -> Any:
    """Oracle for the M2C2 schedule: static parity load balancing.

    Stream ``s`` consumes words ``s, s+streams, s+2*streams, ...`` (the
    paper's static round-robin split), each with its own carry; ``combine``
    merges the per-stream carries. Only valid when the consumer fold is
    reorderable across streams (commutative-monoid carry) — the same
    restriction the paper places on multi-consumer designs.
    """
    outs = []
    for s in range(streams):
        n_s = (spec.n_words - s + streams - 1) // streams

        def body(j, carry, s=s):
            i = s + j * streams
            word = spec.producer(i, operands)
            return spec.consumer(carry, word, i)

        outs.append(jax.lax.fori_loop(0, n_s, body, spec.init))
    merged = combine(outs)
    return spec.finalize(merged) if spec.finalize is not None else merged


# ---------------------------------------------------------------------------
# MLCD legality check (paper Section 3, "Limitations")
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Footprint:
    """Declared global-memory footprint of one word, as index ranges.

    ``reads`` / ``writes``: sequences of (buffer_name, lo, hi) half-open
    intervals word ``i`` touches.
    """

    reads: Tuple[Tuple[str, int, int], ...]
    writes: Tuple[Tuple[str, int, int], ...]


def check_no_mlcd(footprints: Sequence[Footprint]) -> Tuple[bool, str]:
    """True MLCD detector over declared footprints.

    A memory loop-carried dependency exists iff some word ``j > i`` *reads*
    a region word ``i`` *writes* (RAW through global memory across words).
    Such programs must not be feed-forward split (the paper's NW case needed
    a register-carried rewrite first). WAR/WAW across words are harmless
    here because the producer never writes.

    Returns (ok, reason). O(n^2) over words — intended for spec-sized tests
    and the microbenchmark generator, not production loops.
    """
    for i, fi in enumerate(footprints):
        for name_w, wlo, whi in fi.writes:
            for j in range(i + 1, len(footprints)):
                for name_r, rlo, rhi in footprints[j].reads:
                    if name_w == name_r and max(wlo, rlo) < min(whi, rhi):
                        return False, (
                            f"true MLCD: word {j} reads {name_r}[{rlo}:{rhi}) "
                            f"written by word {i} [{wlo}:{whi})")
    return True, "no true MLCD"


def split_words_static(n_words: int, streams: int) -> Sequence[Sequence[int]]:
    """The paper's static load-balancing: word i -> stream (i % streams)."""
    return [list(range(s, n_words, streams)) for s in range(streams)]


# ---------------------------------------------------------------------------
# Convenience: classic tiled-reduction stream (used by tests/microbenchmarks)
# ---------------------------------------------------------------------------

def reduction_stream(x: jnp.ndarray, tile_rows: int,
                     fold: Callable[[jnp.ndarray], jnp.ndarray] = jnp.sum) -> StreamSpec:
    """Stream a [N, C] array by row tiles, folding each tile to a scalar sum."""
    n, c = x.shape
    assert n % tile_rows == 0, (n, tile_rows)

    def producer(i, ops):
        return jax.lax.dynamic_slice_in_dim(ops, i * tile_rows, tile_rows, axis=0)

    def consumer(carry, word, i):
        return carry + fold(word)

    return StreamSpec(
        n_words=n // tile_rows,
        producer=producer,
        consumer=consumer,
        init=jnp.zeros((), x.dtype),
    )
