"""Plan-service CLI: ``python -m repro.plans <sweep|merge|show>``.

    # tune a PlanDB from a recorded traffic profile (1 minute budget)
    python -m repro.plans sweep --profile traffic.json --db plans_db.json \
        --budget-s 60

    # combine per-host artifacts into the release DB
    python -m repro.plans merge --out release_db.json hostA.json hostB.json

    # inspect an artifact or a profile
    python -m repro.plans show plans_db.json
    python -m repro.plans show traffic.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

from repro.plans.plandb import PlanDB, PlanDBError
from repro.plans.profile import TrafficProfile
from repro.plans.sweep import entry_priority, sweep_profile


def _cmd_sweep(args) -> int:
    profile = TrafficProfile.load(args.profile)
    db = PlanDB()
    if args.merge_into and os.path.exists(args.merge_into):
        db = PlanDB.load(args.merge_into)
    scratch = args.scratch_cache or os.path.join(
        tempfile.mkdtemp(prefix="repro-sweep-"), "plans.json")
    result = sweep_profile(
        profile, db=db, namespace=args.namespace, budget_s=args.budget_s,
        scratch_cache=scratch, warmup=args.warmup, iters=args.iters,
        top_k=args.top_k)
    result.db.save(args.db)
    print(json.dumps(result.to_payload(), indent=2, sort_keys=True))
    print(f"wrote {args.db}")
    return 0


def _cmd_merge(args) -> int:
    if not args.dbs:
        print("merge: need at least one input DB", file=sys.stderr)
        return 2
    merged = PlanDB.load(args.dbs[0])
    for path in args.dbs[1:]:
        report = merged.merge(PlanDB.load(path))
        print(f"# merged {path}: +{report.added} added, "
              f"{report.replaced} replaced, {report.kept} kept, "
              f"{len(report.conflicts)} conflicts")
        for line in report.conflicts:
            print(f"#   conflict {line}")
    merged.save(args.out)
    print(json.dumps(merged.stats(), indent=2, sort_keys=True))
    print(f"wrote {args.out}")
    return 0


def _cmd_show(args) -> int:
    with open(args.path) as f:
        payload = json.load(f)
    if "namespaces" in payload:
        db = PlanDB.load(args.path)
        print(json.dumps(db.stats(), indent=2, sort_keys=True))
    else:
        prof = TrafficProfile.from_payload(payload)
        buckets = sorted(prof.entries.values(),
                         key=lambda e: -entry_priority(e))
        print(f"traffic profile: {len(prof)} buckets, "
              f"{prof.total_count} observations")
        for e in buckets:
            print(f"  {e.op:24s} count={e.count:5d} "
                  f"variants={len(e.variants)} dtype={e.dtype} hw={e.hw} "
                  f"mesh={dict(e.mesh_axes)} site={e.site}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.plans",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("sweep", help="tune a PlanDB from a traffic profile")
    sp.add_argument("--profile", required=True)
    sp.add_argument("--db", required=True, help="output PlanDB path")
    sp.add_argument("--merge-into", default=None,
                    help="existing PlanDB to fold the sweep into")
    sp.add_argument("--namespace", default=None,
                    help="target namespace (default: this host's "
                         "fingerprint namespace)")
    sp.add_argument("--budget-s", type=float, default=None)
    sp.add_argument("--warmup", type=int, default=1)
    sp.add_argument("--iters", type=int, default=2)
    sp.add_argument("--top-k", type=int, default=None,
                    help="measured candidates per bucket "
                         "(default: tuner default)")
    sp.add_argument("--scratch-cache", default=None,
                    help="throwaway per-host plan cache used during the "
                         "sweep (default: fresh tempdir)")
    sp.set_defaults(fn=_cmd_sweep)

    mp = sub.add_parser("merge", help="merge PlanDB artifacts")
    mp.add_argument("--out", required=True)
    mp.add_argument("dbs", nargs="+")
    mp.set_defaults(fn=_cmd_merge)

    hp = sub.add_parser("show", help="inspect a PlanDB or traffic profile")
    hp.add_argument("path")
    hp.set_defaults(fn=_cmd_show)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except (PlanDBError, ValueError, FileNotFoundError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
