"""Roofline analysis over the dry-run artifacts.

Per (arch x shape x mesh) cell, from experiments/dryrun/<cell>.json:

  compute term    = HLO_FLOPs / peak_FLOPs          (per device)
  memory term     = HLO_bytes / HBM_bw              (per device)
  collective term = ring-model wire seconds         (per device)

where HLO_FLOPs / bytes / collectives are extrapolated exactly from the
unrolled L=1/L=2 variants:  total = f(1) + (units-1) * (f(2) - f(1))
(the scanned program under-counts loop bodies — measured, DESIGN.md §4).

MODEL_FLOPS is the analytic useful-work floor:
  train:    6 * N_eff * tokens  (+ attention/scan term)
  prefill:  2 * N_eff * tokens  (+ attention/scan term)
  decode:   2 * N_eff * batch   (+ attention-over-cache term)
N_eff = active params minus the embedding lookup table (tied embeddings
count once, as the unembed matmul). The ratio MODEL_FLOPS/HLO_FLOPs exposes
remat recompute and dispatch/dead work; the roofline fraction
  RF = (MODEL_FLOPS / chips / peak) / max(terms)
is the headline "how close to roofline" number per cell.

Hardware constants (assignment): TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs.base import SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = {"pod16x16": 256, "pod2x16x16": 512}


def _extrapolate(result: Dict, field) -> Optional[float]:
    v = result.get("variants")
    if not v:
        return None
    f1, f2 = field(v["L1"]), field(v["L2"])
    units = result["n_layer_units"]
    return f1 + (units - 1) * (f2 - f1)


def model_flops(arch: str, shape_name: str, n_active: int) -> float:
    """Analytic useful FLOPs (global, fwd[+bwd]) for one step."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    d, L = cfg.d_model, cfg.n_layers
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    if cfg.family == "vlm" and shape.kind != "decode":
        tokens += shape.global_batch * cfg.n_patches
    n_eff = n_active
    if not cfg.tie_embeddings:
        n_eff -= cfg.padded_vocab * d          # lookup table: no matmul
    mult = 3.0 if shape.kind == "train" else 1.0
    base = 2.0 * n_eff * tokens * mult
    if cfg.family == "encdec" and shape.kind != "decode":
        # encoder processes B x n_frames tokens through the enc share
        enc_frac = cfg.n_enc_layers / max(cfg.n_enc_layers + cfg.n_layers, 1)
        base += 2.0 * n_eff * enc_frac * shape.global_batch * cfg.n_frames \
            * mult

    # attention / scan mixing term
    h, hd = cfg.n_heads, cfg.hd
    if cfg.family == "ssm":
        n, p = cfg.ssm_head_dim, cfg.ssm_head_dim
        nh = cfg.d_model // cfg.ssm_head_dim
        mix = 8.0 * nh * n * p * L * tokens
    elif cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        nh = d_in // cfg.ssm_head_dim
        mix = 8.0 * nh * cfg.ssm_state * cfg.ssm_head_dim * L * tokens
        n_attn = L // cfg.attn_every_n
        ctx = (shape.seq_len / 2 if shape.kind != "decode" else shape.seq_len)
        mix += 4.0 * h * hd * ctx * n_attn * tokens
    else:
        ctx = (shape.seq_len / 2 if shape.kind != "decode" else shape.seq_len)
        n_attn = L + (cfg.n_enc_layers if cfg.family == "encdec" else 0)
        mix = 4.0 * h * hd * ctx * n_attn * tokens
    return base + mix * mult


def analyze_cell(result: Dict) -> Optional[Dict]:
    if result.get("skipped") or not result.get("ok"):
        return None
    chips = CHIPS[result["mesh"]]
    flops = _extrapolate(result, lambda v: v["flops"])
    nbytes = _extrapolate(result, lambda v: v["bytes"])
    coll_s = _extrapolate(result, lambda v: v["collectives"]["total_seconds"])
    coll_b = _extrapolate(result, lambda v: v["collectives"]["total_bytes"])
    if flops is None:
        return None
    t_comp = flops / PEAK_FLOPS
    t_mem = nbytes / HBM_BW
    t_coll = coll_s
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(result["arch"], result["shape"],
                     result["n_active_params"])
    t_ideal = mf / chips / PEAK_FLOPS
    # decode is inherently memory-bound: its roofline floor is the minimum
    # HBM traffic (bf16 active weights + the KV/state cache, once each),
    # so report RF against the memory ideal for decode cells
    shape = SHAPES[result["shape"]]
    rf = t_ideal / max(max(terms.values()), 1e-12)
    if shape.kind == "decode":
        min_bytes = 2.0 * result["n_active_params"] / chips \
            + result["memory"]["argument_bytes"]
        t_ideal_mem = min_bytes / HBM_BW
        rf = t_ideal_mem / max(max(terms.values()), 1e-12)
    return {
        "cell": result["cell"],
        "arch": result["arch"],
        "shape": result["shape"],
        "mesh": result["mesh"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": nbytes,
        "coll_bytes_per_dev": coll_b,
        "model_flops_global": mf,
        "useful_ratio": mf / chips / max(flops, 1.0),
        "roofline_fraction": rf,
        "peak_hbm_gib": result["memory"]["peak_bytes_est"] / 2**30,
    }


def load_all(dry_dir: str) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| cell | comp (ms) | mem (ms) | coll (ms) | bottleneck "
           "| useful/HLO | RF | HBM GiB |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} x {r['shape']} ({r['mesh']}) "
            f"| {r['t_compute_s']*1e3:.1f} | {r['t_memory_s']*1e3:.1f} "
            f"| {r['t_collective_s']*1e3:.1f} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['peak_hbm_gib']:.1f} |")
    return hdr + "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"))
    args = ap.parse_args()
    rows = []
    for result in load_all(args.dry_dir):
        a = analyze_cell(result)
        if a:
            rows.append(a)
        elif result.get("skipped"):
            print(f"SKIP {result['cell']}: {result['reason']}")
        elif not result.get("ok"):
            print(f"FAIL {result['cell']}: {result.get('error')}")
    print(markdown_table(rows))


if __name__ == "__main__":
    main()
