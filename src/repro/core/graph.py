"""StreamGraph: multi-kernel pipe graphs with fused/staged lowering.

The paper splits *one* kernel into a memory stage and a compute stage
joined by a pipe. MKPipe (arXiv 2002.01614) shows the bigger win comes when
the *multi-kernel* program is a first-class object the compiler schedules:
producer→consumer kernels pipeline through on-chip channels so intermediates
never round-trip global memory — exactly the memory-controller bottleneck
quantified by The Memory Controller Wall (arXiv 1910.06726). This module is
that compiler layer for the repo, one level above
:mod:`repro.core.program`:

* a :class:`StreamGraph` composes registered :class:`StreamProgram` nodes
  into a DAG whose inter-kernel edges are declared :class:`GraphEdge`\\ s
  ("node ``dst`` streams node ``src``'s output through its ``dst_input``
  stream");
* :func:`compile_graph` chooses **per edge** between

  - **fused** lowering — when the producer's output block schedule matches
    the consumer's stream slicer (checked statically via
    ``StreamProgram.out_schedule`` / ``Stream.index``), the edge becomes an
    in-VMEM ring pipe inside a *single* ``pallas_call``: the producer's
    words are inlined ahead of the consumer words that need them and the
    intermediate block lands in a VMEM ring slot, never in HBM;
  - **staged** lowering — a double-buffered HBM handoff: the producer's
    ``pallas_call`` materializes the intermediate, the consumer streams it
    back through its declared ring pipe (depth ≥ 2 double-buffers the
    reload), and the planner charges the round trip in
    :func:`repro.core.pipeline_model.estimate_graph`;

* fusion legality, the per-edge VMEM split (``planner.split_graph_budget``),
  the MKPipe-style cost model (``estimate_graph``), and the graph-keyed
  measured autotuner (``autotune.resolve_graph``) all hang off the same
  compiled plan, so every rejection is observable as a rationale line —
  never a silent fallback.

Fused word schedule
-------------------

Legality analysis runs entirely on Python ints: the producer's output
schedule is grouped into equal-length contiguous runs (one per output
block, in completion order), the consumer's declared stream schedule is
mapped onto those blocks through row-major element offsets (so an
``edge.reshape`` between a ``[BH, S, D]`` producer and a ``[BH*S, D]``
consumer is handled exactly), and the request order must walk the
completion order contiguously. The resulting per-word (block ordinal,
first-request) tables ride into the fused kernel as scalar-prefetched
int32 vectors — the TPU analogue of the FPGA address FIFO — so the kernel
needs no data-dependent control flow beyond ``pl.when``.

At consumer word ``g`` the fused kernel runs::

    b = ord[g]; fresh[g]?            # scalar-prefetched schedule tables
    when fresh:                      # first word that needs block b
        for j in range(words_per_block):       # inlined producer stage
            w = b * words_per_block + j
            acquire(w, producer pipes); producer.consumer(w -> ring[b]);
            release(w, producer pipes)
    acquire(g, consumer's other pipes)
    consumer.consumer(g, edge word served from ring[b])   # compute stage
    release(g, consumer's other pipes)

Producer ``BlockIn`` operands are promoted to ring streams (Pallas block
delivery follows the grid, but the inlined producer's words are
schedule-driven), which is why :class:`repro.core.program.BlockIn` carries
a declared dtype.

Whole-layer chains, epilogues, multi-consumer edges
---------------------------------------------------

Fusion is not limited to pairs: fused edges compose into linear *chains*
(``qkv → attention → out-proj → mlp``), lowered recursively — each stage's
words inline the words of the stage above it on first request, every
intermediate living in its own VMEM ring, the whole chain one
``pallas_call`` checked against the *sum* of the member nodes' split VMEM
budgets (``planner.split_graph_budget``).

A :class:`GraphNode` may carry an :class:`Epilogue` — a residual add or
RMSNorm folded into the consumer body at the output write (the paper's
"compute stage owns the final store"). Epilogue inputs are extra
``BlockIn`` operands of the node, so an edge may feed them
(``dst_input`` naming a BlockIn rather than a Stream): such *block
edges* stage by default, but when the producer is fused away inside the
same chain the consumer is served directly from the chain's intermediate
VMEM ring ("ring-served", a fused edge) — this is how one producer feeds
both the next stage's stream and a later stage's residual epilogue
without ever materializing in HBM. When a fused-away producer's other
consumers *cannot* be served in-chain, the fusion unwinds to staged with
a rationale — never a silent wrong answer.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, \
    Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs
from repro.core import planner
from repro.core.emitter import GatherRingPipe, RingPipe, acquire, release
from repro.core.meshspec import MeshSpec, SINGLE_DEVICE, localize_workload, \
    resolve_sharding
from repro.core.pipe import DEFAULT_VMEM_BUDGET_BYTES, Pipe
from repro.core.pipeline_model import EdgeEstimate, GraphStage, Workload, \
    estimate_graph
from repro.core.planner import PlanError
from repro.core.program import BlockIn, ProducerCtx, ProgramCtx, ScalarIn, \
    ScheduleOpaqueError, Stream, StreamProgram, _OpaqueScalar, \
    _clamped_streams, compile_program, program_workload

_VMEM_BUDGET_BYTES = DEFAULT_VMEM_BUDGET_BYTES


# ---------------------------------------------------------------------------
# The graph IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """A per-output-write transform folded into a node's consumer body.

    ``fn(ctx, idx, value) -> value`` runs at every ``ctx.out[idx] = value``
    the node's consumer performs, inside the kernel, before the store —
    residual adds and RMSNorm live here so they ride the fused chain
    instead of costing an extra HBM round trip. ``ctx`` is the node's
    :class:`~repro.core.program.ProgramCtx` (so ``fn`` may read
    ``ctx.g`` and ``ctx.ref(...)``); ``inputs`` declares extra
    :class:`~repro.core.program.BlockIn` operands ``fn`` reads (a residual
    tensor, a norm weight). They are appended to the program's inputs and
    may be fed by a graph edge like any other block operand.
    """

    fn: Callable
    inputs: Tuple[BlockIn, ...] = ()


class _EpilogueOut:
    """Output-ref proxy that applies the epilogue at each write."""

    __slots__ = ("_ctx", "_fn", "_out")

    def __init__(self, ctx, fn, out):
        self._ctx = ctx
        self._fn = fn
        self._out = out

    def __setitem__(self, idx, value):
        self._out[idx] = self._fn(self._ctx, idx, value)

    def __getitem__(self, idx):
        return self._out[idx]

    @property
    def at(self):
        return self._out.at


class _EpilogueCtx:
    """ProgramCtx proxy whose ``out`` routes writes through the epilogue.

    The epilogue ``fn`` receives the *underlying* ctx, so it can read its
    declared BlockIns via ``ctx.ref`` without re-entering the proxy.
    """

    __slots__ = ("_ctx", "out")

    def __init__(self, ctx, fn):
        self._ctx = ctx
        self.out = _EpilogueOut(ctx, fn, ctx.out)

    @property
    def g(self):
        return self._ctx.g

    @property
    def n_words(self):
        return self._ctx.n_words

    def ref(self, name):
        return self._ctx.ref(name)

    def word(self, name):
        return self._ctx.word(name)

    def scratch(self, name):
        return self._ctx.scratch(name)


def _with_epilogue(program: StreamProgram,
                   ep: Optional[Epilogue]) -> StreamProgram:
    """The node's effective program: epilogue inputs appended, consumer
    wrapped so every output write passes through ``ep.fn``. A pure program
    transform — the result lowers through every path (standalone node,
    fused producer, fused consumer) with no special cases."""
    if ep is None:
        return program
    orig = program.consumer

    def consumer(ctx, _orig=orig, _fn=ep.fn):
        _orig(_EpilogueCtx(ctx, _fn))

    return dataclasses.replace(
        program, name=f"{program.name}+ep",
        inputs=tuple(program.inputs) + tuple(ep.inputs),
        consumer=consumer)


@dataclasses.dataclass(frozen=True)
class GraphNode:
    """One kernel of the multi-kernel program.

    ``workload`` (optional) is the node's analytic
    :class:`~repro.core.pipeline_model.Workload` — kernels' registry
    ``workload`` builders produce it; when omitted a conservative one is
    synthesized from the program's streams. ``plan_tile`` is the tile the
    planner sizes pipes against (default: the first stream's tile).
    ``epilogue`` folds residual/norm math into the consumer body at the
    output write (see :class:`Epilogue`).
    """

    name: str
    program: StreamProgram
    workload: Optional[Workload] = None
    plan_tile: Optional[Tuple[int, ...]] = None
    epilogue: Optional[Epilogue] = None

    @property
    def effective_program(self) -> StreamProgram:
        """The program as compiled: epilogue folded into the consumer."""
        return _with_epilogue(self.program, self.epilogue)


@dataclasses.dataclass(frozen=True)
class GraphEdge:
    """One inter-kernel dataflow edge: ``dst`` reads ``src``'s output
    through its Stream input ``dst_input``.

    ``prefer``: "auto" fuses when legal and VMEM-feasible (staged fallback
    with a rationale otherwise), "fused" demands fusion (infeasibility
    raises :class:`~repro.core.planner.PlanError` with the per-edge
    rationale), "staged" pins the HBM handoff. ``reshape`` declares the
    view the consumer takes of the intermediate (e.g. ``[BH, S, D]`` →
    ``[BH*S, D]`` between attention and its out-projection); it must
    preserve the element count and is applied to the materialized array in
    staged mode and to the offset arithmetic of the legality check in
    fused mode.
    """

    src: str
    dst: str
    dst_input: str
    prefer: str = "auto"
    reshape: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.prefer not in ("auto", "fused", "staged"):
            raise ValueError(f"edge {self.src}->{self.dst}: prefer must be "
                             f"auto|fused|staged, got {self.prefer!r}")

    @property
    def label(self) -> str:
        return f"{self.src}->{self.dst}"


@dataclasses.dataclass(frozen=True)
class StreamGraph:
    """A DAG of stream programs joined by pipe edges.

    Validated at construction: node names unique, edges name known nodes
    and Stream/BlockIn inputs (epilogue inputs count — that is how a
    residual epilogue is fed by an upstream node), no input is fed twice,
    and the graph is acyclic
    (a pipe cycle would deadlock the FPGA channels it models — rejected
    here, like the paper rejects true memory loop-carried dependencies).
    """

    name: str
    nodes: Tuple[GraphNode, ...]
    edges: Tuple[GraphEdge, ...] = ()

    def __post_init__(self):
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"{self.name}: duplicate node names {names}")
        by_name = {n.name: n for n in self.nodes}
        fed = set()
        for e in self.edges:
            for end in (e.src, e.dst):
                if end not in by_name:
                    raise ValueError(f"{self.name}: edge {e.label} names "
                                     f"unknown node {end!r}")
            if e.src == e.dst:
                raise ValueError(f"{self.name}: self-edge on {e.src!r}")
            dst_node = by_name[e.dst]
            prog = dst_node.effective_program
            names = {i.name for i in prog.inputs
                     if not isinstance(i, ScalarIn)}
            if e.dst_input not in names:
                raise ValueError(
                    f"{self.name}: edge {e.label} must feed a Stream input "
                    f"or BlockIn operand of {e.dst!r}: {e.dst_input!r} not "
                    f"in {sorted(names)}")
            key = (e.dst, e.dst_input)
            if key in fed:
                raise ValueError(f"{self.name}: input {e.dst}.{e.dst_input} "
                                 f"is fed by more than one edge")
            fed.add(key)
            if e.reshape is not None:
                src_prog = by_name[e.src].program
                if int(np.prod(e.reshape)) != int(np.prod(src_prog.out_shape)):
                    raise ValueError(
                        f"{self.name}: edge {e.label} reshape {e.reshape} "
                        f"does not preserve the element count of "
                        f"{src_prog.out_shape}")
        self.topo_order()    # raises on cycles

    def node(self, name: str) -> GraphNode:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(f"{self.name}: unknown node {name!r}")

    def topo_order(self) -> Tuple[GraphNode, ...]:
        """Kahn topological order (stable in declaration order); raises
        ValueError on cycles."""
        indeg = {n.name: 0 for n in self.nodes}
        for e in self.edges:
            indeg[e.dst] += 1
        order: List[GraphNode] = []
        ready = [n for n in self.nodes if indeg[n.name] == 0]
        while ready:
            n = ready.pop(0)
            order.append(n)
            for e in self.edges:
                if e.src == n.name:
                    indeg[e.dst] -= 1
                    if indeg[e.dst] == 0:
                        ready.extend(m for m in self.nodes
                                     if m.name == e.dst)
        if len(order) != len(self.nodes):
            cyc = sorted(set(indeg) - {n.name for n in order})
            raise ValueError(f"{self.name}: graph has a cycle through "
                             f"{cyc}")
        return tuple(order)

    def sinks(self) -> Tuple[str, ...]:
        """Nodes with no out-edge — the graph's outputs, in topo order."""
        srcs = {e.src for e in self.edges}
        return tuple(n.name for n in self.topo_order() if n.name not in srcs)


# ---------------------------------------------------------------------------
# Workload synthesis + graph identity (autotune key)
# ---------------------------------------------------------------------------


def node_workload(node: GraphNode) -> Workload:
    """The node's analytic workload (declared, or synthesized from the
    program's streams when the builder did not provide one)."""
    if node.workload is not None:
        return node.workload
    return program_workload(node.program)


def _node_tile(node: GraphNode) -> Tuple[int, ...]:
    return tuple(node.plan_tile or node.program.streams[0].spec.tile)


def _node_dtype(node: GraphNode):
    return jnp.dtype(node.program.streams[0].spec.dtype)


def graph_workload(graph: StreamGraph) -> Tuple[Workload, Tuple[int, ...]]:
    """Summarize the whole graph as one Workload (the joint tuner's call
    site): total words, byte/flop averages, irregular if any node is."""
    ws = [node_workload(n) for n in graph.topo_order()]
    n_words = max(sum(w.n_words for w in ws), 1)
    w = Workload(
        n_words=n_words,
        word_bytes=sum(w.word_bytes * w.n_words for w in ws) / n_words,
        flops_per_word=sum(w.flops_per_word * w.n_words for w in ws) / n_words,
        regular=all(w.regular for w in ws),
        store_bytes_per_word=sum(w.store_bytes_per_word * w.n_words
                                 for w in ws) / n_words,
    )
    return w, _node_tile(graph.topo_order()[0])


def graph_signature(graph: StreamGraph) -> str:
    """Structural identity of the graph for the tuned-plan cache key:
    nodes (program, words, shapes, pipe tiles) + edges. Two graphs with
    the same signature lower identically, so a tuned plan transfers."""
    parts = []
    for n in graph.topo_order():
        p = n.program
        tiles = ",".join("x".join(map(str, s.spec.tile)) for s in p.streams)
        ep = f"+ep{len(n.epilogue.inputs)}" if n.epilogue else ""
        parts.append(f"{n.name}={p.name}{ep}/{p.n_words}w/"
                     f"{'x'.join(map(str, p.out_shape))}"
                     f"{jnp.dtype(p.out_dtype).name}/[{tiles}]")
    for e in graph.edges:
        parts.append(f"{e.label}.{e.dst_input}.{e.prefer}"
                     + (f".r{'x'.join(map(str, e.reshape))}"
                        if e.reshape else ""))
    return ";".join(parts)


# ---------------------------------------------------------------------------
# Fusion legality
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusionReport:
    """Outcome of the static legality analysis of one edge.

    When ``ok``: ``wpb`` producer words complete each of ``n_blocks``
    output blocks (contiguous, in ordinal order); ``ord_seq[g]`` is the
    block ordinal consumer word ``g`` reads; ``squeeze`` leading unit dims
    of the producer block are dropped to match the consumer tile;
    ``inter_depth`` sizes the in-VMEM intermediate ring.
    """

    ok: bool
    reason: str
    wpb: int = 1
    n_blocks: int = 0
    ord_seq: Tuple[int, ...] = ()
    squeeze: int = 0
    inter_depth: int = 1


def _strides(shape: Sequence[int]) -> List[int]:
    st = [1] * len(shape)
    for i in range(len(shape) - 2, -1, -1):
        st[i] = st[i + 1] * shape[i + 1]
    return st


def _block_offset(idx, block, shape) -> int:
    return sum(int(i) * b * s for i, b, s in zip(idx, block, _strides(shape)))


def _is_contiguous_slab(block, shape) -> bool:
    """Is a block at any grid-aligned start a contiguous row-major slab?
    Leading unit dims are free; after the first non-unit dim every dim must
    be full."""
    dims = list(zip(block, shape))
    i = 0
    while i < len(dims) and dims[i][0] == 1:
        i += 1
    return all(b == d for b, d in dims[i + 1:])


def check_fusion(producer: StreamProgram, consumer: StreamProgram,
                 edge: GraphEdge) -> FusionReport:
    """Static legality of fusing ``edge`` (pure-Python schedule analysis).

    Legal iff the producer's output block schedule *is* the consumer's
    stream schedule: same tile (modulo leading unit dims), blocks completed
    in contiguous equal-length word runs, and the consumer's declared
    request order walks the completion order contiguously (revisits allowed
    — a block may serve several consecutive consumer words, the ring slot
    simply stays live). Anything else returns ``ok=False`` with the
    rationale that ends up in the plan / bench JSON.
    """

    def no(reason: str) -> FusionReport:
        return FusionReport(False, reason)

    try:
        st = consumer.stream(edge.dst_input)
    except KeyError as e:
        return no(str(e))
    if st.gather:
        return no(f"consumer stream {edge.dst_input!r} is an irregular "
                  f"gather (data-dependent addresses)")
    try:
        pout = producer.out_schedule()
    except ScheduleOpaqueError as e:
        return no(f"producer schedule opaque: {e}")
    try:
        creq = consumer.stream_schedule(edge.dst_input)
    except ScheduleOpaqueError as e:
        return no(f"consumer schedule opaque: {e}")

    pblock = tuple(producer.out_block)
    tile = tuple(st.spec.tile)
    squeeze = 0
    while len(pblock) - squeeze > len(tile) and pblock[squeeze] == 1:
        squeeze += 1
    if pblock[squeeze:] != tile:
        return no(f"mismatched block schedules: producer out_block {pblock} "
                  f"vs consumer tile {tile}")
    if jnp.dtype(producer.out_dtype) != jnp.dtype(st.spec.dtype):
        return no(f"dtype mismatch: producer {jnp.dtype(producer.out_dtype).name} "
                  f"vs consumer pipe {jnp.dtype(st.spec.dtype).name}")
    cshape = tuple(edge.reshape) if edge.reshape else tuple(producer.out_shape)
    if len(cshape) != len(tile):
        return no(f"consumer operand rank {len(cshape)} (shape {cshape}) "
                  f"!= stream tile rank {len(tile)}")
    if not _is_contiguous_slab(producer.out_block, producer.out_shape):
        return no(f"producer blocks {pblock} of {producer.out_shape} are "
                  f"not contiguous slabs (cannot be matched through a "
                  f"reshape)")
    if not _is_contiguous_slab(tile, cshape):
        return no(f"consumer tiles {tile} of {cshape} are not contiguous "
                  f"slabs (k-dim must fit one tile)")
    for b in (i for i in producer.inputs if isinstance(i, BlockIn)):
        try:
            Pipe(tile=tuple(b.block), dtype=b.dtype, depth=2)
        except ValueError as e:
            return no(f"producer BlockIn {b.name!r} cannot be promoted to a "
                      f"ring stream: {e}")

    # rank guards: _block_offset zips index against block dims, so a
    # short/long tuple would silently drop schedule components and could
    # legalize a fusion that reads the wrong ring slot
    bad = {len(b) for b in pout} - {len(producer.out_block)}
    if bad:
        return no(f"producer out_index_map rank {sorted(bad)} != out_block "
                  f"rank {len(producer.out_block)}")
    bad = {len(b) for b in creq} - {len(tile)}
    if bad:
        return no(f"consumer stream index rank {sorted(bad)} != tile rank "
                  f"{len(tile)}")

    # producer completion runs: contiguous, equal length, each block once
    runs: List[List[Any]] = []    # [block, start, length]
    for w, blk in enumerate(pout):
        if runs and runs[-1][0] == blk:
            runs[-1][2] += 1
        else:
            runs.append([blk, w, 1])
    ordinal: Dict[Tuple[int, ...], int] = {}
    for o, (blk, _, _) in enumerate(runs):
        if blk in ordinal:
            return no(f"producer revisits output block {blk} "
                      f"non-contiguously")
        ordinal[blk] = o
    lengths = {r[2] for r in runs}
    if len(lengths) != 1:
        return no(f"producer block runs have unequal lengths "
                  f"{sorted(lengths)}")
    wpb, n_blocks = runs[0][2], len(runs)

    # map consumer requests onto producer ordinals through element offsets
    # (offsets survive the edge reshape; block tuples do not)
    p_by_off = {_block_offset(blk, producer.out_block, producer.out_shape): o
                for blk, o in ordinal.items()}
    ord_seq: List[int] = []
    prev = -1
    for g, blk in enumerate(creq):
        off = _block_offset(blk, tile, cshape)
        if off not in p_by_off:
            return no(f"consumer word {g} requests block {blk} (offset "
                      f"{off}) the producer never writes")
        o = p_by_off[off]
        if o not in (prev, prev + 1):
            return no(f"consumer request order is not contiguous "
                      f"non-decreasing (ordinal {prev}->{o} at word {g})")
        prev = o
        ord_seq.append(o)
    if prev != n_blocks - 1:
        return no(f"consumer consumes {prev + 1} of {n_blocks} produced "
                  f"blocks — the rest would never be scheduled")
    return FusionReport(
        ok=True,
        reason=(f"fusable: {n_blocks} blocks x {wpb} producer words each, "
                f"tile {tile}, consumer revisits "
                f"{len(ord_seq) / n_blocks:.1f}x"),
        wpb=wpb,
        n_blocks=n_blocks,
        ord_seq=tuple(ord_seq),
        squeeze=squeeze,
        inter_depth=1 if n_blocks == 1 else 2,
    )


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------


def _stream_overrides(program: StreamProgram, depth: int,
                      streams: int) -> Dict[str, Pipe]:
    """Re-size every stream of a program to (depth, streams), clamping
    streams to the tile's divisibility per stream (the planner's global
    choice refined per edge)."""
    return {
        st.name: dataclasses.replace(
            st.spec, depth=depth,
            streams=_clamped_streams(st.spec.tile[0], streams))
        for st in program.streams
    }


def _promote_blockin(b: BlockIn, scalar_names: Sequence[str],
                     depth: int) -> Stream:
    """Promote a producer BlockIn to a regular ring stream: the slicer
    replays the declared index map at the (dynamic) producer word."""
    def slicer(ctx, word, _b=b, _names=tuple(scalar_names)):
        scalars = [ctx.ref(n) for n in _names]
        idx = _b.index_map(word, *scalars) if _names else _b.index_map(word)
        sl = tuple(pl.ds(i * d, d) for i, d in zip(idx, _b.block))
        return ctx.ref(_b.name).at[sl]

    return Stream(b.name,
                  Pipe(tile=tuple(b.block), dtype=b.dtype, depth=depth),
                  slicer)


class _InterSlot:
    """The consumer-side endpoint of a fused edge: serves the current
    block from the in-VMEM intermediate ring (``ctx.word`` protocol)."""

    __slots__ = ("_buf", "_slot", "_squeeze")

    def __init__(self, buf, slot, squeeze):
        self._buf = buf
        self._slot = slot
        self._squeeze = squeeze

    def slot(self, word):
        del word    # the ring position tracks the block ordinal, not g
        return self._buf.at[(self._slot,) + (0,) * self._squeeze]


def _wrap_index_map(orig: Callable, lo: int, hi: int, takes_scalars: bool):
    """Adapt a node's index map to the fused kernel's scalar-prefetch
    signature: it sees only its own scalar refs (slice [lo:hi])."""
    if takes_scalars:
        return lambda g, *s: orig(g, *s[lo:hi])
    return lambda g, *s: orig(g)


@dataclasses.dataclass(frozen=True)
class _RingServe:
    """A second consumer edge served from a fused chain's intermediate
    VMEM ring: the producer at chain position ``src_pos`` feeds stage
    ``dst_pos``'s input ``edge.dst_input`` (a Stream or BlockIn) directly
    from the ring of edge ``src_pos -> src_pos+1``. ``slot_seq[w]`` is the
    ring slot holding the needed block at stage-``dst_pos`` word ``w``."""

    edge: GraphEdge
    src_pos: int
    dst_pos: int
    kind: str                     # "stream" | "block"
    slot_seq: Tuple[int, ...]
    squeeze: int


def _blockin_schedule(program: StreamProgram,
                      bi: BlockIn) -> Tuple[Tuple[int, ...], ...]:
    """A BlockIn's block schedule, one index tuple per word (static-only,
    like ``out_schedule``); raises ScheduleOpaqueError when data-dependent."""
    dummies = tuple(_OpaqueScalar()
                    for _ in range(program.num_scalar_prefetch))
    sched = []
    for g in range(program.n_words):
        try:
            idx = bi.index_map(g, *dummies)
            sched.append(tuple(int(i) for i in idx))
        except ScheduleOpaqueError:
            raise
        except Exception as e:   # noqa: BLE001 — map not int-evaluable
            raise ScheduleOpaqueError(
                f"{program.name}: BlockIn {bi.name!r} index_map is not "
                f"statically evaluable at word {g}: "
                f"{type(e).__name__}: {e}") from e
    return tuple(sched)


def _check_ring_serve(progs: Sequence[StreamProgram],
                      reps: Sequence[FusionReport], edge: GraphEdge,
                      src_pos: int, dst_pos: int):
    """Can ``edge`` be served from the fused chain's intermediate ring?

    Legal iff, at every word of the consuming stage, the block the input
    requests *is* the block the chain's demand-driven schedule most
    recently produced into the ring of edge ``src_pos -> src_pos+1`` (so
    the read is always of a live slot, no extra buffering). Returns
    ``(ok, rationale, _RingServe | None)``.
    """
    def no(reason: str):
        return False, reason, None

    P, D = progs[src_pos], progs[dst_pos]
    try:
        st = D.stream(edge.dst_input)
    except KeyError:
        st = None
    if st is not None:
        if st.gather:
            return no(f"input {edge.dst_input!r} is an irregular gather "
                      f"(data-dependent addresses)")
        kind, tile, dt = "stream", tuple(st.spec.tile), \
            jnp.dtype(st.spec.dtype)
        try:
            creq = D.stream_schedule(edge.dst_input)
        except ScheduleOpaqueError as e:
            return no(str(e))
    else:
        bi = next((i for i in D.inputs
                   if isinstance(i, BlockIn) and i.name == edge.dst_input),
                  None)
        if bi is None:
            return no(f"{D.name} has no input {edge.dst_input!r}")
        kind, tile, dt = "block", tuple(bi.block), jnp.dtype(bi.dtype)
        try:
            creq = _blockin_schedule(D, bi)
        except ScheduleOpaqueError as e:
            return no(str(e))

    pblock = tuple(P.out_block)
    squeeze = 0
    while len(pblock) - squeeze > len(tile) and pblock[squeeze] == 1:
        squeeze += 1
    if pblock[squeeze:] != tile:
        return no(f"mismatched block schedules: producer out_block {pblock} "
                  f"vs consumer block {tile}")
    if jnp.dtype(P.out_dtype) != dt:
        return no(f"dtype mismatch: producer "
                  f"{jnp.dtype(P.out_dtype).name} vs consumer {dt.name}")
    cshape = tuple(edge.reshape) if edge.reshape else tuple(P.out_shape)
    if len(cshape) != len(tile):
        return no(f"consumer operand rank {len(cshape)} != block rank "
                  f"{len(tile)}")
    if not _is_contiguous_slab(P.out_block, P.out_shape) \
            or not _is_contiguous_slab(tile, cshape):
        return no("blocks are not contiguous slabs (cannot be matched "
                  "through a reshape)")
    try:
        pout = P.out_schedule()
    except ScheduleOpaqueError as e:
        return no(f"producer schedule opaque: {e}")
    runs: List[List[Any]] = []
    for w, blk in enumerate(pout):
        if runs and runs[-1][0] == blk:
            runs[-1][1] += 1
        else:
            runs.append([blk, 1])
    p_by_off = {
        _block_offset(blk, P.out_block, P.out_shape): o
        for o, (blk, _) in enumerate(runs)}
    depth = reps[src_pos].inter_depth
    slot_seq = []
    for g, blk in enumerate(creq):
        off = _block_offset(blk, tile, cshape)
        if off not in p_by_off:
            return no(f"word {g} requests block {blk} the producer never "
                      f"writes")
        need = p_by_off[off]
        # the ring holds the block the chain most recently produced: walk
        # the demand-driven schedule from the consuming stage back to the
        # producer (block -> last word that completed it, per edge)
        w, j = g, dst_pos - 1
        while True:
            held = reps[j].ord_seq[w]
            if j == src_pos:
                break
            w = (held + 1) * reps[j].wpb - 1
            j -= 1
        if need != held:
            return no(f"input does not track the chain's live intermediate "
                      f"(word {g} needs producer block ordinal {need}, the "
                      f"ring holds {held})")
        slot_seq.append(need % depth)
    rationale = (f"served in-chain from {edge.src!r}'s intermediate VMEM "
                 f"ring (depth {depth}); the shared output never "
                 f"materializes in HBM")
    return True, rationale, _RingServe(edge, src_pos, dst_pos, kind,
                                       tuple(slot_seq), squeeze)


def _compile_chain(cnodes: Sequence[GraphNode], cedges: Sequence[GraphEdge],
                   reps: Sequence[FusionReport],
                   sizings: Sequence[Tuple[int, int]],
                   serves: Sequence[_RingServe], *, interpret: bool):
    """Lower one fused chain ``n0 -> n1 -> ... -> n{k-1}`` into a single
    ``pallas_call``.

    The grid runs the tail stage's words; each stage recursively inlines
    the words of the stage above it on first request (``fresh`` table per
    edge), every intermediate living in its own VMEM ring. ``serves`` are
    additional in-chain consumers fed straight from an intermediate ring.
    Returns ``(fn, operands)`` with ``operands`` the external inputs in
    call order as ``(node_name, input_name)`` pairs. A fused pair is the
    ``k == 2`` special case.
    """
    k = len(cnodes)
    progs = [n.program for n in cnodes]
    scalars = [[i for i in P.inputs if isinstance(i, ScalarIn)]
               for P in progs]
    excl: List[set] = [set() for _ in range(k)]
    for i, e in enumerate(cedges):
        excl[i + 1].add(e.dst_input)
    for s in serves:
        excl[s.dst_pos].add(s.edge.dst_input)
    tensors = [[i for i in P.inputs
                if not isinstance(i, ScalarIn) and i.name not in excl[pos]]
               for pos, P in enumerate(progs)]

    # Whether stage ``pos``'s word ordinal equals the grid index: true for
    # the tail, and propagates up through every edge whose consumer takes
    # exactly one producer word per block in identity order. Grid-aligned
    # stages can have their BlockIns delivered by BlockSpecs (same as the
    # tail, no ring machinery); only schedule-driven stages need rings.
    aligned = [False] * k
    aligned[k - 1] = True
    for pos in range(k - 2, -1, -1):
        r = reps[pos]
        aligned[pos] = (aligned[pos + 1] and r.wpb == 1
                        and tuple(r.ord_seq) == tuple(range(len(r.ord_seq))))

    # streams per stage: non-grid-aligned stages' BlockIns promote to
    # rings (their words are schedule-driven, not grid-driven); aligned
    # stages' BlockIns ride BlockSpecs like the tail's
    overs = [_stream_overrides(P, *sz) for P, sz in zip(progs, sizings)]
    stream_map: List[Dict[str, Stream]] = []
    promoted: List[set] = []
    for pos in range(k):
        m: Dict[str, Stream] = {}
        pr = set()
        scal_names = [s.name for s in scalars[pos]]
        for i in tensors[pos]:
            if isinstance(i, Stream):
                m[i.name] = dataclasses.replace(i, spec=overs[pos][i.name])
            elif pos < k - 1 and not aligned[pos]:
                pr.add(i.name)
                m[i.name] = _promote_blockin(i, scal_names, sizings[pos][0])
        stream_map.append(m)
        promoted.append(pr)
    rings = [{n: (GatherRingPipe if st.gather else RingPipe)(st.spec)
              for n, st in stream_map[pos].items()} for pos in range(k)]

    ord_arrs = [jnp.asarray(r.ord_seq, jnp.int32) for r in reps]
    fresh_arrs = [jnp.asarray(
        [1 if g == 0 or r.ord_seq[g] != r.ord_seq[g - 1] else 0
         for g in range(len(r.ord_seq))], jnp.int32) for r in reps]
    slot_arrs = [jnp.asarray(s.slot_seq, jnp.int32) for s in serves]
    # identity edges (one producer word per block, in order) need none of
    # the dynamic machinery: the producer word IS the consumer word, every
    # word is fresh, and the ring slot is w % depth — resolved statically
    # so the kernel skips the table reads and the (always-true) pl.when
    identity_edge = [r.wpb == 1
                     and tuple(r.ord_seq) == tuple(range(len(r.ord_seq)))
                     for r in reps]
    serve_inline = [s.slot_seq == tuple(
        w % reps[s.src_pos].inter_depth for w in range(len(s.slot_seq)))
        for s in serves]

    n_user_scal = sum(len(s) for s in scalars)
    n_scal = 2 * (k - 1) + n_user_scal + len(serves)
    scal_lo = [2 * (k - 1) + sum(len(scalars[j]) for j in range(pos))
               for pos in range(k)]
    last_lo = scal_lo[k - 1]
    last_hi = last_lo + len(scalars[-1])
    last_takes = progs[-1].num_scalar_prefetch > 0
    serves_by_dst: Dict[int, List[Tuple[int, _RingServe]]] = {}
    for si, s in enumerate(serves):
        serves_by_dst.setdefault(s.dst_pos, []).append((si, s))

    def kernel(*refs):
        it = iter(refs)
        ord_refs, fresh_refs = [], []
        for _ in range(k - 1):
            ord_refs.append(next(it))
            fresh_refs.append(next(it))
        named = [{s.name: next(it) for s in scalars[pos]}
                 for pos in range(k)]
        slot_refs = [next(it) for _ in serves]
        for pos in range(k):
            for i in tensors[pos]:
                named[pos][i.name] = next(it)
        out = next(it)
        scratch = [{s.name: next(it) for s in progs[pos].scratch}
                   for pos in range(k)]
        inters = [next(it) for _ in range(k - 1)]
        bound: List[Dict[str, Any]] = []
        for pos in range(k):
            raw = ProducerCtx(named[pos])
            bm: Dict[str, Any] = {}
            for name, st in stream_map[pos].items():
                buf, sems = next(it), next(it)
                if st.gather:
                    bm[name] = rings[pos][name].bind(
                        buf, sems,
                        lambda word, r, s=st, rw=raw: s.slicer(rw, word, r))
                else:
                    bm[name] = rings[pos][name].bind(
                        buf, sems,
                        lambda word, s=st, rw=raw: s.slicer(rw, word))
            bound.append(bm)
        ring_lists = [list(b.values()) for b in bound]

        def run_stage(pos, w):
            P = progs[pos]
            if pos > 0 and identity_edge[pos - 1]:
                # identity edge: producer word == consumer word, always
                # fresh — inline unconditionally, no table reads
                run_stage(pos - 1, w)
            elif pos > 0:
                rep = reps[pos - 1]
                b = ord_refs[pos - 1][w]

                # inlined upstream stage: block b's words on first request
                @pl.when(fresh_refs[pos - 1][w] == 1)
                def _():
                    for j in range(rep.wpb):
                        run_stage(pos - 1, b * rep.wpb + j)

            acquire(w, P.n_words, ring_lists[pos])
            body = dict(named[pos])
            for name in promoted[pos]:
                body[name] = bound[pos][name].slot(w)
            pipes_view = dict(bound[pos])
            if pos > 0:
                rep = reps[pos - 1]
                b = w if identity_edge[pos - 1] else ord_refs[pos - 1][w]
                pipes_view[cedges[pos - 1].dst_input] = _InterSlot(
                    inters[pos - 1], b % rep.inter_depth, rep.squeeze)
            for si, s in serves_by_dst.get(pos, ()):
                slot = (w % reps[s.src_pos].inter_depth
                        if serve_inline[si] else slot_refs[si][w])
                if s.kind == "stream":
                    pipes_view[s.edge.dst_input] = _InterSlot(
                        inters[s.src_pos], slot, s.squeeze)
                else:
                    body[s.edge.dst_input] = inters[s.src_pos].at[
                        (slot,) + (0,) * s.squeeze]
            if pos == k - 1:
                o = out
            else:
                o = inters[pos].at[
                    (w // reps[pos].wpb) % reps[pos].inter_depth]
            P.consumer(ProgramCtx(w, P.n_words, body, pipes_view, o,
                                  scratch[pos]))
            release(w, P.n_words, ring_lists[pos])

        run_stage(k - 1, pl.program_id(0))

    in_specs = []
    for pos in range(k):
        for i in tensors[pos]:
            if isinstance(i, Stream) or (pos < k - 1 and not aligned[pos]):
                in_specs.append(pl.BlockSpec(memory_space=pl.ANY))
            else:
                in_specs.append(pl.BlockSpec(
                    i.block,
                    _wrap_index_map(i.index_map, scal_lo[pos],
                                    scal_lo[pos] + len(scalars[pos]),
                                    progs[pos].num_scalar_prefetch > 0)))
    scratch_shapes = []
    for P in progs:
        scratch_shapes += [pltpu.VMEM(s.shape, s.dtype) for s in P.scratch]
    for i in range(k - 1):
        scratch_shapes.append(pltpu.VMEM(
            (reps[i].inter_depth, *progs[i].out_block), progs[i].out_dtype))
    for pos in range(k):
        for name in stream_map[pos]:
            scratch_shapes.extend(rings[pos][name].scratch_shapes)

    C = progs[-1]
    call = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=n_scal,
            grid=(C.n_words,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                C.out_block,
                _wrap_index_map(C.out_index_map, last_lo, last_hi,
                                last_takes)),
            scratch_shapes=scratch_shapes,
        ),
        out_shape=jax.ShapeDtypeStruct(C.out_shape, C.out_dtype),
        interpret=interpret,
    )

    tabs = []
    for i in range(k - 1):
        tabs += [ord_arrs[i], fresh_arrs[i]]

    def fn(*ops):
        return call(*tabs, *ops[:n_user_scal], *slot_arrs,
                    *ops[n_user_scal:])

    operands = ([(cnodes[pos].name, s.name)
                 for pos in range(k) for s in scalars[pos]]
                + [(cnodes[pos].name, i.name)
                   for pos in range(k) for i in tensors[pos]])
    return fn, operands


def _chain_vmem_parts(progs: Sequence[StreamProgram],
                      cedges: Sequence[GraphEdge],
                      reps: Sequence[FusionReport],
                      sizings: Sequence[Tuple[int, int]]) -> Dict[str, int]:
    """Itemized VMEM footprint of a fused chain (for the planner's split
    budget check); a pair is the length-2 case."""
    k = len(progs)
    p_rings = 0
    for pos in range(k - 1):
        over = _stream_overrides(progs[pos], *sizings[pos])
        skip = {cedges[pos - 1].dst_input} if pos > 0 else set()
        p_rings += sum(p.vmem_bytes for n, p in over.items()
                       if n not in skip)
        for b in (i for i in progs[pos].inputs if isinstance(i, BlockIn)):
            p_rings += Pipe(tile=tuple(b.block), dtype=b.dtype,
                            depth=sizings[pos][0]).vmem_bytes
    over_l = _stream_overrides(progs[-1], *sizings[-1])
    c_rings = sum(p.vmem_bytes for n, p in over_l.items()
                  if n != cedges[-1].dst_input)
    inter = sum(reps[i].inter_depth * int(np.prod(progs[i].out_block))
                * jnp.dtype(progs[i].out_dtype).itemsize
                for i in range(k - 1))
    scratch = sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
                  for P in progs for s in P.scratch)
    scratch += int(np.prod(progs[-1].out_block)) \
        * jnp.dtype(progs[-1].out_dtype).itemsize
    return {"producer-rings": int(p_rings), "intermediate-ring": int(inter),
            "consumer-rings": int(c_rings), "scratch": int(scratch)}


# ---------------------------------------------------------------------------
# compile_graph
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EdgePlan:
    """One edge's lowering decision, with the rationale that justifies it
    (fused: legality + VMEM line; staged: why fusion was rejected)."""

    edge: GraphEdge
    mode: str                     # "fused" | "staged"
    rationale: str
    hbm_bytes_saved: float = 0.0


@dataclasses.dataclass(frozen=True)
class GraphPlan:
    """The compiled graph's plan: per-edge decisions, per-node pipe sizing,
    the VMEM budget split, and the MKPipe-style estimate (whose ``skipped``
    lines surface fusion rejections in bench JSON, like ``Plan.skipped``
    does for the kernel planner)."""

    edges: Tuple[EdgePlan, ...]
    sizing: Mapping[str, Tuple[int, int]]       # node -> (depth, streams)
    budgets: Mapping[str, int]                  # node -> vmem share
    estimate: Any                               # pipeline_model.GraphEstimate

    @property
    def fused(self) -> Tuple[EdgePlan, ...]:
        return tuple(e for e in self.edges if e.mode == "fused")

    @property
    def hbm_bytes_saved(self) -> float:
        return sum(e.hbm_bytes_saved for e in self.edges)


@dataclasses.dataclass(frozen=True)
class _Unit:
    """One executable of the compiled graph: a single node's pallas_call
    or a fused pair's."""

    kind: str                     # "node" | "fused"
    out_node: str
    fn: Callable
    operands: Tuple[Tuple[str, str], ...]     # (node, input) per call arg


class CompiledGraph:
    """The compiled multi-kernel program.

    Call it with the graph's external operands in :attr:`arg_names` order
    (``"node.input"`` labels; edge-fed inputs are internal). Returns the
    sink node's output (or a tuple for multi-sink graphs). ``plan`` carries
    the per-edge fused/staged decisions, rationales, and the analytic
    estimate; ``units`` shows the pallas_call structure (one "fused" unit =
    one kernel for a whole fused chain of nodes — the acceptance check
    that an edge really lowered into a single kernel).
    """

    def __init__(self, graph: StreamGraph, policy, plan: GraphPlan,
                 units: Tuple[_Unit, ...], arg_names: Tuple[str, ...],
                 edges_in: Mapping[Tuple[str, str], GraphEdge]):
        self.graph = graph
        self.policy = policy
        self.plan = plan
        self.units = units
        self.arg_names = arg_names
        self._edges_in = dict(edges_in)
        self._sinks = graph.sinks()
        # one jit over the whole unit chain: staged intermediates stay
        # device-resident between pallas_calls and repeat calls replay the
        # compiled program (parity with the jitted repro.ops entrypoints)
        self._jit = jax.jit(self._run)

    def __call__(self, *args):
        if len(args) != len(self.arg_names):
            raise TypeError(
                f"{self.graph.name}: expected {len(self.arg_names)} operands "
                f"{list(self.arg_names)}, got {len(args)}")
        return self._jit(*args)

    def _run(self, *args):
        vals = dict(zip(self.arg_names, args))
        outs: Dict[str, Any] = {}
        for unit in self.units:
            ops = []
            for node, name in unit.operands:
                e = self._edges_in.get((node, name))
                if e is not None:
                    v = outs[e.src]
                    ops.append(v.reshape(e.reshape) if e.reshape else v)
                else:
                    ops.append(vals[f"{node}.{name}"])
            outs[unit.out_node] = unit.fn(*ops)
        res = tuple(outs[s] for s in self._sinks)
        return res[0] if len(res) == 1 else res


def _resolve_node(graph: StreamGraph, node: GraphNode, policy,
                  budget: int, mesh: MeshSpec = SINGLE_DEVICE,
                  shards: int = 1) -> Tuple[Workload, int, int]:
    """Per-node (depth, streams) under the node's split VMEM budget:
    explicit policy ints pass through; "auto"/"measured" resolve through
    the planner (the graph-keyed *measured* path resolves above
    compile_graph, in ``registry.run_graph``, and arrives here as ints).
    ``shards`` localizes the node's word schedule to the mesh's per-shard
    view before planning (local shapes, not global); ``mesh`` keys the
    plan so topologies never share cache entries."""
    w = localize_workload(node_workload(node), shards)
    depth, streams = policy.depth, policy.streams
    if isinstance(depth, str) or isinstance(streams, str):
        try:
            plan = planner.planned_pipe(
                f"graph:{graph.name}/{node.name}", w, _node_tile(node),
                _node_dtype(node), policy.hw,
                stream_options=tuple(policy.stream_options),
                vmem_budget_bytes=budget, mesh=mesh)
            d_plan, s_plan = plan.pipe.depth, plan.pipe.streams
        except PlanError:
            # the split budget is too tight for the latency-hiding depth:
            # degrade to the shallowest ring that fits (double-buffer, else
            # synchronous) — the fused-pair VMEM check downstream is where
            # a genuinely infeasible fusion turns into PlanError/staging
            tile, dt = _node_tile(node), _node_dtype(node)
            d_plan = 2 if Pipe(tile=tile, dtype=dt,
                               depth=2).vmem_bytes <= budget else 1
            s_plan = 1
        depth = d_plan if isinstance(depth, str) else int(depth)
        streams = s_plan if isinstance(streams, str) else int(streams)
    depth, streams = int(depth), int(streams)
    # a ring deeper than the node's word count can never prefetch anything
    # real — the extra slots are dead VMEM charged against the split budget
    # (and dead scratch carried through every grid step)
    if w.n_words > 0:
        depth = max(1, min(depth, w.n_words))
    if policy.mode == "baseline":
        depth = 1
    return w, depth, streams


def _traced_compile_graph(fn):
    """Wrap the graph compile in an obs span carrying the per-edge
    fused/staged decision and rationale (no-op when tracing is off)."""
    @functools.wraps(fn)
    def wrapper(graph, **kw):
        with obs.span("compile_graph", graph=graph.name,
                      nodes=len(graph.nodes)) as sp:
            compiled = fn(graph, **kw)
            n_fused = sum(1 for e in compiled.plan.edges
                          if e.mode == "fused")
            sp.set(
                hbm_bytes_saved=compiled.plan.hbm_bytes_saved,
                fused_edges=n_fused,
                staged_edges=len(compiled.plan.edges) - n_fused,
                edges={f"{e.edge.src}->{e.edge.dst}":
                       {"mode": e.mode, "rationale": e.rationale}
                       for e in compiled.plan.edges})
            return compiled
    return wrapper


@_traced_compile_graph
def compile_graph(graph: StreamGraph, *, policy=None,
                  vmem_budget_bytes: int = _VMEM_BUDGET_BYTES,
                  prefer: Optional[str] = None,
                  sharding=None) -> CompiledGraph:
    """Compile a :class:`StreamGraph`, choosing fused/staged per edge.

    Per edge: "auto" fuses when the static legality analysis passes *and*
    the fused pair fits the planner's split VMEM budget, else stages with
    the rejection line as the edge rationale. ``prefer`` (or
    ``edge.prefer``) = "fused" turns an infeasible fusion into a
    :class:`~repro.core.planner.PlanError` carrying those lines; "staged"
    pins the HBM handoff (the A/B baseline for BENCH_graph.json).

    ``sharding`` makes the compile mesh-aware: pass a
    :class:`~repro.runtime.sharding.ShardingContext` (or a bare
    :class:`~repro.core.meshspec.MeshSpec`), or leave ``None`` to pick up
    the ambient context. Each node's workload is localized to the mesh's
    per-shard word schedule before planning (local shapes, not global) and
    every node plan is cache-keyed by the mesh topology, so a graph
    compiled under a mesh never reuses single-device plans or vice versa.

    Fusion scope: fused edges compose into linear chains — each node may
    have one fused in-edge and one fused out-edge, so a whole decode
    layer lowers into a single kernel. Every prospective fusion is checked
    against the *sum* of the chain members' split VMEM budgets. A
    fused-away producer may feed additional consumers only when each of
    them is served from the chain's intermediate VMEM ring (same chain,
    downstream, block schedule tracking the ring's live slot — see
    ``_check_ring_serve``); otherwise the fusion unwinds to staged with
    the multi-consumer rationale.
    """
    from repro.core.program import current_policy
    policy = policy or current_policy()
    sh = sharding if sharding is not None else policy.mesh
    mesh, shards = resolve_sharding(sh)
    order = graph.topo_order()
    # epilogues fold into the consumer once, up front: everything below
    # (planning, legality, lowering, operand naming) sees the effective
    # program, so epilogues ride every lowering path with no special cases
    nodes = {n.name: (dataclasses.replace(n, program=n.effective_program,
                                          epilogue=None)
                      if n.epilogue else n)
             for n in graph.nodes}
    budgets = planner.split_graph_budget(
        [n.name for n in order], vmem_budget_bytes)

    resolved = {n.name: _resolve_node(graph, nodes[n.name], policy,
                                      budgets[n.name], mesh=mesh,
                                      shards=shards)
                for n in order}

    pos = {n.name: i for i, n in enumerate(order)}

    def _is_stream(dst: str, input_name: str) -> bool:
        try:
            nodes[dst].program.stream(input_name)
            return True
        except KeyError:
            return False

    stream_edges = [e for e in graph.edges
                    if _is_stream(e.dst, e.dst_input)]
    block_edges = [e for e in graph.edges if not _is_stream(e.dst,
                                                            e.dst_input)]

    # -- pass A: greedy chain building over stream edges --------------------
    edge_plans: Dict[GraphEdge, EdgePlan] = {}
    reports: Dict[GraphEdge, FusionReport] = {}
    fused_in: Dict[str, GraphEdge] = {}       # consumer -> fused in-edge
    fused_next: Dict[str, GraphEdge] = {}     # producer -> fused out-edge
    for e in sorted(stream_edges, key=lambda e: (pos[e.dst], pos[e.src])):
        pref = prefer or e.prefer
        P, C = nodes[e.src].program, nodes[e.dst].program
        if pref == "staged":
            edge_plans[e] = EdgePlan(e, "staged", "staged by request")
            continue
        rep = check_fusion(P, C, e)
        reason = None
        if not rep.ok:
            reason = rep.reason
        elif e.src in fused_next:
            reason = (f"producer {e.src!r} already fuses into "
                      f"{fused_next[e.src].dst!r} (one fused out-edge "
                      f"per node)")
        elif e.dst in fused_in:
            reason = (f"consumer {e.dst!r} already has a fused in-edge "
                      f"from {fused_in[e.dst].src!r} (one fused in-edge "
                      f"per node)")
        else:
            # the prospective chain this fusion would create: everything
            # already fused through either endpoint, plus this edge — the
            # whole chain cohabits one kernel, so it is checked against
            # the sum of its members' split budgets
            cnames = [e.src]
            while cnames[0] in fused_in:
                cnames.insert(0, fused_in[cnames[0]].src)
            cnames.append(e.dst)
            while cnames[-1] in fused_next:
                cnames.append(fused_next[cnames[-1]].dst)
            chain_edges = [e if (a, b) == (e.src, e.dst) else fused_in[b]
                           for a, b in zip(cnames, cnames[1:])]
            parts = _chain_vmem_parts(
                [nodes[n].program for n in cnames], chain_edges,
                [rep if ce is e else reports[ce] for ce in chain_edges],
                [resolved[n][1:] for n in cnames])
            fits, line = planner.check_fused_vmem(
                e.label, parts, sum(budgets[n] for n in cnames))
            if fits:
                st = C.stream(e.dst_input)
                saved = (float(np.prod(P.out_shape))
                         * jnp.dtype(P.out_dtype).itemsize
                         + float(C.n_words) * st.spec.word_bytes)
                edge_plans[e] = EdgePlan(e, "fused",
                                         f"{rep.reason}; {line}", saved)
                reports[e] = rep
                fused_in[e.dst] = e
                fused_next[e.src] = e
                continue
            reason = line
        edge_plans[e] = EdgePlan(e, "staged", reason)

    # -- pass B: multi-consumer resolution (ring-serve or unwind) -----------
    def _chains() -> Dict[str, Tuple[Tuple[str, ...], int]]:
        res: Dict[str, Tuple[Tuple[str, ...], int]] = {}
        for tail in (n for n in fused_in if n not in fused_next):
            cn = [tail]
            while cn[0] in fused_in:
                cn.insert(0, fused_in[cn[0]].src)
            for i, n in enumerate(cn):
                res[n] = (tuple(cn), i)
        return res

    serves: Dict[GraphEdge, Tuple[_RingServe, str]] = {}
    while True:
        serves.clear()
        in_chain = _chains()
        conflict = None
        for src, fe in list(fused_next.items()):
            for e2 in graph.edges:
                if e2.src != src or e2 == fe:
                    continue
                pref2 = prefer or e2.prefer
                if pref2 == "staged":
                    conflict = (fe, f"producer {src!r} output has multiple "
                                    f"consumers and edge {e2.label} is "
                                    f"staged by request, so it must "
                                    f"materialize in HBM")
                    break
                sinfo, dinfo = in_chain.get(src), in_chain.get(e2.dst)
                if sinfo and dinfo and sinfo[0] == dinfo[0] \
                        and dinfo[1] > sinfo[1]:
                    cn = sinfo[0]
                    ok, why, serve = _check_ring_serve(
                        [nodes[n].program for n in cn],
                        [reports[fused_in[n]] for n in cn[1:]],
                        e2, sinfo[1], dinfo[1])
                else:
                    ok, why, serve = False, (
                        f"consumer {e2.dst!r} is not downstream of "
                        f"{src!r} in the fused chain"), None
                if ok:
                    serves[e2] = (serve, why)
                else:
                    conflict = (fe, f"producer {src!r} also feeds "
                                    f"{e2.dst}.{e2.dst_input}, which "
                                    f"cannot be served from the chain's "
                                    f"intermediate VMEM ring: {why}")
                    break
            if conflict:
                break
        if conflict is None:
            break
        fe, why = conflict
        edge_plans[fe] = EdgePlan(fe, "staged", why)
        del fused_in[fe.dst]
        del fused_next[fe.src]
        reports.pop(fe, None)

    for e2, (serve, why) in serves.items():
        D = nodes[e2.dst].program
        if serve.kind == "stream":
            load = float(D.n_words) * D.stream(e2.dst_input).spec.word_bytes
        else:
            bi = next(i for i in D.inputs
                      if isinstance(i, BlockIn) and i.name == e2.dst_input)
            load = float(D.n_words) * float(np.prod(bi.block)) \
                * jnp.dtype(bi.dtype).itemsize
        edge_plans[e2] = EdgePlan(e2, "fused", why, load)

    for e2 in block_edges:
        if e2 in edge_plans:
            continue
        if (prefer or e2.prefer) == "staged":
            edge_plans[e2] = EdgePlan(e2, "staged", "staged by request")
            continue
        edge_plans[e2] = EdgePlan(e2, "staged", (
            f"consumer input {e2.dst}.{e2.dst_input} is a block-delivered "
            f"operand (BlockIn), not a pipe stream; its producer is not "
            f"fused away, so the intermediate materializes in HBM and "
            f"Pallas delivers its blocks by grid index"))

    # a demanded fusion that ended staged (anywhere in planning) is a
    # PlanError carrying every per-edge rejection line, like Plan.skipped
    rejected = [
        f"{e.label}: {edge_plans[e].rationale}"
        for e in sorted(graph.edges, key=lambda e: (pos[e.dst], pos[e.src]))
        if edge_plans[e].mode == "staged"
        and (prefer or e.prefer) == "fused"]
    if rejected:
        first = next(e for e in graph.edges
                     if edge_plans[e].mode == "staged"
                     and (prefer or e.prefer) == "fused")
        raise PlanError(resolved[first.dst][0],
                        budgets[first.src] + budgets[first.dst], rejected)

    # -- build executable units (fused chains collapse into one kernel) ----
    # only staged edges feed a materialized operand; a fused edge's
    # intermediate never exists outside the kernel
    edges_in = {(e.dst, e.dst_input): e for e in graph.edges
                if edge_plans[e].mode == "staged"}
    chain_map = _chains()
    units: List[_Unit] = []
    for n in order:
        if n.name in fused_next:
            continue    # emitted inside its chain's fused unit
        if n.name in fused_in:
            cn, _ = chain_map[n.name]
            chain_serves = sorted(
                (s for s, _ in serves.values() if s.edge.dst in cn),
                key=lambda s: (s.dst_pos, s.src_pos))
            fn, operands = _compile_chain(
                [nodes[m] for m in cn], [fused_in[m] for m in cn[1:]],
                [reports[fused_in[m]] for m in cn[1:]],
                [resolved[m][1:] for m in cn], chain_serves,
                interpret=policy.interpret)
            units.append(_Unit("fused", n.name, fn, tuple(operands)))
        else:
            _, d, s = resolved[n.name]
            prog = nodes[n.name].program
            fn = compile_program(
                prog, interpret=policy.interpret,
                pipe_overrides=_stream_overrides(prog, d, s))
            units.append(_Unit(
                "node", n.name, fn,
                tuple((n.name, i.name) for i in prog.inputs)))

    fed_any = {(e.dst, e.dst_input) for e in graph.edges}
    arg_names = tuple(
        f"{n.name}.{i.name}" for n in order
        for i in nodes[n.name].program.inputs
        if (n.name, i.name) not in fed_any)

    # -- analytic estimate (MKPipe stage overlap + per-edge traffic) --------
    # stages follow the *execution* order of the units (a fused chain's
    # members are consecutive even when the declaration topo order
    # interleaves an unrelated node), so estimate_graph's
    # consecutive-stage fusion model lines up with plan.edges; edges not
    # between consecutive stages (ring-served residuals, skip edges)
    # surface through ``extra_edges``
    stage_order: List[GraphNode] = []
    for u in units:
        if u.kind == "fused":
            cn, _ = chain_map[u.out_node]
            stage_order.extend(nodes[m] for m in cn)
        else:
            stage_order.append(nodes[u.out_node])
    stages = []
    for n in stage_order:
        w, d, s = resolved[n.name]
        tile = _node_tile(n)
        pipe = Pipe(tile=tile, dtype=_node_dtype(n), depth=max(d, 1),
                    streams=_clamped_streams(tile[0], s))
        e = fused_in.get(n.name)
        in_edges = [ed for ed in graph.edges if ed.dst == n.name]
        rationale = ""
        if e is not None:
            rationale = edge_plans[e].rationale
        elif in_edges:
            rationale = "; ".join(
                edge_plans[ed].rationale for ed in in_edges)
        prev_name = stages[-1].name if stages else None
        fused_with_prev = e is not None and e.src == prev_name
        saved_load = saved_store = 0.0
        if fused_with_prev:
            P = nodes[e.src].program
            st = nodes[e.dst].program.stream(e.dst_input)
            saved_store = float(np.prod(P.out_shape)) \
                * jnp.dtype(P.out_dtype).itemsize
            saved_load = float(nodes[e.dst].program.n_words) \
                * st.spec.word_bytes
        stages.append(GraphStage(
            name=n.name, workload=w, pipe=pipe,
            fused_with_prev=fused_with_prev,
            saved_load_bytes=saved_load, saved_store_bytes=saved_store,
            rationale=rationale))
    adjacent = {(a.name, b.name)
                for a, b in zip(stage_order, stage_order[1:])}
    extra = tuple(
        EdgeEstimate(edge=e.label, mode=edge_plans[e].mode,
                     hbm_bytes_saved=edge_plans[e].hbm_bytes_saved
                     if edge_plans[e].mode == "fused" else 0.0,
                     rationale=edge_plans[e].rationale)
        for e in graph.edges if (e.src, e.dst) not in adjacent)
    estimate = estimate_graph(tuple(stages), policy.hw, extra_edges=extra)

    plan = GraphPlan(
        edges=tuple(edge_plans[e] for e in graph.edges),
        sizing={k: (d, s) for k, (_, d, s) in resolved.items()},
        budgets=budgets,
        estimate=estimate,
    )
    return CompiledGraph(graph, policy, plan, tuple(units), arg_names,
                         edges_in)
