"""Public op wrapper + cost model for ff_gather."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.emitter import cdiv
from repro.core.pipe import Pipe, vmem_budget_ok
from repro.core.pipeline_model import Workload
from repro.core.program import PipePolicy, make_entrypoint
from repro.kernels.ff_gather.kernel import _ROWS, build_program, gather_ff
from repro.kernels.ff_gather.ref import gather_ref
from repro.kernels.registry import KernelCost, register_kernel


def gather_cost(n: int, cols: int, *, depth: int = 4,
                dtype=jnp.float32) -> KernelCost:
    itemsize = jnp.dtype(dtype).itemsize
    return KernelCost(
        flops=0.0,
        hbm_bytes=float(2 * n * cols * itemsize + n * 4),
        vmem_bytes=depth * _ROWS * cols * itemsize,
    )


def gather_workload(n: int, cols: int, *,
                    dtype=jnp.float32) -> Tuple[Workload, Tuple[int, int]]:
    """One word per 8-row bundle of irregular single-row loads — the
    paper's IR access pattern: latency per word, hidden by (depth-1) x rows
    outstanding row DMAs. The planner's ``streams`` choice is modeled as
    concurrent 8-row producers; emission realizes it by widening the bundle
    to ``8 * streams`` rows per word (budget re-checked in ``_apply``)."""
    itemsize = jnp.dtype(dtype).itemsize
    w = Workload(
        n_words=max(cdiv(n, _ROWS), 1),
        word_bytes=float(_ROWS * cols * itemsize),
        flops_per_word=0.0,
        regular=False,
        store_bytes_per_word=float(_ROWS * cols * itemsize),
    )
    return w, (_ROWS, cols)


def _apply(table, idx, *, policy: PipePolicy):
    """rows = table[idx];
    policy.mode="ff"|"autotune"(measured plan)|"baseline"(depth=1)|"ref".

    The planned (or explicit) ``streams`` value widens the per-word row
    bundle to ``8 * streams`` concurrent row DMAs — the irregular-stream
    analogue of the paper's multi-producer design — so it is no longer
    silently dropped. There is no separate tile knob: the row bundle *is*
    the tile, so the autotuner searches (depth, streams) only.
    """
    if policy.mode == "ref":
        return gather_ref(table, idx)
    n = idx.shape[0]
    cols = table.shape[1]

    def _run(depth, streams):
        # The planner models 8-row words ("streams" = concurrent 8-row
        # producers); emission merges them into one 8*streams-row bundle.
        # Clamp to the bundles the index stream can actually fill (a wider
        # word than n rows is pure padding traffic), then re-check the
        # *emitted* ring against the VMEM budget and shed streams if the
        # widened word would blow it.
        streams = max(1, min(streams, n // _ROWS))
        while streams > 1 and not vmem_budget_ok(
                [Pipe(tile=(_ROWS * streams, cols), dtype=table.dtype,
                      depth=depth)]):
            streams //= 2
        rows_per_word = _ROWS * streams
        pad = (-n) % rows_per_word
        idx_p = jnp.pad(idx.astype(jnp.int32), (0, pad))
        return gather_ff(table, idx_p, depth=depth, streams=streams,
                         interpret=policy.interpret)

    w, tile = gather_workload(n, cols, dtype=table.dtype)
    # Clamp the tuner's search space to the streams the index stream can
    # fill, so candidates are distinct *effective* configs and the
    # persisted plan names the streams value that actually executes
    # (_run's clamp then only sheds on the VMEM re-check).
    max_streams = max(1, n // _ROWS)
    so = tuple(sorted({min(int(s), max_streams)
                       for s in policy.stream_options}))
    pol = policy if so == tuple(policy.stream_options) \
        else policy.replace(stream_options=so)
    choice = autotune.resolve_call(
        "ff_gather", pol, workload=w, tile=tile, dtype=table.dtype,
        workload_fn=lambda tk: gather_workload(n, cols, dtype=table.dtype),
        runner=None if autotune.has_tracers(table, idx) else
        lambda tk, dep, st: lambda: _run(dep, st),
        site={"rows": table.shape[0], "cols": cols, "n": n},
        site_dynamic=("rows", "n"))
    out = _run(choice.depth, choice.streams)
    return out[:n]


gather = make_entrypoint("ff_gather", _apply)


def _make_inputs(key):
    tab = jax.random.normal(key, (96, 128), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (52,), 0, 96)
    return (tab, idx), {}


def _sweep_inputs(key, site):
    # rebuild concrete operands at a recorded call-site shape (plan sweep)
    rows, cols, n = int(site["rows"]), int(site["cols"]), int(site["n"])
    dt = jnp.dtype(site.get("dtype", "float32"))
    tab = jax.random.normal(key, (rows, cols), dt)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, rows)
    return (tab, idx), {}


def _smoke_program(*, depth: int = 4, streams: int = 1, tile=None):
    # the smoke shape point of _make_inputs (52 rows padded to the bundle);
    # no tile knob: the 8*streams row bundle is the tile
    del tile
    n = -(-52 // (_ROWS * streams)) * (_ROWS * streams)
    return build_program(n, 128, dtype=jnp.float32, depth=depth,
                         streams=streams)


register_kernel(
    name="ff_gather",
    alias="gather",
    op=gather,
    ref=gather_ref,
    cost=gather_cost,
    workload=gather_workload,
    program=_smoke_program,
    make_inputs=_make_inputs,
    bench_kwargs={"n": 1 << 20, "cols": 512, "dtype": jnp.float32},
    regular=False,
    tol=0.0,
    doc="irregular row gather (embedding / MoE dispatch)",
    shard_dims=(None, 0),        # table replicated, index rows split
    shard_out_dim=0,
    sweep_inputs=_sweep_inputs,
)
