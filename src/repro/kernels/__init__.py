"""repro.kernels — Pallas TPU kernels implementing the feed-forward (DAE)
design model, one subpackage per hot spot:

  ff_matmul            DAE blocked matmul (regular streams)
  ff_attention         flash attention prefill, GQA, KV ring pipes
  ff_decode_attention  flash-decode vs. long KV caches
  ff_chunk_scan        gated linear-attention scan (Mamba2 / RWKV6)
  ff_gather            irregular row gather (embedding / MoE dispatch)

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit
wrapper + exact tile-schedule cost model + registration), ref.py (pure-jnp
oracle). Kernels validate under interpret=True on CPU; real-TPU lowering is
the target.

The StreamProgram/registry contract — what a *new* kernel must provide
----------------------------------------------------------------------

1. **Declare the kernel as a StreamProgram**
   (:mod:`repro.core.program`), never hand-rolled DMA loops. In kernel.py,
   a ``build_program(shapes..., depth, streams) -> StreamProgram`` that
   states:

   * producer stages — one :class:`~repro.core.program.Stream` edge per
     streamed operand, carrying its :class:`~repro.core.pipe.Pipe` spec
     and a ``slicer(ctx, word)`` address stream (``gather=True`` +
     ``slicer(ctx, word, row)`` for irregular per-row gathers). Slicers
     may depend only on the word index and scalar-prefetched inputs —
     the feed-forward restriction, enforced structurally;
   * passive operands — :class:`~repro.core.program.BlockIn` blocked
     inputs and :class:`~repro.core.program.ScalarIn` prefetched scalars;
   * the consumer compute body — ``consumer(ctx)`` reading landed words
     via ``ctx.word(name)`` and carrying state in declared
     :class:`~repro.core.program.ScratchSpec` VMEM.

   :func:`~repro.core.program.compile_program` lowers the graph through
   the shared ring-pipe emitter (:mod:`repro.core.emitter`) into one
   ``pallas_call`` — ring scratch, binding, and the acquire/consume/
   release word schedule are owned there. ``depth == 1`` automatically
   degenerates to the synchronous copy-then-compute baseline.

2. **Expose a policy-driven op and register it**
   (:mod:`repro.kernels.registry`). In ops.py, implement
   ``_apply(*arrays, policy: PipePolicy, **statics)`` (ref-mode dispatch,
   padding, plan resolution via :func:`repro.core.autotune.resolve_call`,
   which covers both the analytic planner and the measured tuner), wrap
   it with
   :func:`repro.core.program.make_entrypoint` (which adds the ``policy=``
   argument, the session ``repro.policy`` context, and the deprecated
   keyword shims), and call
   :func:`~repro.kernels.registry.register_kernel` with the op, a short
   ``alias`` (becomes ``repro.ops.<alias>``), the pure-jnp oracle, the
   KernelCost model, a Workload builder (shapes -> (core.Workload, tile)),
   the ``program`` builder at the smoke shape point, tiny smoke inputs,
   and a benchmark shape point. The benchmark harness
   (benchmarks/kernel_bench.py, ``benchmarks/run.py --smoke``),
   ``repro.ops``, and the registry tests enumerate the registry — a new
   kernel is its subpackage plus the one ``register_kernel`` call, then
   add the ops module path to ``registry._BUILTIN``.

3. **Support planner auto-sizing and measured autotuning.** ``_apply``
   must resolve the policy through
   :func:`repro.core.autotune.resolve_call` with the op's Workload: the
   roofline model picks (depth, streams) for ``"auto"`` per call-site
   shape against the policy's hardware model (cached on (op, shape,
   dtype, hw)), and ``mode="autotune"`` / ``"measured"`` sizing searches
   the declared ``tile_options`` x depth x streams space empirically via
   a call-site ``runner`` closure, persisting tuned plans to the on-disk
   plan cache. Kernels with tunable tiles declare ``tile_options`` in
   their registry entry and accept the corresponding kwargs in ``_apply``
   and ``program(tile=...)``.
"""

from repro.core.emitter import cdiv, pad_to
from repro.kernels.registry import (
    KernelCost,
    KernelSpec,
    all_kernels,
    get_kernel,
    kernel_names,
    register_kernel,
    run_smoke,
)

__all__ = [
    "KernelCost",
    "KernelSpec",
    "all_kernels",
    "cdiv",
    "get_kernel",
    "kernel_names",
    "pad_to",
    "register_kernel",
    "run_smoke",
]
