"""Per-arch smoke tests (assignment deliverable f): a REDUCED config of the
same family runs one forward + one train step on CPU, asserting output
shapes and no NaNs; full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config, smoke_config, \
    shape_applicable
from repro.launch import steps as steps_lib
from repro.models import build_model
from repro.optim import adamw

KEY = jax.random.key(0)


def make_batch(cfg, b=2, s=16):
    batch = {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.n_frames, cfg.d_model), cfg.cdtype)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            KEY, (b, cfg.n_patches, cfg.d_model), cfg.cdtype)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = smoke_config(arch_id)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)

    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch_id

    opt_cfg = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
    train_step = jax.jit(steps_lib.make_train_step(
        model, optimizer=cfg.optimizer,
        opt_cfg=None if cfg.optimizer == "adafactor" else opt_cfg))
    opt_init, _ = steps_lib.opt_init_and_update(cfg.optimizer, opt_cfg)
    opt_state = opt_init(params)
    new_params, new_opt, m = train_step(params, opt_state, batch)
    assert bool(jnp.isfinite(m["loss"])), arch_id
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved, arch_id


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_prefill_shapes(arch_id):
    cfg = smoke_config(arch_id)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg, b=2, s=24)
    batch.pop("labels")
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), arch_id
    assert cache is not None


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch_id):
    """Every applicable (arch x shape) cell must produce well-formed
    ShapeDtypeStruct inputs (the dry-run contract)."""
    cfg = get_config(arch_id)
    model = build_model(cfg)
    for shape in SHAPES.values():
        ok, _ = shape_applicable(cfg, shape)
        if not ok:
            continue
        specs = model.input_specs(shape)
        axes = model.input_axes(shape)
        assert set(axes) == set(specs)
        for name, sds in specs.items():
            assert isinstance(sds, jax.ShapeDtypeStruct)
            assert len(axes[name]) == len(sds.shape), (arch_id, shape.name,
                                                       name)
        if shape.kind == "decode":
            cache, cache_axes = model.cache_spec(shape)
            flat_c = jax.tree.leaves(cache)
            assert flat_c, (arch_id, shape.name)
            tupleish = lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x)
            flat_a = jax.tree.leaves(cache_axes, is_leaf=tupleish)
            assert len(flat_a) == len(flat_c)


def test_assigned_dims_exact():
    """Assignment sheet dims must match the configs bit-for-bit."""
    rows = {
        "zamba2_2p7b": (54, 2560, 32, 32, 10240, 32000),
        "starcoder2_15b": (40, 6144, 48, 4, 24576, 49152),
        "qwen2_72b": (80, 8192, 64, 8, 29568, 152064),
        "llama3_2_1b": (16, 2048, 32, 8, 8192, 128256),
        "qwen1_5_0p5b": (24, 1024, 16, 16, 2816, 151936),
        "grok1_314b": (64, 6144, 48, 8, 32768, 131072),
        "deepseek_v2_lite_16b": (27, 2048, 16, 16, 1408, 102400),
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
        "internvl2_1b": (24, 896, 14, 2, 4864, 151655),
        "rwkv6_7b": (32, 4096, 64, 64, 14336, 65536),
    }
    for arch_id, (L, d, h, kvh, ff, v) in rows.items():
        cfg = get_config(arch_id)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab)
        assert got == (L, d, h, kvh, ff, v), (arch_id, got)
    assert get_config("zamba2_2p7b").ssm_state == 64
    assert get_config("grok1_314b").n_experts == 8
    assert get_config("grok1_314b").top_k == 2
    ds = get_config("deepseek_v2_lite_16b")
    assert ds.kv_lora_rank == 512 and ds.n_experts == 64 and ds.top_k == 6
    assert ds.n_shared_experts == 2


def test_shape_set_exact():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_500k_applicability():
    """long_500k runs only for sub-quadratic archs (zamba2, rwkv6)."""
    runs = [a for a in ARCH_IDS
            if shape_applicable(get_config(a), SHAPES["long_500k"])[0]]
    assert sorted(runs) == ["rwkv6_7b", "zamba2_2p7b"]


def test_hillclimb_knobs_preserve_semantics():
    """loss_chunk / moe_local_dispatch / xla_tiled scan are pure perf knobs:
    outputs must match the baseline implementations."""
    import jax.numpy as jnp
    key = jax.random.key(11)
    # chunked-vocab CE
    cfg = smoke_config("llama3_2_1b").replace(remat="none")
    m = build_model(cfg)
    p = m.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab),
             "labels": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    l1, _ = m.loss(p, batch)
    l2, _ = build_model(cfg.replace(loss_chunk=4)).loss(p, batch)
    assert abs(float(l1) - float(l2)) < 1e-5
    # local MoE dispatch (1 shard == global path)
    cfg = smoke_config("grok1_314b").replace(remat="none")
    m = build_model(cfg)
    p = m.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab),
             "labels": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    l1, _ = m.loss(p, batch)
    l2, _ = build_model(cfg.replace(moe_local_dispatch=True)).loss(p, batch)
    assert abs(float(l1) - float(l2)) < 1e-5
    # tiled scan in a full model
    cfg = smoke_config("rwkv6_7b").replace(remat="none")
    m = build_model(cfg)
    p = m.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab),
             "labels": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    l1, _ = m.loss(p, batch)
    l2, _ = build_model(cfg.replace(scan_impl="xla_tiled")).loss(p, batch)
    assert abs(float(l1) - float(l2)) < 1e-4
