"""Plan-service tests (repro.plans + the core profiling/plandb hooks).

Covers: PlanDB merge semantics (disjoint union, newer-wins, bitwise
namespace preservation, format-mismatch rejection, corrupt-file handling),
shape-bucketing determinism, traffic recording (autotune vs planner origin,
double-count suppression), the fingerprint registry, the per-(op, workload)
fallback-warning dedup, and record -> sweep -> fresh-process PlanDB lookup
end to end on a real registry kernel.
"""

import json
import os
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import Workload, autotune, profiling
from repro.core.autotune import (
    resolve_call,
    tuned_cache_clear,
    tuning_config,
)
from repro.core.program import PipePolicy
from repro.plans import (
    PlanDB,
    PlanDBError,
    TrafficProfile,
    bucket_site,
    bucket_value,
    content_hash,
    plan_namespace,
    record_traffic,
    register_fingerprint_resolver,
    sweep_profile,
)
from repro.plans import plandb as plandb_lib
from repro.plans import registry as plan_registry

W = Workload(n_words=512, word_bytes=128 * 128 * 4.0,
             flops_per_word=2.0 * 128 * 128 * 128, regular=True)
W2 = Workload(n_words=260, word_bytes=64 * 64 * 4.0,
              flops_per_word=0.0, regular=False)
TILE = (128, 128)

REC_A = {"op": "ff_synth", "depth": 3, "streams": 2, "tile_kwargs": {},
         "measured_s": 1e-3}
REC_B = {"op": "ff_synth", "depth": 5, "streams": 1, "tile_kwargs": {},
         "measured_s": 2e-3}


@pytest.fixture
def plan_env(tmp_path, monkeypatch):
    """Cold caches + env isolated from the host running the tests."""
    monkeypatch.setenv("REPRO_PLAN_CACHE", os.path.join(tmp_path, "host.json"))
    monkeypatch.delenv("REPRO_PLAN_DB", raising=False)
    monkeypatch.delenv("REPRO_PLAN_NAMESPACE", raising=False)
    tuned_cache_clear()
    plandb_lib.clear_cache()
    autotune.plan_stats_clear()
    yield tmp_path
    tuned_cache_clear()
    plandb_lib.clear_cache()


# ---------------------------------------------------------------------------
# PlanDB merge semantics
# ---------------------------------------------------------------------------

def test_merge_disjoint_keys_is_union():
    a, b = PlanDB(), PlanDB()
    a.put("cpu.cpu", "k1", REC_A, tuned_at=1.0)
    b.put("cpu.cpu", "k2", REC_B, tuned_at=2.0)
    report = a.merge(b)
    assert report.added == 1 and not report.conflicts
    assert set(a.records("cpu.cpu")) == {"k1", "k2"}


def test_merge_same_key_newer_wins_and_is_reported():
    a, b = PlanDB(), PlanDB()
    a.put("cpu.cpu", "k", REC_A, tuned_at=1.0)
    b.put("cpu.cpu", "k", REC_B, tuned_at=2.0)
    report = a.merge(b)
    assert report.replaced == 1 and len(report.conflicts) == 1
    assert a.get("cpu.cpu", "k")["depth"] == REC_B["depth"]
    # and the mirror merge keeps the same (newer) record: order-independent
    c = PlanDB()
    c.put("cpu.cpu", "k", REC_B, tuned_at=2.0)
    d = PlanDB()
    d.put("cpu.cpu", "k", REC_A, tuned_at=1.0)
    rep2 = c.merge(d)
    assert rep2.kept == 1 and c.get("cpu.cpu", "k")["depth"] == REC_B["depth"]


def test_merge_identical_content_keeps_ours_and_advances_timestamp():
    a, b = PlanDB(), PlanDB()
    a.put("cpu.cpu", "k", REC_A, tuned_at=1.0)
    b.put("cpu.cpu", "k", REC_A, tuned_at=9.0)
    report = a.merge(b)
    assert report.kept == 1 and not report.conflicts
    assert a.get("cpu.cpu", "k")["tuned_at"] == 9.0


def test_merge_preserves_foreign_namespaces_bitwise(plan_env):
    """The acceptance criterion: merging DBs tuned on different hardware
    fingerprints never rewrites a byte of either namespace."""
    a, b = PlanDB(), PlanDB()
    a.put("cpu.cpu", "k1", REC_A, tuned_at=1.0)
    b.put("tpu.tpu-v5-lite", "k1", REC_B, tuned_at=2.0)  # same key, other ns
    before_a = json.dumps(a.records("cpu.cpu"), sort_keys=True)
    before_b = json.dumps(b.records("tpu.tpu-v5-lite"), sort_keys=True)
    report = a.merge(b)
    assert not report.conflicts
    assert json.dumps(a.records("cpu.cpu"), sort_keys=True) == before_a
    assert json.dumps(a.records("tpu.tpu-v5-lite"),
                      sort_keys=True) == before_b
    # and a save/load round trip keeps both
    path = os.path.join(plan_env, "merged.json")
    a.save(path)
    again = PlanDB.load(path)
    assert json.dumps(again.records("tpu.tpu-v5-lite"),
                      sort_keys=True) == before_b


def test_merge_rejects_plan_format_mismatch():
    a = PlanDB()
    b = PlanDB(plan_format=-1)
    with pytest.raises(PlanDBError, match="plan format"):
        a.merge(b)


def test_load_rejects_format_mismatch_and_corruption(plan_env):
    path = os.path.join(plan_env, "db.json")
    db = PlanDB()
    db.put("cpu.cpu", "k", REC_A)
    db.save(path)
    payload = json.load(open(path))
    payload["format"] = 99
    json.dump(payload, open(path, "w"))
    with pytest.raises(PlanDBError, match="format"):
        PlanDB.load(path)
    with open(path, "w") as f:
        f.write("{not json")
    with pytest.raises(PlanDBError, match="corrupt"):
        PlanDB.load(path)
    with pytest.raises(FileNotFoundError):
        PlanDB.load(os.path.join(plan_env, "missing.json"))


def test_serving_lookup_degrades_on_corrupt_db_with_one_warning(plan_env):
    path = os.path.join(plan_env, "bad.json")
    with open(path, "w") as f:
        f.write("{not json")
    with pytest.warns(RuntimeWarning, match="unusable PlanDB"):
        assert plandb_lib.lookup("k", path=path) is None
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # second lookup: no re-warn
        assert plandb_lib.lookup("k", path=path) is None


def test_lookup_falls_back_to_default_namespace(plan_env):
    path = os.path.join(plan_env, "db.json")
    db = PlanDB()
    db.put(plan_registry.DEFAULT_NAMESPACE, "k", REC_A)
    db.save(path)
    rec = plandb_lib.lookup("k", path=path, namespace="no.such.hw")
    assert rec is not None and rec["depth"] == REC_A["depth"]


def test_content_hash_ignores_volatile_fields():
    assert content_hash(dict(REC_A, tuned_at=1.0, content_hash="x")) \
        == content_hash(dict(REC_A, tuned_at=2.0))
    assert content_hash(REC_A) != content_hash(REC_B)


# ---------------------------------------------------------------------------
# Shape bucketing: deterministic, idempotent, dynamic-keys-only
# ---------------------------------------------------------------------------

def test_bucket_value_rounds_to_pow2_and_is_idempotent():
    assert [bucket_value(v) for v in (1, 2, 3, 12, 16, 17)] \
        == [1, 2, 4, 16, 16, 32]
    assert bucket_value(0) == 0 and bucket_value(-3) == -3
    for v in range(1, 200):
        assert bucket_value(bucket_value(v)) == bucket_value(v)


def test_bucket_site_touches_only_dynamic_int_keys():
    site = {"m": 12, "k": 7, "block": (8, 8), "causal": True}
    out = bucket_site(site, dynamic=("m", "causal", "block"))
    assert out == {"m": 16, "k": 7, "block": (8, 8), "causal": True}
    assert bucket_site(None, dynamic=("m",)) is None


def test_profile_bucketing_and_roundtrip(plan_env):
    prof = TrafficProfile()
    pol = PipePolicy(mode="autotune", interpret=True)

    def see(n):
        profiling.set_recorder(prof.observe)
        try:
            profiling.emit_call(
                op="ff_synth", policy=pol,
                workload=Workload(n_words=n, word_bytes=4.0,
                                  flops_per_word=0.0, regular=False),
                tile=TILE, dtype="float32",
                mesh=autotune.resolve_mesh(None),
                site={"n": n, "cols": 8}, site_dynamic=("n",))
        finally:
            profiling.set_recorder(None)

    for n in (12, 13, 16, 40):
        see(n)
    # 12, 13, 16 share the pow2-16 bucket; 40 lands in 64
    assert len(prof) == 2 and prof.total_count == 4
    (b16,) = [e for e in prof.entries.values() if e.site["n"] == 16]
    assert b16.count == 3 and len(b16.variants) == 3   # exact variants kept
    path = os.path.join(plan_env, "prof.json")
    prof.save(path)
    again = TrafficProfile.load(path)
    assert again.to_payload() == prof.to_payload()     # deterministic bytes
    again.merge(prof)
    assert again.total_count == 8 and len(again) == 2


def test_profile_rejects_format_mismatch():
    with pytest.raises(ValueError, match="format"):
        TrafficProfile.from_payload({"format": 99, "entries": {}})


# ---------------------------------------------------------------------------
# Traffic recording through the real resolution hooks
# ---------------------------------------------------------------------------

def _synthetic_runner(tile_kwargs, depth, streams):
    return lambda: jnp.float32(abs(depth - 3) + abs(streams - 2))


def test_record_traffic_captures_resolve_call_once(plan_env, monkeypatch):
    monkeypatch.setattr(autotune, "measure",
                        lambda fn, **kw: 1e-3 * (1.0 + float(fn())))
    with record_traffic() as prof:
        resolve_call("ff_synth", PipePolicy(mode="autotune"), workload=W,
                     tile=TILE, dtype=jnp.float32,
                     workload_fn=lambda tk: (W, TILE),
                     runner=_synthetic_runner,
                     site={"m": 128}, site_dynamic=("m",))
    # exactly one autotune-origin bucket: the internal planner funnel was
    # suppressed, not double-counted
    (entry,) = prof.entries.values()
    assert entry.origin == "autotune" and entry.count == 1
    assert entry.site == {"m": 128}
    assert not profiling.recording()          # recorder restored on exit


def test_record_traffic_sees_direct_planner_calls(plan_env):
    from repro.core import planner
    with record_traffic() as prof:
        planner.resolve_policy("ff_direct", PipePolicy(), workload=W,
                               tile=TILE, dtype=jnp.float32)
    (entry,) = prof.entries.values()
    assert entry.origin == "planner" and entry.op == "ff_direct"


def test_recorder_exceptions_disable_recording_not_serving(plan_env):
    profiling.set_recorder(lambda cs: 1 / 0)
    try:
        with pytest.warns(RuntimeWarning, match="recorder raised"):
            choice = resolve_call("ff_synth", PipePolicy(), workload=W,
                                  tile=TILE, dtype=jnp.float32)
        assert choice.source == "analytic"    # resolution survived
        assert not profiling.recording()      # recorder dropped
    finally:
        profiling.set_recorder(None)


# ---------------------------------------------------------------------------
# Fallback-warning dedup: once per (op, workload), not once per op
# ---------------------------------------------------------------------------

def test_unmeasurable_warning_dedup_per_workload(plan_env):
    autotune._warned_fallback_ops.clear()
    pol = PipePolicy(mode="autotune")

    def unmeasurable(w):
        return resolve_call("ff_synth", pol, workload=w, tile=TILE,
                            dtype=jnp.float32, runner=None)

    with pytest.warns(RuntimeWarning, match="not measurable"):
        unmeasurable(W)
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # same workload: silent
        unmeasurable(W)
    with pytest.warns(RuntimeWarning, match="not measurable"):
        unmeasurable(W2)                      # new workload: warns again


# ---------------------------------------------------------------------------
# Fingerprint registry
# ---------------------------------------------------------------------------

def test_namespace_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_NAMESPACE", "ops.override")
    assert plan_namespace() == "ops.override"


def test_generic_resolver_and_custom_resolver_priority(monkeypatch):
    monkeypatch.delenv("REPRO_PLAN_NAMESPACE", raising=False)
    fp = {"platform": "TPU", "device_kind": "TPU v5 Lite",
          "device_count": 8}
    assert plan_namespace(fp) == "tpu.tpu-v5-lite"   # sanitized generic

    @register_fingerprint_resolver("test-pod")
    def _pod(f):
        return "tpu-pod.v5e" if f["device_count"] >= 8 else None

    try:
        assert plan_namespace(fp) == "tpu-pod.v5e"   # beats the default tier
        assert plan_namespace({"platform": "cpu", "device_kind": "cpu",
                               "device_count": 1}) == "cpu.cpu"  # abstains
    finally:
        del plan_registry._RESOLVERS["test-pod"]


def test_plan_db_path_precedence(plan_env, monkeypatch):
    assert autotune.plan_db_path() is None
    monkeypatch.setenv("REPRO_PLAN_DB", "/tmp/env.json")
    assert autotune.plan_db_path() == "/tmp/env.json"
    with tuning_config(plan_db="/tmp/cfg.json"):
        assert autotune.plan_db_path() == "/tmp/cfg.json"
    assert autotune.plan_db_path() == "/tmp/env.json"


# ---------------------------------------------------------------------------
# End to end: record -> sweep -> fresh-process PlanDB hit (real kernel)
# ---------------------------------------------------------------------------

def test_record_sweep_plandb_roundtrip(plan_env):
    from repro.kernels.ff_gather import gather

    # depth/streams pinned: the sweep measures exactly one candidate, so
    # this stays a unit test, not a benchmark
    pol = PipePolicy(mode="autotune", depth=2, streams=1, interpret=True)
    tab = jax.random.normal(jax.random.key(0), (64, 8), jnp.float32)
    idx = jax.random.randint(jax.random.key(1), (16,), 0, 64)

    host = os.path.join(plan_env, "host.json")
    with record_traffic() as prof, tuning_config(cache_path=host):
        gather(tab, idx, policy=pol)
    assert len(prof) == 1

    # namespace defaults to this process's fingerprint namespace — the
    # same one the replay lookups resolve to
    sweep = sweep_profile(prof,
                          scratch_cache=os.path.join(plan_env, "scratch.json"),
                          warmup=0, iters=1)
    assert sweep.tuned_buckets == 1 and sweep.keys_written == 1, sweep.skipped
    dbp = os.path.join(plan_env, "db.json")
    sweep.db.save(dbp)

    # simulated fresh process: all in-memory state cleared, empty host
    # cache, only the swept DB in the chain
    tuned_cache_clear()
    plandb_lib.clear_cache()
    autotune.plan_stats_clear()
    cold = os.path.join(plan_env, "cold.json")
    with tuning_config(cache_path=cold, plan_db=dbp), warnings.catch_warnings():
        warnings.simplefilter("error")        # a re-measure warning = failure
        gather(tab, idx, policy=pol)
    stats = autotune.plan_stats_snapshot()
    assert stats.get("plandb") == 1
    assert stats["hit_rate"] == 1.0
    assert not os.path.exists(cold)           # nothing re-measured/persisted


def test_sweep_skips_unsweepable_buckets_with_reasons(plan_env):
    prof = TrafficProfile()
    pol = PipePolicy(mode="autotune", interpret=True)
    profiling.set_recorder(prof.observe)
    try:
        # a graph-style op that is not a registered graph
        profiling.emit_call(op="graph:synth", policy=pol, workload=W,
                            tile=TILE, dtype="float32",
                            mesh=autotune.resolve_mesh(None))
    finally:
        profiling.set_recorder(None)
    sweep = sweep_profile(prof, namespace="cpu.test", warmup=0, iters=1)
    assert sweep.tuned_buckets == 0
    assert len(sweep.skipped) == 1 and "not a registered graph" \
        in sweep.skipped[0]
