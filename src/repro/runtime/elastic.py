"""Elastic scaling: reshard a checkpoint onto a different mesh.

Recovery path when a pod (or slice) is lost: rebuild the mesh from the
surviving device set, recompute shardings from the same logical rules, and
restore the last checkpoint with the new placements. Since checkpoints are
host-numpy and shardings are derived (not stored), any mesh whose axes
divide the array dims works — scale down 2 pods -> 1, or up 1 -> 2.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import restore
from repro.runtime import sharding as shlib


def remesh_restore(ckpt_dir: str, state_like: Any, axes_tree: Any,
                   mesh: Mesh, *, step: Optional[int] = None,
                   overrides=None) -> Tuple[Any, int]:
    """Restore ``state_like`` onto ``mesh`` using logical ``axes_tree``."""
    with shlib.use_sharding(mesh, overrides=overrides) as ctx:
        shardings = jax.tree.map(
            lambda ax: shlib.sharding_for(ax, ctx), axes_tree,
            is_leaf=lambda x: isinstance(x, tuple) and
            all(a is None or isinstance(a, str) for a in x))
        state, got_step, _ = restore(ckpt_dir, state_like, step=step,
                                     shardings=shardings)
    return state, got_step


def survivable_mesh(devices: Sequence[jax.Device], model_axis: int,
                    pod_axis: int = 1) -> Mesh:
    """Largest (pod, data, model) mesh the surviving devices support.

    Keeps the model axis intact (TP groups must be complete) and shrinks
    data parallelism — the standard elastic-DP policy.
    """
    n = len(devices)
    if n % model_axis != 0:
        raise ValueError(
            f"{n} surviving devices cannot host model_axis={model_axis}")
    data = n // (model_axis * pod_axis)
    if data < 1:
        raise ValueError("not enough devices for one data shard")
    shape = (pod_axis, data, model_axis) if pod_axis > 1 else (data, model_axis)
    names = ("pod", "data", "model") if pod_axis > 1 else ("data", "model")
    devs = np.asarray(devices[:pod_axis * data * model_axis]).reshape(shape)
    return Mesh(devs, names)
