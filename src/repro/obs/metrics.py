"""Metrics registry: counters, gauges, and exponential-bucket histograms.

One process-global registry replaces the ad-hoc stat scatter
(``autotune.plan_stats()``, ``elastic.last_remesh()``,
``Supervisor.save_count``): every subsystem increments named families
here and :func:`metrics_snapshot` / :func:`render_text` read them all
through one surface.

Two cost tiers, by design:

* **structural** counters (plan resolutions, checkpoint saves, remesh
  drops) are always on — they sit on cold control paths and existing
  APIs like ``plan_stats()`` are required to work without opt-in;
* **hot-path** instrumentation (serve per-step latency observes, span
  timing) is guarded by the caller behind ``obs.enabled()`` so the
  default serve loop pays one bool check and nothing else.

Histograms use exponential buckets at 16 per octave (factor
``2**0.0625``) from 100 ns up. Quantiles follow ``np.percentile``'s
linear-interpolation rank semantics (interpolating between the bucketed
values at the two neighbouring integer ranks), so the only error left is
bucket quantization: ~±2.2% worst case — well inside the 10%
live-vs-post-hoc tolerance the serve telemetry gate checks.

Families are named ``subsystem_noun[_unit]`` (``plan_resolutions_total``,
``serve_token_latency_seconds``) with optional labels; the text exporter
renders Prometheus-style lines (``name{k="v"} value``).
"""

from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, List, Optional, Tuple

_LOCK = threading.Lock()

# exponential histogram geometry: 16 buckets per octave starting at 100ns
_HIST_LO = 1e-7
_HIST_FACTOR = 2.0 ** 0.0625
_LOG_FACTOR = math.log(_HIST_FACTOR)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonic count. ``inc`` only; reset via :func:`metrics_clear`."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1) -> None:
        with _LOCK:
            self.value += n


class Gauge:
    """Point-in-time value (``set``), with ``inc`` for up/down counts."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1) -> None:
        with _LOCK:
            self.value += n


class Histogram:
    """Exponential-bucket histogram over positive values (latencies,
    bytes). Bucket ``i`` covers ``[_HIST_LO * f**i, _HIST_LO * f**(i+1))``;
    values below ``_HIST_LO`` land in bucket 0. Tracks count/sum/min/max
    so quantile endpoints are exact."""

    __slots__ = ("buckets", "count", "sum", "min", "max")

    def __init__(self):
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        if v <= _HIST_LO:
            i = 0
        else:
            i = int(math.log(v / _HIST_LO) / _LOG_FACTOR) + 1
        with _LOCK:
            self.buckets[i] = self.buckets.get(i, 0) + 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def _value_at(self, k: int) -> float:
        """Bucket-quantized value of the k-th (0-based) ordered sample:
        the geometric midpoint of its bucket, clamped to [min, max]."""
        seen = 0
        for i in sorted(self.buckets):
            n = self.buckets[i]
            if seen + n > k:
                lo = _HIST_LO * (_HIST_FACTOR ** max(i - 1, 0))
                hi = _HIST_LO * (_HIST_FACTOR ** i)
                return min(max((lo * hi) ** 0.5, self.min), self.max)
            seen += n
        return self.max

    def quantile(self, q: float) -> float:
        """Approximate q-quantile with ``np.percentile``'s linear rank
        semantics: rank ``q * (count - 1)``, interpolating between the
        (bucket-quantized) values at the two neighbouring integer ranks —
        so live quantiles track a post-hoc percentile of the same samples
        to within bucket resolution even on stretched tails."""
        if self.count == 0:
            return float("nan")
        q = min(max(q, 0.0), 1.0)
        rank = q * (self.count - 1)
        k = int(rank)
        frac = rank - k
        v = self._value_at(k)
        if frac > 0.0:
            v += (self._value_at(k + 1) - v) * frac
        return min(max(v, self.min), self.max)

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0, "sum": 0.0}
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.sum / self.count,
            "min": self.min,
            "max": self.max,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
        }


class _Family:
    __slots__ = ("name", "kind", "help", "children")

    def __init__(self, name: str, kind: str, help: str):
        self.name = name
        self.kind = kind
        self.help = help
        self.children: Dict[LabelKey, object] = {}

    def child(self, labels: Dict[str, str]):
        key = _label_key(labels)
        c = self.children.get(key)
        if c is None:
            with _LOCK:
                c = self.children.get(key)
                if c is None:
                    c = {"counter": Counter, "gauge": Gauge,
                         "histogram": Histogram}[self.kind]()
                    self.children[key] = c
        return c


_REG: Dict[str, _Family] = {}


def _family(name: str, kind: str, help: str) -> _Family:
    fam = _REG.get(name)
    if fam is None:
        with _LOCK:
            fam = _REG.get(name)
            if fam is None:
                fam = _Family(name, kind, help)
                _REG[name] = fam
    if fam.kind != kind:
        raise ValueError(
            f"metric {name!r} already registered as {fam.kind}, not {kind}")
    return fam


def counter(name: str, help: str = "", **labels) -> Counter:
    """The label-bound counter child for ``name``; created on first use."""
    return _family(name, "counter", help).child(labels)


def gauge(name: str, help: str = "", **labels) -> Gauge:
    return _family(name, "gauge", help).child(labels)


def histogram(name: str, help: str = "", **labels) -> Histogram:
    return _family(name, "histogram", help).child(labels)


def metrics_clear(prefix: Optional[str] = None) -> None:
    """Drop all families, or only those whose name starts with ``prefix``
    (e.g. ``metrics_clear("plan_")`` between bench phases)."""
    with _LOCK:
        if prefix is None:
            _REG.clear()
        else:
            for name in [n for n in _REG if n.startswith(prefix)]:
                del _REG[name]


def _labels_dict(key: LabelKey) -> Dict[str, str]:
    return dict(key)


def metrics_snapshot() -> Dict[str, object]:
    """Everything the registry holds, as plain JSON-ready dicts:
    ``{"counters": {...}, "gauges": {...}, "histograms": {...}}`` keyed by
    ``name`` or ``name{k=v,...}`` when labelled."""
    out: Dict[str, Dict[str, object]] = {
        "counters": {}, "gauges": {}, "histograms": {}}
    for fam in sorted(_REG.values(), key=lambda f: f.name):
        for key, child in sorted(fam.children.items()):
            label = fam.name
            if key:
                label += "{" + ",".join(f"{k}={v}" for k, v in key) + "}"
            if fam.kind == "counter":
                out["counters"][label] = child.value
            elif fam.kind == "gauge":
                out["gauges"][label] = child.value
            else:
                out["histograms"][label] = child.summary()
    return out


def _fmt_labels(key: LabelKey, extra: Iterable[Tuple[str, str]] = ()) -> str:
    pairs = list(key) + list(extra)
    if not pairs:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in pairs) + "}"


def render_text() -> str:
    """Prometheus-style exposition text for every family: ``# HELP`` /
    ``# TYPE`` headers, one sample line per child (histograms render
    ``_count``/``_sum`` plus ``quantile=`` samples)."""
    lines: List[str] = []
    for fam in sorted(_REG.values(), key=lambda f: f.name):
        if fam.help:
            lines.append(f"# HELP {fam.name} {fam.help}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for key, child in sorted(fam.children.items()):
            if fam.kind in ("counter", "gauge"):
                lines.append(f"{fam.name}{_fmt_labels(key)} {child.value:.17g}")
            else:
                lines.append(f"{fam.name}_count{_fmt_labels(key)} {child.count}")
                lines.append(f"{fam.name}_sum{_fmt_labels(key)} {child.sum:.17g}")
                if child.count:
                    for q in (0.5, 0.9, 0.99):
                        v = child.quantile(q)
                        lines.append(
                            f"{fam.name}{_fmt_labels(key, [('quantile', f'{q:g}')])}"
                            f" {v:.17g}")
    return "\n".join(lines) + ("\n" if lines else "")


def parse_text(text: str) -> Dict[str, float]:
    """Parse exposition text back to ``{sample_name: value}`` (labels
    folded into the key verbatim) — the round-trip check used by tests."""
    out: Dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        out[name] = float(value)
    return out
