"""End-to-end behaviour tests for the framework: a tiny LM trains to lower
loss through the full driver stack (data pipe -> jit train step -> optimizer
-> checkpoints), and the serve driver generates greedily."""

import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_train_driver_loss_decreases(tmp_path):
    from repro.launch import train as train_mod
    state = train_mod.main([
        "--arch", "llama3_2_1b", "--smoke", "--steps", "60", "--batch", "4",
        "--seq", "64", "--lr", "3e-3", "--ckpt-dir", str(tmp_path / "ck"),
        "--ckpt-every", "30", "--log-every", "50"])
    assert state is not None
    # checkpoint written at final step
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path / "ck")) == 60


def test_train_driver_learns(tmp_path, capsys):
    """Loss at the end must be below loss at the start (synthetic Markov
    stream is learnable)."""
    from repro.launch import train as train_mod
    train_mod.main([
        "--arch", "qwen1_5_0p5b", "--smoke", "--steps", "200", "--batch", "4",
        "--seq", "64", "--lr", "1e-2", "--ckpt-dir", str(tmp_path / "ck"),
        "--ckpt-every", "500", "--log-every", "10"])
    out = capsys.readouterr().out
    losses = [float(line.split("loss=")[1].split()[0])
              for line in out.splitlines() if "loss=" in line]
    assert len(losses) >= 5
    assert np.mean(losses[-2:]) < np.mean(losses[:2]) - 0.2, losses


def test_serve_driver_generates():
    """serve driver end to end: both schedulers replay the trace, every
    request emits, paged decode matches the dense path bitwise."""
    from repro.launch import serve as serve_mod
    out = serve_mod.main(["--arch", "qwen1_5_0p5b", "--smoke", "--requests",
                          "3", "--prompt-len", "12", "--max-new", "4",
                          "--slots", "2", "--page", "8", "--impl", "xla"])
    assert out["token_count_parity"]
    assert out["bitwise_identical"]
    assert out["paged"]["tokens"] >= 3      # every request emitted
    assert out["lockstep"]["tokens"] == out["paged"]["tokens"]


def test_grad_accum_equivalence():
    """accum_steps=2 must match accum_steps=1 on the same global batch
    (up to fp32 accumulation order)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import smoke_config
    from repro.launch import steps as steps_lib
    from repro.models import build_model
    from repro.optim import adamw

    cfg = smoke_config("llama3_2_1b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt_cfg = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.key(2), (4, 32), 0, cfg.vocab),
    }
    s1 = jax.jit(steps_lib.make_train_step(model, opt_cfg=opt_cfg))
    s2 = jax.jit(steps_lib.make_train_step(model, opt_cfg=opt_cfg,
                                           accum_steps=2))
    p1, _, m1 = s1(params, adamw.init(params), batch)
    p2, _, m2 = s2(params, adamw.init(params), batch)
    # microbatch mean-of-means == full mean here (equal microbatch sizes)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3, d
