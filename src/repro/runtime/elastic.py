"""Elastic scaling: reshard a checkpoint onto a different mesh, plan-aware.

Recovery path when a pod (or slice) is lost: rebuild the mesh from the
surviving device set, recompute shardings from the same logical rules, and
restore the last checkpoint with the new placements. Since checkpoints are
host-numpy and shardings are derived (not stored), any mesh whose axes
divide the array dims works — scale down 2 pods -> 1, or up 1 -> 2.

The restore is **plan-aware** (this is what makes a remesh safe for the
stream/plan stack):

* the surviving topology is resolved to a
  :class:`~repro.core.meshspec.MeshSpec` and every planner / autotune
  cache entry keyed by a mesh that no longer exists is dropped
  (``planner.invalidate_mesh_plans`` / ``autotune.invalidate_mesh``) — a
  2-pod->1-pod recovery can never serve a plan sized for the lost
  topology;
* the release PlanDB (``REPRO_PLAN_DB`` / ``tuning_config(plan_db=)``),
  whose keys embed the mesh token, is pre-warmed so call sites under the
  *new* topology hit swept plans before falling back to measurement or
  the analytic planner;
* :func:`last_remesh` exposes a :class:`RemeshReport` (surviving mesh
  token, dropped-entry counts, PlanDB coverage) so the chaos harness can
  assert the invalidation actually happened.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro import obs
from repro.checkpoint import restore
from repro.core import autotune, planner
from repro.core.meshspec import MeshSpec
from repro.runtime import sharding as shlib


@dataclasses.dataclass(frozen=True)
class RemeshReport:
    """What one :func:`remesh_restore` did to the plan stack."""

    mesh: MeshSpec
    step: int
    planner_dropped: int
    autotune_dropped: int
    plan_db: Optional[str] = None
    plan_db_records: int = 0     # swept records covering the new namespace


_LAST_REMESH: "list[RemeshReport]" = []


def last_remesh() -> Optional[RemeshReport]:
    """The most recent remesh's report (chaos-harness introspection)."""
    return _LAST_REMESH[-1] if _LAST_REMESH else None


def remesh_restore(ckpt_dir: str, state_like: Any, axes_tree: Any,
                   mesh: Mesh, *, step: Optional[int] = None,
                   overrides=None, invalidate_plans: bool = True,
                   plan_db: Optional[str] = None) -> Tuple[Any, int]:
    """Restore ``state_like`` onto ``mesh`` using logical ``axes_tree``.

    ``invalidate_plans`` (default on) drops planner/autotune entries keyed
    by any topology other than the surviving ``mesh`` (single-device plans
    survive: they are topology-independent) and pre-warms the PlanDB
    (``plan_db`` > ``$REPRO_PLAN_DB``/``tuning_config``) for the new
    topology's lookups. Pass ``invalidate_plans=False`` only when the
    caller manages plan caches itself (e.g. a fresh process whose caches
    are empty anyway).
    """
    spec = MeshSpec.from_mesh(mesh)
    with obs.span("remesh_restore", mesh=spec.token,
                  devices=spec.device_count) as sp:
        planner_dropped = autotune_dropped = 0
        db = plan_db if plan_db is not None else autotune.plan_db_path()
        db_records = 0
        if invalidate_plans:
            planner_dropped = planner.invalidate_mesh_plans(spec)
            autotune_dropped = autotune.invalidate_mesh(spec)
        if db:
            from repro.plans import plandb as plandb_lib
            pre = plandb_lib.prewarm(db)
            db_records = int(pre["records_in_namespace"]
                             + pre["records_in_default"])
        with shlib.use_sharding(mesh, overrides=overrides) as ctx:
            shardings = jax.tree.map(
                lambda ax: shlib.sharding_for(ax, ctx), axes_tree,
                is_leaf=lambda x: isinstance(x, tuple) and
                all(a is None or isinstance(a, str) for a in x))
            state, got_step, _ = restore(ckpt_dir, state_like, step=step,
                                         shardings=shardings)
        sp.set(step=got_step, planner_dropped=planner_dropped,
               autotune_dropped=autotune_dropped, plan_db_records=db_records)
    _LAST_REMESH[:] = [RemeshReport(
        mesh=spec, step=got_step, planner_dropped=planner_dropped,
        autotune_dropped=autotune_dropped, plan_db=db,
        plan_db_records=db_records)]
    obs.counter("remesh_total", "elastic remesh_restore calls").inc()
    obs.counter("remesh_plans_dropped_total",
                "stale plan entries dropped by remesh", layer="planner"
                ).inc(planner_dropped)
    obs.counter("remesh_plans_dropped_total",
                "stale plan entries dropped by remesh", layer="autotune"
                ).inc(autotune_dropped)
    return state, got_step


def survivable_mesh(devices: Sequence[jax.Device], model_axis: int,
                    pod_axis: int = 1) -> Mesh:
    """Largest (pod, data, model) mesh the surviving devices support.

    Keeps the model axis intact (TP groups must be complete) and shrinks
    data parallelism — the standard elastic-DP policy. The surviving
    device count must divide evenly into ``pod_axis * model_axis`` groups
    (a partial TP group or ragged pod cannot host the model); non-divisible
    counts raise ``ValueError`` instead of silently dropping devices.
    """
    n = len(devices)
    if n % model_axis != 0:
        raise ValueError(
            f"{n} surviving devices cannot host model_axis={model_axis}")
    if n % (model_axis * pod_axis) != 0:
        raise ValueError(
            f"{n} surviving devices do not divide into pod_axis={pod_axis} "
            f"x model_axis={model_axis} groups")
    data = n // (model_axis * pod_axis)
    if data < 1:
        raise ValueError("not enough devices for one data shard")
    shape = (pod_axis, data, model_axis) if pod_axis > 1 else (data, model_axis)
    names = ("pod", "data", "model") if pod_axis > 1 else ("data", "model")
    devs = np.asarray(devices[:pod_axis * data * model_axis]).reshape(shape)
    return Mesh(devs, names)


def replace_host(ckpt_dir: str, state_like: Any, axes_tree: Any,
                 surviving_devices: Sequence[jax.Device], *,
                 model_axis: int, pod_axis: int = 1,
                 step: Optional[int] = None, overrides=None,
                 plan_db: Optional[str] = None,
                 ) -> Tuple[Any, int, Mesh]:
    """The straggler watchdog's "replace" action, end to end: build the
    largest mesh the surviving devices support and plan-aware-restore the
    newest checkpoint onto it. Returns ``(state, step, mesh)`` — the
    caller re-installs ``use_sharding(mesh)`` and re-jits its steps."""
    mesh = survivable_mesh(surviving_devices, model_axis, pod_axis=pod_axis)
    state, got_step = remesh_restore(
        ckpt_dir, state_like, axes_tree, mesh, step=step,
        overrides=overrides, plan_db=plan_db)
    return state, got_step, mesh
