"""Feed-forward irregular gather: rows = table[idx].

The paper's *irregular memory access* case (Table 3, M-AI10-IR; MoE
dispatch / embedding lookup in our models). The index stream is scalar-
prefetched (TPU analogue of the FPGA burst-coalesced LSU's request buffer),
and each pipe word is a bundle of ``rows_per_word`` single-row DMAs issued
``depth-1`` words ahead — memory-level parallelism for a pattern the MXU
pipeline cannot prefetch on its own. The per-row bundle is emitted through
the shared :class:`~repro.core.emitter.GatherRingPipe`: the rows *are* the
stream decomposition (depth-1 words x rows outstanding requests).

A true-MLCD variant of this op (gather from a table the same kernel is
scattering into) is *rejected* by core.check_no_mlcd and deliberately has no
kernel here — the paper's legality restriction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.emitter import GatherRingPipe, acquire, release
from repro.core.pipe import Pipe

_ROWS = 8   # rows per pipe word (one f32 sublane granule)


def _kernel(idx_ref, tab_hbm, o_ref, buf, sems, *, ring: GatherRingPipe):
    g = pl.program_id(0)
    n_words = pl.num_programs(0)

    def row_slice(word, r):
        row = idx_ref[word * _ROWS + r]
        return tab_hbm.at[pl.ds(row, 1), :]

    pipe = ring.bind(buf, sems, row_slice)
    acquire(g, n_words, [pipe])
    o_ref[...] = pipe.slot(g)[...]
    release(g, n_words, [pipe])


@functools.partial(jax.jit, static_argnames=("depth", "interpret"))
def gather_ff(table: jnp.ndarray, idx: jnp.ndarray, *, depth: int = 4,
              interpret: bool = True) -> jnp.ndarray:
    """table: [R, C]; idx: [n] int32 with n % 8 == 0. Returns [n, C]."""
    r, c = table.shape
    n = idx.shape[0]
    assert n % _ROWS == 0, n
    ring = GatherRingPipe(Pipe(tile=(_ROWS, c), dtype=table.dtype,
                               depth=depth))
    kernel = functools.partial(_kernel, ring=ring)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n // _ROWS,),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((_ROWS, c), lambda g, idx: (g, 0)),
            scratch_shapes=[*ring.scratch_shapes],
        ),
        out_shape=jax.ShapeDtypeStruct((n, c), table.dtype),
        interpret=interpret,
    )(idx, table)
