"""Unified telemetry: tracing spans, live metrics, bandwidth accounting.

Three layers, one import (``from repro import obs``):

* :mod:`repro.obs.tracing` — ``obs.span("compile_graph", ...)`` context
  managers with thread-local nesting and a JSONL sink
  (``REPRO_TRACE=/path`` or ``tuning_config(trace_path=...)``);
* :mod:`repro.obs.metrics` — process-global counters / gauges /
  exponential-bucket histograms behind ``obs.metrics_snapshot()`` and a
  Prometheus-style ``obs.render_text()`` exporter;
* :mod:`repro.obs.bandwidth` — achieved-GB/s and roofline-utilization
  joins of modeled bytes with measured wall time, per kernel and per
  graph edge (``benchmarks/run.py --telemetry``).

stdlib-only on purpose: ``repro.core`` imports ``repro.obs``, never the
reverse, so instrumentation can sit in the lowest layers. Everything is
zero-cost when disabled — ``obs.span`` returns a shared no-op behind one
``obs.enabled()`` check, and only cold structural counters are always on.
"""

from repro.obs.tracing import (   # noqa: F401
    NOOP_SPAN,
    Span,
    TRACE_ENV,
    current_span,
    disable,
    drain,
    enable,
    enabled,
    restore,
    span,
    trace_path,
)
from repro.obs.metrics import (   # noqa: F401
    Counter,
    Gauge,
    Histogram,
    counter,
    gauge,
    histogram,
    metrics_clear,
    metrics_snapshot,
    parse_text,
    render_text,
)
from repro.obs.bandwidth import (   # noqa: F401
    graph_utilization,
    kernel_utilization,
)

__all__ = [
    "NOOP_SPAN", "Span", "TRACE_ENV", "current_span", "disable", "drain",
    "enable", "enabled", "restore", "span", "trace_path",
    "Counter", "Gauge", "Histogram", "counter", "gauge", "histogram",
    "metrics_clear", "metrics_snapshot", "parse_text", "render_text",
    "graph_utilization", "kernel_utilization",
]
