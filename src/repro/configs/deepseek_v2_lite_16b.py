"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + 2 shared / 64 routed
top-6 experts.  [arXiv:2405.04434; hf]  27L d_model=2048 16H (kv=16)
d_ff=1408 (per-expert) vocab=102400."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="deepseek_v2_lite_16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab=256,
    n_experts=8,
    n_shared_experts=1,
    top_k=2,
    moe_d_ff=64,
    kv_lora_rank=32,
    qk_rope_dim=8,
    qk_nope_dim=16,
    v_head_dim=16,
    capacity_factor=8.0,   # smoke: no token drops (decode-consistency tests)
    compute_dtype="float32",
)
