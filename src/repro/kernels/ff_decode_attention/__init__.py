from repro.kernels.ff_decode_attention.ops import (
    decode_attention,
    decode_attention_cost,
)
from repro.kernels.ff_decode_attention.ref import decode_attention_ref

__all__ = ["decode_attention", "decode_attention_cost", "decode_attention_ref"]
