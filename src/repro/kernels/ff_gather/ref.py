"""Pure-jnp oracle for ff_gather."""

import jax.numpy as jnp


def gather_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, idx, axis=0)
