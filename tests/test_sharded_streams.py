"""Mesh-aware streams: sharded-stream parity and local-shape planning.

Runs in subprocesses with 8 forced host devices (the main test process
keeps the single real CPU device), like tests/test_distributed.py:

* every registry kernel that declares ``shard_dims`` runs under
  ``shard_map`` and must match the unsharded op and the XLA oracle;
* a kernel compiled inside ``shard_map`` plans against *local* shard
  shapes (asserted via the planner's ``last_plan`` workload) with the
  plan cache keyed by the mesh topology;
* the collective-overlap helpers route their local dot through the
  ``repro.ops.matmul`` stream kernel when given a policy;
* ``pipeline_apply`` keeps GPipe parity with a policy installed;
* ``launch/serve.py --smoke`` runs end-to-end through ``repro.ops``
  under the host mesh.
"""

import os
import subprocess
import sys
import textwrap

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(body: str, n_dev: int = 8, timeout: int = 560) -> str:
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    env = {**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_registry_kernels_sharded_parity():
    """Per registry kernel: sharded == unsharded == XLA reference."""
    out = run_sub("""
        from repro.kernels.registry import all_kernels, run_sharded_smoke
        from repro.runtime import sharding as shlib

        mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
        covered = 0
        with shlib.use_sharding(mesh):
            for spec in all_kernels():
                if spec.shard_dims is None:     # documented opt-out
                    print(f"parity {spec.name} skipped (no shard_dims)")
                    continue
                _, _, _, err_un, err_ref = run_sharded_smoke(spec, mesh)
                tol = max(spec.tol, 1e-6)
                assert err_un <= tol, (spec.name, "vs unsharded", err_un)
                assert err_ref <= tol, (spec.name, "vs ref", err_ref)
                print(f"parity {spec.name} {err_un:.1e} {err_ref:.1e}")
                covered += 1
        assert covered >= 5, f"only {covered} kernels ran sharded parity"
        print("sharded parity ok")
    """)
    assert "sharded parity ok" in out


def test_shard_map_plans_local_workload_with_mesh_key():
    """Inside shard_map the planner sees the per-shard word schedule, and
    the plan is keyed by the mesh topology (acceptance: Plan workload)."""
    out = run_sub("""
        import repro
        from repro.core import planner
        from repro.kernels.ff_matmul.ops import matmul_workload
        from repro.runtime import sharding as shlib
        from repro.runtime.streams import shard_streams

        mesh = jax.make_mesh((8,), ("data",))
        m_global, n, k = 8 * 192, 160, 136
        a = jax.random.normal(jax.random.key(0), (m_global, k), jnp.float32)
        b = jax.random.normal(jax.random.key(1), (k, n), jnp.float32)

        planner.plan_cache_clear()
        with shlib.use_sharding(mesh):
            f = shard_streams(repro.ops.matmul,
                              in_specs=(P("data"), P(None, None)),
                              out_specs=P("data"))
            out = f(a, b)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(a) @ np.asarray(b), atol=1e-4)

        plan = planner.last_plan("ff_matmul")
        w_local, _ = matmul_workload(m_global // 8, n, k,
                                     (128, 128, 128), jnp.float32)
        w_global, _ = matmul_workload(m_global, n, k,
                                      (128, 128, 128), jnp.float32)
        assert plan.workload == w_local, (plan.workload, w_local)
        assert plan.workload.n_words < w_global.n_words
        assert plan.mesh.token == "data8", plan.mesh
        assert plan.mesh.device_count == 8

        # repeat call: served from the mesh-keyed plan cache, no new miss
        misses = planner.plan_cache_info().misses
        _ = f(a, b)
        info = planner.plan_cache_info()
        assert info.misses == misses and info.hits >= 1, info
        print("local planning ok", plan.workload.n_words, plan.mesh.token)
    """)
    assert "local planning ok" in out


def test_collectives_policy_routes_stream_matmul():
    """allgather_matmul / matmul_reducescatter with a PipePolicy run their
    per-hop dot through repro.ops.matmul and keep exact-shape parity."""
    out = run_sub("""
        from repro.core import PipePolicy, planner
        from repro.runtime import sharding as shlib
        from repro.runtime.collectives import allgather_matmul, \\
            matmul_reducescatter
        from repro.runtime.streams import shard_map_compat

        mesh = jax.make_mesh((8,), ("d",))
        pol = PipePolicy(interpret=True)
        x = jax.random.normal(jax.random.key(0), (64, 32))
        w = jax.random.normal(jax.random.key(1), (32, 16))
        with shlib.use_sharding(mesh):
            f = shard_map_compat(
                lambda xs, ws: allgather_matmul(xs, ws, "d", policy=pol),
                mesh, (P("d", None), P(None, None)), P(None, None))
            got = f(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)
        plan = planner.last_plan("ff_matmul")
        assert plan is not None and plan.mesh.token == "d8", plan

        x2 = jax.random.normal(jax.random.key(2), (64, 128))
        w2 = jax.random.normal(jax.random.key(3), (128, 16))
        with shlib.use_sharding(mesh):
            g = shard_map_compat(
                lambda xs, ws: matmul_reducescatter(xs, ws, "d", policy=pol),
                mesh, (P(None, "d"), P("d", None)), P("d", None))
            got2 = g(x2, w2)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(x2 @ w2),
                                   rtol=1e-4, atol=1e-4)
        print("collective stream matmul ok")
    """)
    assert "collective stream matmul ok" in out


def test_pipeline_apply_with_policy_matches_sequential():
    """The stream-schedule rewrite of pipeline_apply keeps GPipe parity,
    with a session policy installed around the stage body."""
    out = run_sub("""
        from repro.core import PipePolicy
        from repro.runtime.pipeline_parallel import pipeline_apply
        from repro.runtime import sharding as shlib
        from repro.runtime.streams import shard_map_compat

        n_stage, m, mb, d = 4, 8, 4, 16
        mesh = jax.make_mesh((n_stage,), ("pod",))
        ws = jax.random.normal(jax.random.key(0), (n_stage, d, d)) / (d ** 0.5)
        x = jax.random.normal(jax.random.key(1), (m, mb, d))

        def stage(w, h):
            return jnp.tanh(h @ w)

        pol = PipePolicy(interpret=True)
        with shlib.use_sharding(mesh):
            f = shard_map_compat(
                lambda w, x: pipeline_apply(stage, w[0], x, "pod",
                                            policy=pol),
                mesh, (P("pod"), P(None)), P("pod"))
            got = f(ws, x)

        want = x
        for s in range(n_stage):
            want = stage(ws[s], want)
        np.testing.assert_allclose(np.asarray(got)[-m:], np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        print("pipeline policy ok")
    """)
    assert "pipeline policy ok" in out


def test_compile_graph_localizes_node_workloads():
    """compile_graph(sharding=...) plans each node against the per-shard
    word schedule, keyed by the mesh (single-process: synthetic MeshSpec)."""
    out = run_sub("""
        from repro.core import MeshSpec, PipePolicy, planner
        from repro.core.graph import compile_graph
        from repro.models.layers import build_attention_proj_graph

        g = build_attention_proj_graph()
        planner.plan_cache_clear()
        cg_single = compile_graph(g, policy=PipePolicy())
        single = {op: p.workload.n_words
                  for op, p in planner._LAST_PLAN.items()}

        planner.plan_cache_clear()
        mesh = MeshSpec(axes=(("data", 4),))
        cg_mesh = compile_graph(g, policy=PipePolicy(), sharding=mesh)
        for op, plan in planner._LAST_PLAN.items():
            assert plan.mesh.token == "data4", (op, plan.mesh)
            assert plan.workload.n_words <= -(-single[op] // 4) or \\
                plan.workload.n_words == 1, (op, plan.workload.n_words,
                                             single[op])
        print("graph localization ok")
    """, n_dev=1)
    assert "graph localization ok" in out


def test_serve_smoke_runs_through_repro_ops_under_mesh():
    """launch/serve.py --smoke end to end: repro.ops kernels, host mesh."""
    env = {**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "qwen1_5_0p5b", "--smoke", "--impl", "ff", "--requests", "2",
         "--prompt-len", "12", "--max-new", "4"],
        env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "impl=ff" in r.stdout
    assert "'data': 4" in r.stdout and "'model': 2" in r.stdout
    assert "decode" in r.stdout
