"""Roofline report over the dry-run artifacts (EXPERIMENTS.md §Roofline is
generated from this)."""

from __future__ import annotations

import os

from repro.launch.roofline import analyze_cell, load_all, markdown_table

DRY = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def main():
    print("# Roofline terms per (arch x shape x mesh) from the dry-run")
    print("name,us_per_call,derived")
    rows = []
    for result in load_all(DRY):
        a = analyze_cell(result)
        if a is None:
            continue
        rows.append(a)
        dom = max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"])
        print(f"roofline/{a['cell']},{dom * 1e6:.0f},"
              f"{a['bottleneck']}_RF={a['roofline_fraction']:.3f}")
    if rows:
        print("#")
        for line in markdown_table(rows).splitlines():
            print("# " + line)


if __name__ == "__main__":
    main()
