"""Multi-device behaviours, run in subprocesses with 8 forced host devices
(the main test process keeps the single real CPU device).

Covers: sharded train step == single-device step, int8 compressed
all-reduce error bound, collective-matmul overlap helpers == plain matmul,
GPipe pipeline == sequential stage application, elastic remesh restore."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(body: str, n_dev: int = 8, timeout: int = 560) -> str:
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        if hasattr(jax, "shard_map"):
            shard_map = jax.shard_map
        else:
            # jax < 0.5: shard_map lives in jax.experimental and the
            # replication-check kwarg is named check_rep, not check_vma
            from jax.experimental.shard_map import shard_map as _shard_map
            def shard_map(f, mesh, in_specs, out_specs, check_vma=True):
                return _shard_map(f, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, check_rep=check_vma)
    """) + textwrap.dedent(body)
    env = {**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = run_sub("""
        from repro.configs.base import smoke_config
        from repro.models import build_model
        from repro.launch import steps as steps_lib
        from repro.runtime import sharding as shlib
        from repro.optim import adamw

        cfg = smoke_config("llama3_2_1b")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        opt_cfg = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
        opt_init, _ = steps_lib.opt_init_and_update("adamw", opt_cfg)
        batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.key(2), (8, 32), 0, cfg.vocab)}

        # single-device reference
        ts = steps_lib.make_train_step(model, opt_cfg=opt_cfg)
        p1, _, m1 = jax.jit(ts)(params, opt_init(params), batch)

        # sharded over (data=4, model=2)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with shlib.use_sharding(mesh):
            p2, _, m2 = jax.jit(ts)(params, opt_init(params), batch)
        d = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        print("maxdiff", d, "loss", float(m1["loss"]), float(m2["loss"]))
        # sharded reductions reorder float sums; AdamW's rsqrt amplifies the
        # epsilon-scale grad differences into ~1e-4 param deltas after one step
        assert d < 1e-3, d
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    """)
    assert "maxdiff" in out


def test_compressed_allreduce_error_bound():
    out = run_sub("""
        from repro.optim.compression import compressed_allreduce, quantize
        mesh = jax.make_mesh((8,), ("d",))
        x = jax.random.normal(jax.random.key(0), (8, 64, 128)) * 3.0
        f = shard_map(lambda s: compressed_allreduce(s, "d"),
                      mesh=mesh, in_specs=P("d"), out_specs=P("d"))
        got = f(x)                       # mean over devices, each row = mean
        want = jnp.mean(x, axis=0, keepdims=True)
        err = float(jnp.max(jnp.abs(got[0] - want[0])))
        # per-device quantization error <= max|x|/127; mean preserves bound
        bound = float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6
        print("err", err, "bound", bound)
        assert err <= bound, (err, bound)
    """)
    assert "err" in out


def test_allgather_matmul_overlap_equals_plain():
    out = run_sub("""
        from repro.runtime.collectives import allgather_matmul, matmul_reducescatter
        mesh = jax.make_mesh((8,), ("d",))
        x = jax.random.normal(jax.random.key(0), (64, 32))
        w = jax.random.normal(jax.random.key(1), (32, 16))
        f = shard_map(lambda xs, w: allgather_matmul(xs, w, "d"),
                      mesh=mesh, in_specs=(P("d", None), P(None, None)),
                      out_specs=P(None, None), check_vma=False)
        got = f(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=1e-5, atol=1e-5)

        # matmul + reduce-scatter: x [m, k] sharded on k
        x2 = jax.random.normal(jax.random.key(2), (64, 128))
        w2 = jax.random.normal(jax.random.key(3), (128, 16))
        g = shard_map(lambda xs, ws: matmul_reducescatter(xs, ws, "d"),
                      mesh=mesh, in_specs=(P(None, "d"), P("d", None)),
                      out_specs=P("d", None), check_vma=False)
        got2 = g(x2, w2)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(x2 @ w2),
                                   rtol=1e-4, atol=1e-4)
        print("overlap ok")
    """)
    assert "overlap ok" in out


def test_pipeline_parallel_matches_sequential():
    out = run_sub("""
        from repro.runtime.pipeline_parallel import pipeline_apply
        n_stage, m, mb, d = 4, 8, 4, 16
        mesh = jax.make_mesh((n_stage,), ("pod",))
        ws = jax.random.normal(jax.random.key(0), (n_stage, d, d)) / (d ** 0.5)
        x = jax.random.normal(jax.random.key(1), (m, mb, d))

        def stage(w, h):
            return jnp.tanh(h @ w)

        f = shard_map(lambda w, x: pipeline_apply(stage, w[0], x, "pod"),
                      mesh=mesh, in_specs=(P("pod"), P(None)),
                      out_specs=P("pod"), check_vma=False)
        got = f(ws, x)            # [n_stage * M, mb, d]; last stage banks outs

        want = x
        for s in range(n_stage):
            want = stage(ws[s], want)
        np.testing.assert_allclose(np.asarray(got)[-m:],
                                   np.asarray(want), rtol=1e-4, atol=1e-4)
        print("pipeline ok")
    """)
    assert "pipeline ok" in out


@pytest.mark.slow
def test_elastic_remesh_restore(tmp_path):
    out = run_sub(f"""
        from repro.configs.base import smoke_config
        from repro.models import build_model
        from repro.runtime import sharding as shlib
        from repro.runtime.elastic import remesh_restore, survivable_mesh
        from repro.checkpoint import save

        cfg = smoke_config("qwen1_5_0p5b")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        save(r"{tmp_path}", 5, params)

        # "pod loss": restore onto a 4-device mesh (model axis kept at 2)
        devs = jax.devices()[:4]
        mesh = survivable_mesh(devs, model_axis=2)
        state, step = remesh_restore(r"{tmp_path}", model.abstract_params(),
                                     model.param_axes(), mesh)
        assert step == 5
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        shs = {{str(l.sharding) for l in jax.tree.leaves(state)}}
        print("remesh ok", len(shs))
    """)
    assert "remesh ok" in out


def test_production_mesh_shapes():
    out = run_sub("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.shape == {"data": 16, "model": 16}, m1.shape
        m2 = make_production_mesh(multi_pod=True)
        assert m2.shape == {"pod": 2, "data": 16, "model": 16}, m2.shape
        print("mesh ok")
    """, n_dev=512)
    assert "mesh ok" in out
