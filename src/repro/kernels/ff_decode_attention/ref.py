"""Pure-jnp oracle for ff_decode_attention."""

from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         lengths: jnp.ndarray) -> jnp.ndarray:
    """q: [B, KVH, G, D]; k, v: [B, KVH, S, D]; lengths: [B] -> [B, KVH, G, D]."""
    b, kvh, g, d = q.shape
    s = k.shape[2]
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    mask = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhgs,bhsd->bhgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
