"""Paper Table 3: auto-generated microbenchmarks — access-pattern
(regular/irregular) x divergence/DLCD — M2C2 vs single work-item baseline,
plus an interpret-mode correctness pass of every registry-enumerated kernel
(regular and irregular exemplars) against its oracle."""

from __future__ import annotations

import time

from repro.core import ARRIA_CX, Pipe, estimate_baseline, estimate_feedforward
from benchmarks.workloads import MICRO


def model_rows():
    out = []
    for name, b in MICRO.items():
        base = estimate_baseline(b.workload, ARRIA_CX)
        m2c2 = estimate_feedforward(b.workload, ARRIA_CX,
                                    Pipe(tile=(8, 128), depth=8, streams=2))
        out.append({
            "name": name,
            "us_per_call": m2c2.total_s * 1e6 / b.workload.n_words,
            "speedup": base.total_s / m2c2.total_s,
            "paper": b.paper_speedup,
            "bottleneck": m2c2.bottleneck,
        })
    return out


def kernel_validation():
    """Registry-enumerated kernel correctness (interpret mode) + wall time.
    Every registered kernel runs its smoke shapes with planner-sized pipes
    (depth/streams "auto") against its oracle."""
    from repro.kernels.registry import all_kernels, run_smoke
    results = []
    for spec in all_kernels():
        t0 = time.time()
        _, _, err = run_smoke(spec)
        dt = time.time() - t0
        ok = err <= spec.tol
        results.append((spec.name, spec.regular, ok, err, dt))
    return results


def main():
    print("# Table 3 analogue: microbenchmarks (M2C2 vs baseline)")
    print("name,us_per_call,derived")
    for r in model_rows():
        print(f"table3/{r['name']},{r['us_per_call']:.3f},"
              f"m2c2={r['speedup']:.2f}x_paper={r['paper']:.2f}x")
    rs = {r["name"]: r for r in model_rows()}
    assert rs["M_AI10_R"]["speedup"] > rs["M_AI10_IR"]["speedup"], \
        "regular must gain more than irregular (paper Table 3)"
    assert rs["M_AI6_forif_R"]["speedup"] > rs["M_AI10_R"]["speedup"], \
        "divergent/DLCD kernels must gain more (paper Table 3)"
    results = kernel_validation()
    for name, regular, ok, err, dt in results:
        pat = "regular" if regular else "irregular"
        print(f"# generated-kernel validation: {name}({pat})={ok} "
              f"err={err:.1e} ({dt*1e3:.0f} ms interp)")
    assert all(ok for _, _, ok, _, _ in results), results
    pats = {regular for _, regular, _, _, _ in results}
    assert pats == {True, False}, "need both R and IR exemplars (Table 3)"


if __name__ == "__main__":
    main()
