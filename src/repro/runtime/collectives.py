"""Overlap-friendly collectives as Stream producers/consumers.

The feed-forward model at mesh scale: communication is the producer, the
MXU is the consumer, and ``ppermute`` rings are the pipes. This module
expresses the collective-overlap paths on an explicit ring abstraction —
:class:`RingStream`, the mesh-scale analogue of the kernel emitter's
``RingPipe`` — so the word schedule reads exactly like a
:class:`~repro.core.program.StreamProgram` body::

    for word in ring.words():
        part = consume(cur)        # compute kernel on the landed word
        cur = ring.hop(cur)        # producer: next word's transfer in
                                   # flight while `part` retires

``allgather_matmul`` and ``matmul_reducescatter`` interleave each ring hop
with the partial matmul it feeds (hop k+1 is in flight while chunk k
multiplies; XLA overlaps the independent ppermute with the dot). The local
dot is pluggable: pass a :class:`~repro.core.program.PipePolicy` to route
it through the tuned ``repro.ops.matmul`` stream kernel — the consumer of
the mesh-level pipe is then itself a pipe-structured kernel, planned at
the *local shard shapes* and cache-keyed by the mesh topology.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def axis_size(axis_name: str) -> int:
    """Static size of a mapped axis. jax >= 0.5 has jax.lax.axis_size;
    older versions constant-fold psum(1, axis) to the same int."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


@dataclasses.dataclass(frozen=True)
class RingStream:
    """The inter-device pipe of one mapped mesh axis.

    The mesh-scale analogue of :class:`repro.core.emitter.RingPipe`: a
    ``ppermute`` hop is the producer DMA moving the next word into this
    device's (single) ring slot, the loop body is the consumer, and the
    ring has ``n_words() == axis_size`` words — one per source shard.
    ``reverse`` flips the ring direction (gather rings shift forward,
    reduce-scatter rings shift partial sums backward).
    """

    axis_name: str
    reverse: bool = False

    def n_words(self) -> int:
        return axis_size(self.axis_name)

    def index(self):
        return jax.lax.axis_index(self.axis_name)

    def hop(self, x: jnp.ndarray) -> jnp.ndarray:
        """Issue the next word's transfer: shift ``x`` one hop around the
        ring (the producer stage; independent of the consumer's dot, so
        XLA schedules them concurrently)."""
        n = self.n_words()
        if self.reverse:
            perm = [(i, (i - 1) % n) for i in range(n)]
        else:
            perm = [(i, (i + 1) % n) for i in range(n)]
        return jax.lax.ppermute(x, self.axis_name, perm)


def _local_matmul(policy=None) -> Callable[[jnp.ndarray, jnp.ndarray],
                                           jnp.ndarray]:
    """The consumer's dot: plain XLA by default; with a policy, the tuned
    ``repro.ops.matmul`` stream kernel under that policy (mesh-tagged via
    :func:`repro.runtime.streams.mesh_policy`, so the per-shard plan is
    keyed by the topology it runs under)."""
    if policy is None:
        return lambda x, w: jnp.dot(
            x, w, preferred_element_type=jnp.promote_types(x.dtype, w.dtype))
    import repro.ops
    from repro.runtime.streams import mesh_policy
    pol = mesh_policy(policy)

    def dot(x, w):
        out_dtype = jnp.promote_types(x.dtype, w.dtype)
        return repro.ops.matmul(x, w, policy=pol,
                                out_dtype=out_dtype).astype(out_dtype)
    return dot


def ring_allgather(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """All-gather along ``axis_name`` via the ring (shard_map body).
    Returns the concatenation over devices along dim 0."""
    ring = RingStream(axis_name)
    n = ring.n_words()
    idx = ring.index()
    out = jnp.zeros((n, *x.shape), x.dtype)
    cur = x
    for word in range(n):
        src = (idx - word) % n            # word `word` holds shard `src`
        out = out.at[src].set(cur)        # consume: bank the landed word
        if word + 1 < n:
            cur = ring.hop(cur)           # produce: next word in flight
    return out.reshape(n * x.shape[0], *x.shape[1:])


def allgather_matmul(x_shard: jnp.ndarray, w: jnp.ndarray,
                     axis_name: str,
                     policy=None) -> jnp.ndarray:
    """Compute (allgather(x) @ w) with per-hop overlap.

    x_shard: [m_shard, k] (sharded on rows over ``axis_name``); w: [k, n]
    replicated. Returns [m_shard * n_dev, n] — each hop's chunk multiplies
    while the next hop's ppermute is in flight. ``policy`` routes the
    per-word dot through the ``repro.ops.matmul`` stream kernel.
    """
    ring = RingStream(axis_name)
    dot = _local_matmul(policy)
    n_dev = ring.n_words()
    idx = ring.index()
    m = x_shard.shape[0]
    out = jnp.zeros((n_dev, m, w.shape[1]),
                    jnp.promote_types(x_shard.dtype, w.dtype))
    cur = x_shard
    for word in range(n_dev):
        src = (idx - word) % n_dev
        part = dot(cur, w)                         # consumer
        out = out.at[src].set(part)
        if word + 1 < n_dev:
            cur = ring.hop(cur)                    # producer
    return out.reshape(n_dev * m, w.shape[1])


def matmul_reducescatter(x: jnp.ndarray, w_shard: jnp.ndarray,
                         axis_name: str,
                         policy=None) -> jnp.ndarray:
    """Compute reduce_scatter(x @ allgathered-w) in ring form: each word
    multiplies one weight shard and shifts the partial sum — the ring
    reduce-scatter fused with the matmul that produces it.

    x: [m, k_shard] (k sharded); w_shard: [k_shard, n]. Output: [m, n]
    reduced over the axis, scattered by rows: returns [m // n_dev, n].
    ``policy`` routes the per-word dot through ``repro.ops.matmul``.
    """
    ring = RingStream(axis_name, reverse=True)
    dot = _local_matmul(policy)
    n_dev = ring.n_words()
    idx = ring.index()
    m = x.shape[0]
    rows = m // n_dev
    acc = jnp.zeros((rows, w_shard.shape[1]),
                    jnp.promote_types(x.dtype, w_shard.dtype))
    for word in range(n_dev):
        blk = (idx + 1 + word) % n_dev
        x_blk = jax.lax.dynamic_slice_in_dim(x, blk * rows, rows, axis=0)
        acc = acc + dot(x_blk, w_shard)            # consumer
        if word + 1 < n_dev:
            acc = ring.hop(acc)                    # producer (reverse ring)
    return acc
