"""Straggler detection + mitigation policy.

At 1000+ nodes, the slowest participant sets the step time for synchronous
SPMD. The watchdog keeps a robust (median/MAD) model of per-step durations
and per-host heartbeats; persistent outliers trigger a mitigation action:

  "none"            within tolerance
  "rebalance"       transient slowness: shrink that host's data shard
                    (batch rebalancing hook)
  "replace"         persistent: promote a hot spare, evict the host, and
                    elastic-remesh (runtime.elastic) from checkpoint

The policy is pure bookkeeping (host-side), so it is fully unit-testable
without hardware; the trainer wires `observe_step` around its step timer.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass
class StragglerConfig:
    window: int = 50
    slow_factor: float = 1.5       # x median step time = outlier
    tolerate: int = 3              # consecutive outliers before rebalance
    evict_after: int = 10          # consecutive outliers before replace
    hot_spares: int = 2


class StragglerWatchdog:
    def __init__(self, cfg: StragglerConfig, hosts: List[str]):
        self.cfg = cfg
        self.hosts = list(hosts)
        self.spares: List[str] = [f"spare_{i}" for i in range(cfg.hot_spares)]
        self._times: Dict[str, Deque[float]] = {
            h: deque(maxlen=cfg.window) for h in hosts}
        self._strikes: Dict[str, int] = {h: 0 for h in hosts}
        self.evicted: List[str] = []

    def _median(self) -> float:
        all_t = sorted(t for dq in self._times.values() for t in dq)
        return all_t[len(all_t) // 2] if all_t else 0.0

    def observe_step(self, host_times: Dict[str, float]) -> Dict[str, str]:
        """Feed per-host step durations; returns {host: action}."""
        actions: Dict[str, str] = {}
        for h, t in host_times.items():
            if h not in self._times:
                continue
            self._times[h].append(t)
        med = self._median()
        for h, t in host_times.items():
            if h not in self._times:
                continue
            if med > 0 and t > self.cfg.slow_factor * med:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
            if self._strikes[h] >= self.cfg.evict_after:
                actions[h] = "replace"
            elif self._strikes[h] >= self.cfg.tolerate:
                actions[h] = "rebalance"
            else:
                actions[h] = "none"
        return actions

    def replace(self, host: str) -> Optional[str]:
        """Evict ``host``; return the promoted spare (or None -> shrink)."""
        if host not in self.hosts:
            return None
        self.hosts.remove(host)
        self.evicted.append(host)
        self._times.pop(host, None)
        self._strikes.pop(host, None)
        if self.spares:
            spare = self.spares.pop(0)
            self.hosts.append(spare)
            self._times[spare] = deque(maxlen=self.cfg.window)
            self._strikes[spare] = 0
            return spare
        return None
