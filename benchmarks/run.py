"""Benchmark harness: one module per paper table/figure + the roofline
report. Prints ``name,us_per_call,derived`` CSV lines (detail lines are
'#'-prefixed).

``--smoke`` skips the modeled tables and instead exercises every kernel in
the registry at tiny shapes with planner-sized pipes (interpret mode), so
the perf plumbing — registry enumeration, auto planning, emitter DMA
schedules — cannot silently rot even where full benches are too slow."""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def smoke() -> None:
    from repro.core import plan_cache_info
    from repro.kernels.registry import all_kernels, run_smoke

    failures = []
    print("# smoke: every registered kernel, tiny shapes, depth/streams=auto")
    for spec in all_kernels():
        t0 = time.time()
        try:
            _, _, err = run_smoke(spec)
            ok = err <= spec.tol
        except Exception:   # noqa: BLE001 — report all kernels
            traceback.print_exc()
            ok, err = False, float("nan")
        dt = (time.time() - t0) * 1e3
        status = "ok" if ok else "FAIL"
        print(f"smoke/{spec.name},{dt:.0f},err={err:.1e}_{status}")
        if not ok:
            failures.append(spec.name)
    print(f"# plan cache: {plan_cache_info()}")
    if failures:
        print(f"\nFAILED smoke kernels: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("smoke ok")


def full() -> None:
    from benchmarks import (fig4_m2c2, kernel_bench, roofline_report,
                            table2_feedforward, table3_microbench)
    failures = []
    for mod in (table2_feedforward, fig4_m2c2, table3_microbench,
                kernel_bench, roofline_report):
        print(f"\n===== {mod.__name__} =====")
        try:
            mod.main()
        except Exception:   # noqa: BLE001 — report all benches
            failures.append(mod.__name__)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED benches: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("\nall benches ok")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="run every registered kernel at tiny shapes "
                             "instead of the modeled benches")
    args = parser.parse_args()
    smoke() if args.smoke else full()


if __name__ == "__main__":
    main()
