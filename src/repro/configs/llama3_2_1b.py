"""llama3.2-1b [dense] — small llama3, tied embeddings.
[hf:meta-llama/Llama-3.2-1B; unverified]  16L d_model=2048 32H (GQA kv=8)
d_ff=8192 vocab=128256."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama3_2_1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    tie_embeddings=True,
    rope_theta=500000.0,
    rule_overrides={"kv_heads": None},   # 8 kv heads vs 16-way model axis
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    compute_dtype="float32",
)
