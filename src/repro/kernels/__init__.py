"""repro.kernels — Pallas TPU kernels implementing the feed-forward (DAE)
design model, one subpackage per hot spot:

  ff_matmul            DAE blocked matmul (regular streams)
  ff_attention         flash attention prefill, GQA, KV ring pipes
  ff_decode_attention  flash-decode vs. long KV caches
  ff_chunk_scan        gated linear-attention scan (Mamba2 / RWKV6)
  ff_gather            irregular row gather (embedding / MoE dispatch)

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit
wrapper + exact tile-schedule cost model + registration), ref.py (pure-jnp
oracle). Kernels validate under interpret=True on CPU; real-TPU lowering is
the target.

The emitter/registry contract — what a *new* kernel must provide
----------------------------------------------------------------

1. **Emit pipelines through the shared ring-pipe emitter**
   (:mod:`repro.core.emitter`), never hand-rolled DMA loops. In kernel.py:

   * build one :class:`~repro.core.emitter.RingPipe` per operand stream
     from its :class:`~repro.core.pipe.Pipe` spec (regular block copies),
     or a :class:`~repro.core.emitter.GatherRingPipe` for irregular
     per-row gathers;
   * splat each ring's ``scratch_shapes`` into the pallas_call scratch
     list — the emitter owns the VMEM ring buffer and DMA semaphores;
   * inside the kernel, ``bind(buf, sems, slicer)`` each ring to its
     scratch refs and HBM address stream (the slicer may depend only on
     the word index — the feed-forward restriction), then use the
     primitives: ``acquire(g, n_words, pipes)`` / ``slot(g)`` /
     ``release(g, n_words, pipes)``. ``depth == 1`` automatically
     degenerates to the synchronous copy-then-compute baseline.

2. **Register with the kernel registry**
   (:mod:`repro.kernels.registry`). In ops.py, call
   :func:`~repro.kernels.registry.register_kernel` with the public op
   wrapper (modes "ff"/"baseline"/"ref"), the pure-jnp oracle, the
   KernelCost model, a Workload builder (shapes -> (core.Workload, tile)),
   tiny smoke inputs, and a benchmark shape point. The benchmark harness
   (benchmarks/kernel_bench.py, ``benchmarks/run.py --smoke``) and the
   registry tests enumerate the registry — a new kernel is its subpackage
   plus the one ``register_kernel`` call, then add the ops module path to
   ``registry._BUILTIN``.

3. **Support planner auto-sizing.** The op wrapper must accept
   ``depth="auto"`` / ``streams="auto"`` and resolve them through
   :func:`repro.core.planner.resolve_auto` with the op's Workload — the
   roofline model then picks (depth, streams) per call-site shape, cached
   on (op, shape, dtype, hw).
"""

from repro.core.emitter import cdiv, pad_to
from repro.kernels.registry import (
    KernelCost,
    KernelSpec,
    all_kernels,
    get_kernel,
    kernel_names,
    register_kernel,
    run_smoke,
)

__all__ = [
    "KernelCost",
    "KernelSpec",
    "all_kernels",
    "cdiv",
    "get_kernel",
    "kernel_names",
    "pad_to",
    "register_kernel",
    "run_smoke",
]
