"""Traffic profiles: the recorded workload distribution the sweep tunes for.

A :class:`TrafficProfile` aggregates the :class:`repro.core.profiling.CallSite`
stream from a real run (serving, training) into *buckets*: call sites that
agree on everything except their dynamic shape dims, with those dims rounded
up to the next power of two. Bucketing is what makes dynamic-shape traffic
tunable offline — a serving run sees hundreds of distinct prompt lengths,
but only a handful of pow2 buckets, and a plan measured at the bucket shape
transfers to every exact shape inside it (the sweep still writes the tuned
record under every *exact* plan key observed, so serving lookups are exact-
match and never approximate).

Each bucket keeps its observation count plus the exact workload variants
seen, so :mod:`repro.plans.sweep` can (a) rank buckets by observed
frequency x modeled cost and (b) emit one PlanDB record per exact key.

Profiles are plain JSON (``PROFILE_FORMAT_VERSION``-stamped), mergeable
across runs/hosts with :meth:`TrafficProfile.merge`, and deterministic:
the same call-site stream always serializes to the same bytes.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
from typing import Any, Dict, Iterable, Mapping, Optional, Tuple

from repro.core import profiling
from repro.core.profiling import CallSite

PROFILE_FORMAT_VERSION = 1


def bucket_value(v: int) -> int:
    """Next power of two >= v (positive ints; <=0 passes through).
    Deterministic and idempotent — bucketing a bucket is a no-op."""
    if v <= 0:
        return v
    return 1 << (int(v) - 1).bit_length()


def bucket_site(site: Optional[Mapping[str, Any]],
                dynamic: Iterable[str]) -> Optional[Dict[str, Any]]:
    """Round the dynamic (traffic-dependent) keys of a call-site shape dict
    up to powers of two; static keys (block sizes, flags, group counts)
    pass through untouched — rounding those would change kernel semantics,
    not just the shape point."""
    if site is None:
        return None
    dyn = set(dynamic)
    out = {}
    for k in sorted(site):
        v = site[k]
        if k in dyn and isinstance(v, int) and not isinstance(v, bool):
            out[k] = bucket_value(v)
        else:
            out[k] = v
    return out


def _canon(obj) -> str:
    """Canonical JSON (sorted keys, tuples as lists) — bucket/variant
    identity."""
    return json.dumps(obj, sort_keys=True, default=list)


def _bucket_workload(workload_dict: Mapping[str, Any]) -> Dict[str, Any]:
    """Fallback bucketing for call sites with no shape dict (graphs, legacy
    planner callers): round the word count — the only traffic-dependent
    Workload field — to a power of two."""
    out = dict(workload_dict)
    out["n_words"] = bucket_value(int(out.get("n_words", 0)))
    return out


@dataclasses.dataclass
class ProfileEntry:
    """One shape bucket: everything that identifies the call site except
    the exact dynamic shapes, plus the exact variants observed in it."""

    op: str
    dtype: str
    hw: str
    mesh_axes: Tuple[Tuple[str, int], ...]
    extra_key: str
    origin: str
    policy: Dict[str, Any]
    site: Optional[Dict[str, Any]]          # bucketed shape dict
    site_dynamic: Tuple[str, ...]
    tile: Tuple[int, ...]
    count: int = 0
    # canonical exact-workload JSON -> {"workload": dict, "count": int}
    variants: Dict[str, Dict[str, Any]] = dataclasses.field(
        default_factory=dict)

    def to_payload(self) -> dict:
        d = dataclasses.asdict(self)
        d["mesh_axes"] = [list(ax) for ax in self.mesh_axes]
        d["site_dynamic"] = list(self.site_dynamic)
        d["tile"] = list(self.tile)
        return d

    @classmethod
    def from_payload(cls, d: Mapping[str, Any]) -> "ProfileEntry":
        return cls(
            op=d["op"], dtype=d["dtype"], hw=d["hw"],
            mesh_axes=tuple((str(n), int(s)) for n, s in d["mesh_axes"]),
            extra_key=d.get("extra_key", ""),
            origin=d.get("origin", "autotune"),
            policy=dict(d["policy"]),
            site=dict(d["site"]) if d.get("site") is not None else None,
            site_dynamic=tuple(d.get("site_dynamic", ())),
            tile=tuple(int(t) for t in d.get("tile", ())),
            count=int(d["count"]),
            variants={k: {"workload": dict(v["workload"]),
                          "count": int(v["count"])}
                      for k, v in d.get("variants", {}).items()})


def bucket_key(cs: CallSite) -> str:
    """Deterministic bucket identity of one call site. Excludes the policy
    *mode* (a profile recorded under mode="ff" is swept for serving under
    mode="autotune") but includes the fields that constrain the search
    space or the measured kernel (pins, stream_options, interpret)."""
    pol = cs.policy
    pol_sig = {"depth": pol["depth"], "streams": pol["streams"],
               "stream_options": list(pol["stream_options"]),
               "interpret": pol["interpret"]}
    site_b = bucket_site(cs.site, cs.site_dynamic)
    if site_b is None:
        site_b = _bucket_workload(dataclasses.asdict(cs.workload))
    return _canon([cs.op, cs.dtype, cs.hw, [list(ax) for ax in cs.mesh_axes],
                   cs.extra_key, pol_sig, site_b])


class TrafficProfile:
    """Bucketed aggregate of recorded call sites (see module docstring)."""

    def __init__(self):
        self.entries: Dict[str, ProfileEntry] = {}

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def total_count(self) -> int:
        return sum(e.count for e in self.entries.values())

    def observe(self, cs: CallSite) -> None:
        key = bucket_key(cs)
        entry = self.entries.get(key)
        if entry is None:
            entry = self.entries[key] = ProfileEntry(
                op=cs.op, dtype=cs.dtype, hw=cs.hw,
                mesh_axes=tuple(cs.mesh_axes), extra_key=cs.extra_key,
                origin=cs.origin,
                policy={k: (list(v) if isinstance(v, tuple) else v)
                        for k, v in cs.policy.items()},
                site=bucket_site(cs.site, cs.site_dynamic),
                site_dynamic=tuple(cs.site_dynamic), tile=tuple(cs.tile))
        entry.count += 1
        wl = dataclasses.asdict(cs.workload)
        vkey = _canon(wl)
        var = entry.variants.setdefault(vkey, {"workload": wl, "count": 0})
        var["count"] += 1

    def merge(self, other: "TrafficProfile") -> "TrafficProfile":
        """Fold another profile's observations into this one (counts add,
        variants union). Returns self."""
        for key, oe in other.entries.items():
            e = self.entries.get(key)
            if e is None:
                self.entries[key] = dataclasses.replace(
                    oe, variants={k: dict(v) for k, v in oe.variants.items()})
                continue
            e.count += oe.count
            for vkey, var in oe.variants.items():
                mine = e.variants.setdefault(
                    vkey, {"workload": dict(var["workload"]), "count": 0})
                mine["count"] += var["count"]
        return self

    def to_payload(self) -> dict:
        return {"format": PROFILE_FORMAT_VERSION,
                "entries": {k: self.entries[k].to_payload()
                            for k in sorted(self.entries)}}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_payload(), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def from_payload(cls, payload: Mapping[str, Any]) -> "TrafficProfile":
        if payload.get("format") != PROFILE_FORMAT_VERSION:
            raise ValueError(
                f"traffic profile format {payload.get('format')!r} != "
                f"{PROFILE_FORMAT_VERSION}")
        prof = cls()
        for key, d in payload.get("entries", {}).items():
            prof.entries[key] = ProfileEntry.from_payload(d)
        return prof

    @classmethod
    def load(cls, path: str) -> "TrafficProfile":
        with open(path) as f:
            return cls.from_payload(json.load(f))


@contextlib.contextmanager
def record_traffic(path: Optional[str] = None,
                   profile: Optional[TrafficProfile] = None):
    """Record every plan resolution in the scope into a TrafficProfile.

    Installs the core recording hook (:mod:`repro.core.profiling`) for the
    duration of the ``with`` block, restoring whatever recorder was there
    before. ``path`` (if given) is written on exit. Note: call sites inside
    ``jax.jit`` are recorded once per *trace*, not per execution — counts
    weight distinct shapes, not wall-clock frequency of cached executions.
    """
    prof = profile if profile is not None else TrafficProfile()
    prev = profiling.set_recorder(prof.observe)
    try:
        yield prof
    finally:
        profiling.set_recorder(prev)
        if path:
            prof.save(path)
