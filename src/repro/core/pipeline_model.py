"""Analytic cycle model of the feed-forward (DAE) pipeline.

The paper evaluates on an Arria-CX FPGA board with Intel's on-chip profiler.
This container has no FPGA and no TPU, so the quantitative engine of the
reproduction is an explicit analytic model of a decoupled access/execute
pipeline. It models, in seconds:

* the **baseline** ("single work-item") kernel, where loads are *entangled*
  with compute: the conservative compiler serializes the loop whenever it
  suspects a memory loop-carried dependency (false MLCD -> initiation
  interval II >> 1), and divergence/DLCDs stall the load units;
* the **feed-forward** kernel pair, where the producer streams words through
  a pipe of ``depth`` slots, so memory time and compute time *overlap* and
  the steady-state word time is max(t_mem, t_comp) instead of their sum;
* **multiple producers/consumers** (M2C2 etc.), which raise achievable
  memory-level parallelism until the memory system saturates — with a
  contention penalty for irregular access (the paper's Table 3 effect).

The model is deliberately simple, fully documented, and property-tested
(tests/test_pipeline_model.py): pipelining can never make a kernel slower
than the sum of its parts predicts, depth beyond the latency-hiding point
changes nothing (the paper's "depth does not significantly affect
performance"), and stream count saturates at the memory system's knee
(the paper's ">2x2 does not help").

Two hardware presets are provided:

* :data:`ARRIA_CX` — the paper's board (34.1 GB/s DDR4, ~300 MHz fabric);
  used by the benchmark suite to reproduce the paper's tables.
* :data:`TPU_V5E` — the deployment target (819 GB/s HBM, 197 TFLOP/s bf16);
  used by the planner to size pipes for the Pallas kernels.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.pipe import Pipe


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Memory/compute machine model for the DAE pipeline."""

    name: str
    clock_hz: float                   # fabric clock for II-denominated stalls
    hbm_bw: float                     # peak global-memory bandwidth, bytes/s
    stream_bw_frac: float             # fraction of peak one producer can pull
    dma_latency_s: float              # issue->first-byte latency of one copy
    flops: float                      # peak compute, FLOP/s
    irregular_eff: float              # bandwidth derate for irregular access
    contention_coeff: float           # per-extra-stream penalty (irregular)
    max_streams: int                  # memory-system saturation knee

    def stream_bandwidth(self, streams: int, regular: bool) -> float:
        """Aggregate achievable bandwidth for ``streams`` concurrent producers."""
        streams = min(streams, self.max_streams)
        eff = 1.0 if regular else self.irregular_eff
        per_stream = self.hbm_bw * self.stream_bw_frac * eff
        if not regular:
            # concurrent irregular streams fight for row buffers / channels
            per_stream = per_stream / (1.0 + self.contention_coeff * (streams - 1))
        return min(self.hbm_bw * eff, streams * per_stream)


# The paper's board: Intel PAC, Arria CX, 2x4GB DDR4 @ 34.1 GB/s.
ARRIA_CX = HardwareModel(
    name="arria-cx-pac",
    clock_hz=300e6,
    hbm_bw=34.1e9,
    stream_bw_frac=0.55,     # one in-order LSU stream cannot saturate DDR4
    dma_latency_s=300e-9,
    flops=1.5e12,
    irregular_eff=0.18,      # Wang et al. [17]: random access collapses DDR bw
    contention_coeff=0.85,
    max_streams=4,
)

# Deployment target: TPU v5e chip (assignment constants).
TPU_V5E = HardwareModel(
    name="tpu-v5e",
    clock_hz=940e6,
    hbm_bw=819e9,
    stream_bw_frac=0.55,     # one DMA queue's practical share of HBM
    dma_latency_s=2e-6,
    flops=197e12,
    irregular_eff=0.25,
    contention_coeff=0.6,
    max_streams=4,
)


@dataclasses.dataclass(frozen=True)
class Workload:
    """One kernel's stream program, in pipe words.

    Attributes:
      n_words: number of pipe words (tiles) the kernel processes.
      word_bytes: global-memory bytes loaded per word.
      flops_per_word: arithmetic work per word.
      regular: access pattern of the loads (paper: R vs IR).
      divergence: mean fractional control-flow bubble per word when control
        flow is *entangled* with the loads (baseline); in the FF design the
        bubble moves to the consumer and is smoothed across consumers.
      dlcd_cycles: length (cycles) of the data loop-carried dependency chain
        per word (reductions etc.). In the baseline this stalls the *loads*;
        in the FF design it bounds only the consumer.
      false_mlcd_ii: initiation interval (cycles) the conservative compiler
        assigns the baseline loop for a suspected-but-false memory LCD
        (paper: FW=285, BackProp=416). 0 = compiler proves independence.
      store_bytes_per_word: global stores per word (both designs keep stores).
    """

    n_words: int
    word_bytes: float
    flops_per_word: float
    regular: bool = True
    divergence: float = 0.0
    dlcd_cycles: float = 0.0
    false_mlcd_ii: float = 0.0
    store_bytes_per_word: float = 0.0

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_word / max(self.word_bytes, 1e-30)


@dataclasses.dataclass(frozen=True)
class PipelineEstimate:
    """Model output for one design point."""

    total_s: float
    t_mem_word_s: float
    t_comp_word_s: float
    achieved_bw: float          # bytes/s pulled from global memory
    bottleneck: str             # "memory" | "compute" | "latency" | "ii"
    vmem_bytes: int

    @property
    def achieved_bw_mb_s(self) -> float:
        return self.achieved_bw / 1e6


def _word_mem_bytes(w: Workload) -> float:
    return w.word_bytes + w.store_bytes_per_word


_BURST_LSU_OUTSTANDING = 16   # burst-coalesced LSU request buffer depth


def estimate_baseline(w: Workload, hw: HardwareModel) -> PipelineEstimate:
    """Single work-item kernel: loads entangled with compute.

    A *well-pipelined* baseline loop (no LCD) still achieves II=1 with the
    burst-coalesced LSU hiding latency over its request buffer — that is why
    the paper's saturated kernels (PageRank, Hotspot) see ~1x from FF. What
    the baseline cannot escape: the compiler-assigned II from (suspected)
    MLCDs / DLCD chains serializes the *whole* loop, and divergence bubbles
    stall the load units (control flow entangled with addresses).
    """
    bw = hw.stream_bandwidth(1, w.regular)
    t_transfer = _word_mem_bytes(w) / bw
    t_compute = max(w.flops_per_word / hw.flops,
                    w.dlcd_cycles / hw.clock_hz)
    t_lat = (0.0 if w.regular
             else hw.dma_latency_s / _BURST_LSU_OUTSTANDING)
    # divergence inflates everything entangled with the loads — including
    # the DLCD chain; the false-MLCD II is a fixed compiler schedule
    serial = max(t_lat, t_transfer, t_compute, 1.0 / hw.clock_hz) \
        * (1.0 + w.divergence)

    t_ii = w.false_mlcd_ii / hw.clock_hz
    t_word = max(serial, t_ii)
    bottleneck = "ii" if t_ii >= serial and w.false_mlcd_ii > 0 else (
        "memory" if t_transfer >= t_compute else "compute")
    total = w.n_words * t_word
    return PipelineEstimate(
        total_s=total,
        t_mem_word_s=t_transfer,
        t_comp_word_s=t_compute,
        achieved_bw=w.n_words * _word_mem_bytes(w) / total,
        bottleneck=bottleneck,
        vmem_bytes=0,
    )


def estimate_feedforward(
    w: Workload,
    hw: HardwareModel,
    pipe: Pipe,
    consumers: Optional[int] = None,
) -> PipelineEstimate:
    """Feed-forward kernel pair connected by ``pipe``.

    Steady state: producer and consumer overlap; the word time is the max of
    the two stages. The producer is free of DLCD/divergence (paper's whole
    point); the false MLCD vanishes because the split *proves* independence.

    Latency exposure: a *regular* stream is serviced by a prefetching LSU /
    streaming DMA — issue latency amortizes over the stream and only the
    pipeline fill pays it. An *irregular* stream pays latency per word,
    hidden by (depth-1) x streams outstanding transactions, but concurrent
    irregular streams also contend for the memory system's transaction
    resources (the paper's Table-3 effect). The pipelined loop itself can
    retire at most one word per clock (II=1 floor).
    """
    producers = pipe.streams
    consumers = producers if consumers is None else consumers

    bw = hw.stream_bandwidth(producers, w.regular)
    t_transfer = _word_mem_bytes(w) / bw
    if w.regular:
        t_latency_exposed = 0.0
    else:
        outstanding = max(pipe.depth - 1, 1) * producers
        lat = hw.dma_latency_s * (1.0 + hw.contention_coeff * (producers - 1))
        t_latency_exposed = lat / outstanding
    t_mem = max(t_transfer, t_latency_exposed)

    t_flops = w.flops_per_word / hw.flops
    t_dlcd = w.dlcd_cycles / hw.clock_hz
    # divergence bubbles smooth across consumers (static parity balancing)
    t_comp = (max(t_flops, t_dlcd) * (1.0 + w.divergence / consumers)) / consumers \
        if consumers > 1 else max(t_flops, t_dlcd) * (1.0 + w.divergence)

    t_word = max(t_mem, t_comp, 1.0 / hw.clock_hz)   # II=1 retirement floor
    fill = hw.dma_latency_s + pipe.depth * t_mem          # pipeline warmup
    total = fill + w.n_words * t_word
    if t_word == t_mem and t_mem == t_latency_exposed and t_latency_exposed > t_transfer:
        bottleneck = "latency"
    else:
        bottleneck = "memory" if t_mem >= t_comp else "compute"
    return PipelineEstimate(
        total_s=total,
        t_mem_word_s=t_mem,
        t_comp_word_s=t_comp,
        achieved_bw=w.n_words * _word_mem_bytes(w) / total,
        bottleneck=bottleneck,
        vmem_bytes=pipe.vmem_bytes,
    )


def speedup(w: Workload, hw: HardwareModel, pipe: Pipe,
            consumers: Optional[int] = None) -> float:
    """FF speedup over the single work-item baseline (paper Table 2 metric)."""
    base = estimate_baseline(w, hw)
    ff = estimate_feedforward(w, hw, pipe, consumers)
    return base.total_s / ff.total_s
