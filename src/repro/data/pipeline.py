"""Host-side feed-forward data pipeline: producer threads -> bounded queue
(pipe) -> consumer.

This is the paper's design model at the host level: N producer threads (the
"memory kernels") materialize batches; the bounded queue is the pipe (its
``depth`` = channel depth); the training loop is the consumer. Static
round-robin step assignment = the paper's static load balancing, and makes
delivery order deterministic regardless of producer timing.

State is one integer (next step) because batches are pure functions of the
step index — checkpoint/resume is exact.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Optional

import numpy as np


class HostPipeline:
    def __init__(self, batch_fn: Callable[[int], Dict[str, np.ndarray]],
                 *, depth: int = 2, producers: int = 1, start_step: int = 0):
        self.batch_fn = batch_fn
        self.depth = depth
        self.producers = producers
        self._next_emit = start_step
        self._stop = threading.Event()
        self._ready: Dict[int, Dict[str, np.ndarray]] = {}
        self._lock = threading.Condition()
        self._threads = []
        for p in range(producers):
            t = threading.Thread(target=self._produce, args=(start_step + p,),
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _produce(self, first: int) -> None:
        step = first
        while not self._stop.is_set():
            batch = self.batch_fn(step)
            with self._lock:
                # pipe back-pressure: only steps inside the lookahead window
                # [next_emit, next_emit + depth) may sit in the pipe, so a
                # fast producer can never crowd out the word the consumer
                # needs next (in-order delivery, bounded occupancy).
                while step - self._next_emit >= self.depth:
                    if self._stop.is_set():
                        return
                    self._lock.wait(timeout=0.1)
                self._ready[step] = batch
                self._lock.notify_all()
            step += self.producers

    def get(self, timeout: float = 30.0) -> Dict[str, np.ndarray]:
        """Blocking read from the pipe (in step order)."""
        with self._lock:
            deadline_step = self._next_emit
            ok = self._lock.wait_for(
                lambda: deadline_step in self._ready, timeout=timeout)
            if not ok:
                raise TimeoutError(f"pipe starved at step {deadline_step}")
            batch = self._ready.pop(deadline_step)
            self._next_emit += 1
            self._lock.notify_all()
            return batch

    @property
    def state(self) -> int:
        """Checkpointable pipeline state: the next step to be consumed."""
        with self._lock:
            return self._next_emit

    def stop(self) -> None:
        self._stop.set()
        with self._lock:
            self._lock.notify_all()
        for t in self._threads:
            t.join(timeout=2.0)
