"""Roofline-driven pipe planner.

The paper leaves (depth, #producers, #consumers) to the programmer, guided
by profiler output, and reports two empirical rules: depth barely matters
once latency is hidden, and >2x2 streams saturate the memory system. The
planner encodes exactly that reasoning on top of the analytic model, so the
framework can size pipes automatically per kernel call site.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

from repro.core.pipe import Pipe, required_depth, vmem_budget_ok
from repro.core.pipeline_model import (
    HardwareModel,
    TPU_V5E,
    Workload,
    estimate_feedforward,
)


@dataclasses.dataclass(frozen=True)
class Plan:
    pipe: Pipe
    consumers: int
    predicted_s: float
    predicted_bw: float
    rationale: str


def plan_pipe(
    w: Workload,
    tile: Tuple[int, ...],
    dtype,
    hw: HardwareModel = TPU_V5E,
    stream_options: Sequence[int] = (1, 2, 4),
    depth_cap: int = 17,     # (cap-1) outstanding = burst-LSU parity

    vmem_budget_bytes: int = 96 * 1024 * 1024,
) -> Plan:
    """Pick (depth, streams) minimizing modeled time under the VMEM budget.

    Ties break toward fewer streams and shallower pipes (the paper's
    "limit the number of channels" guidance).
    """
    base_pipe = Pipe(tile=tile, dtype=dtype, depth=2, streams=1)
    service = w.word_bytes / hw.stream_bandwidth(1, w.regular)
    depth = required_depth(hw.dma_latency_s, service, cap=depth_cap)

    best: Plan | None = None
    for streams in stream_options:
        if tile[0] % streams != 0:
            continue
        pipe = base_pipe.with_depth(depth).with_streams(streams)
        if not vmem_budget_ok([pipe], vmem_budget_bytes):
            continue
        est = estimate_feedforward(w, hw, pipe)
        cand = Plan(
            pipe=pipe,
            consumers=streams,
            predicted_s=est.total_s,
            predicted_bw=est.achieved_bw,
            rationale=(
                f"depth={depth} hides dma latency "
                f"({hw.dma_latency_s*1e9:.0f}ns over {service*1e9:.0f}ns/word); "
                f"streams={streams} bottleneck={est.bottleneck}"),
        )
        # require a >2% modeled win to take on more streams (channel-count
        # frugality, per the paper)
        if best is None or cand.predicted_s < best.predicted_s * 0.98:
            best = cand
    assert best is not None, "no feasible pipe under VMEM budget"
    return best
