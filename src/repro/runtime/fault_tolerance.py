"""Fault tolerance: checkpoint/restart supervision + preemption handling.

Designed for the 1000+ node regime where *something* is always failing:

* periodic atomic checkpoints (every N steps) + async host offload;
* SIGTERM/preemption -> drain current step, final checkpoint, clean exit
  (cluster schedulers send SIGTERM before eviction); the supervisor saves
  the previous SIGTERM handler and restores it on ``close()`` (it is a
  context manager), and a preemption landing exactly on a ``ckpt_every``
  boundary saves once, not twice;
* checkpoints carry a **tuned-plan snapshot** (``autotune.snapshot_plans``,
  keyed by ``PLAN_FORMAT_VERSION``): ``resume()`` pre-warms the autotune
  lookup chain from it, so a restarted job — even on a host with a cold
  plan cache — serves every previously tuned call site from memory and
  re-measures nothing;
* on start, auto-resume from the newest complete checkpoint — a killed job
  restarted with the same command continues bitwise-identically (stateless
  data pipeline + pure-function batches make this exact; tested by killing
  a training subprocess mid-run: ``runtime/chaos.py`` + ``tests/test_chaos``);
* failure injection hooks for tests.
"""

from __future__ import annotations

import dataclasses
import signal
import threading
from typing import Any, Callable, Optional

from repro import obs
from repro.checkpoint import latest_step, restore, save


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_last: int = 3
    handle_sigterm: bool = True
    # embed autotune.snapshot_plans() in every checkpoint's extra (and
    # pre-warm from it on resume) so restarts skip plan re-measurement
    plan_snapshot: bool = True


class Supervisor:
    """Wraps a step function with checkpoint/restart semantics.

    Use as a context manager (or call :meth:`close`) so the previously
    installed SIGTERM handler is restored when supervision ends — nested
    tools (test harnesses, notebook kernels, an outer supervisor) keep
    their own preemption handling.
    """

    def __init__(self, cfg: FTConfig, state_like: Any,
                 fail_at_step: Optional[int] = None):
        self.cfg = cfg
        self.state_like = state_like
        self.fail_at_step = fail_at_step
        self._preempted = threading.Event()
        self._prev_sigterm = None
        self._sigterm_installed = False
        self._last_saved_step: Optional[int] = None
        self.save_count = 0
        self.resume_prewarmed = 0    # plan records installed by resume()
        if cfg.handle_sigterm:
            try:
                self._prev_sigterm = signal.getsignal(signal.SIGTERM)
                signal.signal(signal.SIGTERM, self._on_sigterm)
                self._sigterm_installed = True
            except ValueError:
                pass    # not on main thread (tests)

    def _on_sigterm(self, *_):
        self._preempted.set()

    @property
    def preempted(self) -> bool:
        return self._preempted.is_set()

    def close(self) -> None:
        """Restore the SIGTERM handler that was installed before this
        supervisor took over (idempotent)."""
        if self._sigterm_installed:
            signal.signal(signal.SIGTERM, self._prev_sigterm)
            self._sigterm_installed = False

    def __enter__(self) -> "Supervisor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def resume(self) -> tuple[Any, int]:
        """(state, start_step); fresh state_like if no checkpoint exists.

        When the checkpoint carries a plan snapshot, the autotune chain is
        pre-warmed from it (``resume_prewarmed`` records how many tuned
        plans were installed) before any kernel call site resolves — the
        restarted job replays tuned plans instead of re-measuring."""
        with obs.span("supervisor_resume", ckpt_dir=self.cfg.ckpt_dir) as sp:
            step = latest_step(self.cfg.ckpt_dir)
            if step is None:
                sp.set(found=False, step=0)
                return self.state_like, 0
            state, step, extra = restore(self.cfg.ckpt_dir, self.state_like,
                                         step=step)
            if self.cfg.plan_snapshot:
                from repro.core import autotune
                self.resume_prewarmed = autotune.restore_snapshot(
                    (extra or {}).get("plan_snapshot"))
            sp.set(found=True, step=step, prewarmed=self.resume_prewarmed)
        obs.counter("supervisor_resumes_total",
                    "checkpoint resumes (fault_tolerance.Supervisor)").inc()
        obs.counter("supervisor_plans_prewarmed_total",
                    "tuned plans installed from checkpoint snapshots"
                    ).inc(self.resume_prewarmed)
        return state, step

    def _save(self, step: int, state: Any) -> None:
        # a preemption on a ckpt_every boundary (or the final step) must
        # not write the same checkpoint twice
        if step == self._last_saved_step:
            return
        with obs.span("supervisor_save", step=step,
                      ckpt_dir=self.cfg.ckpt_dir):
            extra = None
            if self.cfg.plan_snapshot:
                from repro.core import autotune
                extra = {"plan_snapshot": autotune.snapshot_plans()}
            save(self.cfg.ckpt_dir, step, state, extra=extra,
                 keep_last=self.cfg.keep_last)
        self._last_saved_step = step
        self.save_count += 1
        obs.counter("supervisor_saves_total",
                    "checkpoints written (fault_tolerance.Supervisor)").inc()

    def run(self, state: Any, start_step: int, n_steps: int,
            step_fn: Callable[[Any, int], Any],
            on_step: Optional[Callable[[int, Any], None]] = None) -> Any:
        step = start_step
        while step < n_steps:
            if self.fail_at_step is not None and step == self.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            state = step_fn(state, step)
            step += 1
            if on_step:
                on_step(step, state)
            if step % self.cfg.ckpt_every == 0 or step == n_steps:
                self._save(step, state)
            if self._preempted.is_set():
                # drain: the current step finished above — final checkpoint
                # (deduplicated when it coincides with the boundary save)
                self._save(step, state)
                break
        return state
