"""Fault tolerance: killed/failed training resumes bitwise-identically, and
the supervisor + straggler policies behave as specified."""

import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.runtime.fault_tolerance import FTConfig, Supervisor
from repro.runtime.stragglers import (BatchRebalancer, StragglerConfig,
                                      StragglerWatchdog, _median)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _counter_step(state, step):
    return {"x": state["x"] + step + 1}


def test_supervisor_resume_after_injected_failure(tmp_path):
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=3,
                   handle_sigterm=False)
    sup = Supervisor(cfg, {"x": np.zeros((), np.int64)}, fail_at_step=7)
    state, start = sup.resume()
    with pytest.raises(RuntimeError, match="injected"):
        sup.run(state, start, 10, _counter_step)
    # new supervisor (a "restarted job") resumes from step 6 checkpoint
    sup2 = Supervisor(cfg, {"x": np.zeros((), np.int64)})
    state, start = sup2.resume()
    assert start == 6
    final = sup2.run(state, start, 10, _counter_step)
    assert int(final["x"]) == sum(range(1, 11))   # identical to no-failure run


def _run_train(ckpt_dir, steps, fail_at=None, timeout=600):
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "qwen1_5_0p5b", "--smoke", "--steps", str(steps), "--batch", "2",
           "--seq", "32", "--ckpt-dir", ckpt_dir, "--ckpt-every", "5",
           "--log-every", "1"]
    if fail_at is not None:
        cmd += ["--fail-at", str(fail_at)]
    env = {**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}
    return subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=timeout)


@pytest.mark.slow
def test_training_killed_and_resumed_is_identical(tmp_path):
    """Deliverable: node-failure recovery. Run A: crash at step 12; run B:
    resume to 20. Run C: uninterrupted 20 steps. Final params must match
    bitwise (stateless data pipeline + pure-function batches)."""
    d1 = str(tmp_path / "crash")
    r = _run_train(d1, 20, fail_at=12)
    assert r.returncode != 0 and "injected failure" in r.stderr
    r = _run_train(d1, 20)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "resumed from checkpoint at step 10" in r.stdout

    d2 = str(tmp_path / "clean")
    r = _run_train(d2, 20)
    assert r.returncode == 0, r.stderr[-2000:]

    from repro.checkpoint import latest_step
    assert latest_step(d1) == 20 and latest_step(d2) == 20
    za = np.load(os.path.join(d1, "step_00000020", "arrays.npz"))
    zb = np.load(os.path.join(d2, "step_00000020", "arrays.npz"))
    assert set(za.files) == set(zb.files)
    for k in za.files:
        np.testing.assert_array_equal(za[k], zb[k], err_msg=k)


def test_straggler_watchdog_policies():
    cfg = StragglerConfig(window=20, slow_factor=1.5, tolerate=3,
                          evict_after=6, hot_spares=1)
    hosts = [f"h{i}" for i in range(8)]
    wd = StragglerWatchdog(cfg, hosts)
    # warmup: uniform
    for _ in range(5):
        acts = wd.observe_step({h: 1.0 for h in hosts})
    assert all(a == "none" for a in acts.values())
    # h3 becomes persistently slow
    actions_seen = []
    for i in range(7):
        t = {h: 1.0 for h in hosts}
        t["h3"] = 2.5
        acts = wd.observe_step(t)
        actions_seen.append(acts["h3"])
    assert "rebalance" in actions_seen
    assert actions_seen[-1] == "replace"
    spare = wd.replace("h3")
    assert spare == "spare_0"
    assert "h3" in wd.evicted and "spare_0" in wd.hosts
    # transient blip never escalates
    wd2 = StragglerWatchdog(cfg, hosts)
    for i in range(10):
        t = {h: 1.0 for h in hosts}
        if i == 4:
            t["h1"] = 3.0
        acts = wd2.observe_step(t)
        assert acts["h1"] in ("none",) if i != 4 else True
    assert acts["h1"] == "none"


# ---------------------------------------------------------------------------
# Straggler statistics: true median + MAD thresholding
# ---------------------------------------------------------------------------


def test_median_even_and_odd_lengths():
    assert _median([3.0, 1.0, 2.0]) == 2.0
    assert _median([4.0, 1.0, 3.0, 2.0]) == 2.5    # mean of the middle two
    assert _median([1.0, 2.0]) == 1.5
    assert _median([7.0]) == 7.0
    assert _median([]) == 0.0


def test_mad_threshold_catches_what_slow_factor_misses():
    """With realistic per-step jitter the MAD model flags a 1.3x host that
    the 1.5x multiplicative fallback would tolerate."""
    cfg = StragglerConfig(window=32, slow_factor=1.5, mad_factor=5.0,
                          tolerate=3, evict_after=50)
    hosts = ["h0", "h1", "h2", "h3"]
    wd = StragglerWatchdog(cfg, hosts)
    actions = []
    for i in range(8):
        jitter = 0.01 * ((i * 7) % 5 - 2) / 2.0
        t = {h: 1.0 + jitter for h in hosts}
        if i >= 2:
            t["h3"] = 1.3 + jitter             # < slow_factor * median
        actions.append(wd.observe_step(t)["h3"])
    thr = wd._threshold()
    assert 0 < thr < 1.3, thr                  # MAD path, below the outlier
    assert thr < 1.5                           # tighter than the fallback
    assert "rebalance" in actions, actions


def test_mad_zero_falls_back_to_slow_factor():
    """A degenerate window (every sample identical) must keep the old
    multiplicative behavior: 1.4x tolerated, 1.6x struck."""
    cfg = StragglerConfig(window=16, slow_factor=1.5, tolerate=2,
                          evict_after=50)
    hosts = ["h0", "h1", "h2", "h3"]
    wd = StragglerWatchdog(cfg, hosts)
    for _ in range(4):
        acts = wd.observe_step({"h0": 1.0, "h1": 1.0, "h2": 1.0, "h3": 1.4})
    assert acts["h3"] == "none"
    wd2 = StragglerWatchdog(cfg, hosts)
    for _ in range(4):
        acts = wd2.observe_step({"h0": 1.0, "h1": 1.0, "h2": 1.0, "h3": 1.6})
    assert acts["h3"] == "rebalance"


def test_batch_rebalancer_shrink_floor_and_replan():
    calls = []
    rb = BatchRebalancer({"h0": 8, "h1": 8}, min_share=2,
                         replan=lambda h, s: calls.append((h, s)) or s)
    assert rb.shrink("h1") == 4 and rb.shrink("h1") == 2
    assert rb.shrink("h1") == 2                # floored: no replan call
    assert calls == [("h1", 4), ("h1", 2)]
    assert rb.last_replan["h1"] == 2
    assert rb.total() == 10 and rb.shrunk["h1"] == 2
    rb.drop("h1")
    assert rb.total() == 8 and rb.shrink("h1") == 0
    assert rb.shrink("nope") == 0              # unknown host is a no-op


def test_watchdog_mitigate_rebalance_then_replace():
    """The actions become real through the hooks: rebalance shrinks the
    share (and resets strikes), replace drives on_replace + eviction."""
    replaced = []
    hosts = ["h0", "h1", "h2", "h3"]
    rb = BatchRebalancer({h: 4 for h in hosts})
    cfg = StragglerConfig(window=32, slow_factor=1.5, tolerate=2,
                          evict_after=4, hot_spares=1)
    wd = StragglerWatchdog(cfg, hosts, rebalancer=rb,
                           on_replace=lambda h: replaced.append(h) or "ok")
    outcomes = []
    for _ in range(16):
        t = {h: 1.0 for h in hosts}
        t["h3"] = 3.0
        outcomes.append(wd.step(t))
        if "h3" not in wd.hosts:
            break
    acted = [o["h3"]["action"] for o in outcomes if "h3" in o]
    assert "rebalance" in acted and acted[-1] == "replace", acted
    assert rb.shrunk["h3"] >= 2                 # shrunk to the floor first
    assert "h3" not in rb.shares                # dropped on replace
    assert replaced == ["h3"]
    assert "h3" in wd.evicted and "spare_0" in wd.hosts
    assert [m["action"] for m in wd.mitigations] == acted


# ---------------------------------------------------------------------------
# Supervisor lifecycle: handler restore + no double save + plan snapshot
# ---------------------------------------------------------------------------


def test_supervisor_restores_previous_sigterm_handler(tmp_path):
    sentinel = lambda *_: None                  # noqa: E731
    prev = signal.signal(signal.SIGTERM, sentinel)
    try:
        with Supervisor(FTConfig(ckpt_dir=str(tmp_path)),
                        {"x": np.zeros(())}) as sup:
            assert signal.getsignal(signal.SIGTERM) == sup._on_sigterm
        assert signal.getsignal(signal.SIGTERM) is sentinel
        sup.close()                             # idempotent
        assert signal.getsignal(signal.SIGTERM) is sentinel
    finally:
        signal.signal(signal.SIGTERM, prev)


def test_supervisor_no_double_save_on_boundary_preemption(tmp_path):
    """Preemption landing exactly on a ckpt_every boundary saves once."""
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=3,
                   handle_sigterm=False, plan_snapshot=False)
    sup = Supervisor(cfg, {"x": np.zeros((), np.int64)})

    def on_step(step, _state):
        if step == 6:                           # boundary: 6 % 3 == 0
            sup._on_sigterm()
    final = sup.run({"x": np.zeros((), np.int64)}, 0, 20, _counter_step,
                    on_step=on_step)
    assert sup.preempted
    assert int(final["x"]) == sum(range(1, 7))
    assert sup.save_count == 2                  # steps 3 and 6 — 6 once
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 6


def test_supervisor_checkpoint_carries_plan_snapshot(tmp_path):
    """Saved checkpoints embed the tuned-plan snapshot and resume() pre-
    warms the autotune chain from it under the *current* cache path."""
    from repro.core import autotune

    cache_a = str(tmp_path / "cache_a.json")
    cache_b = str(tmp_path / "cache_b.json")
    key = "ff_fake|TPUv5e|float32|fmt%d|meshsingle|dev1||tile..." \
        % autotune.PLAN_FORMAT_VERSION
    rec = {"tile": [128, 128], "depth": 2, "streams": 1,
           "mesh": "single", "ms": 0.5}
    cfg = FTConfig(ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=2,
                   handle_sigterm=False)
    autotune.tuned_cache_clear()
    try:
        with autotune.tuning_config(cache_path=cache_a):
            autotune._MEM[(autotune.cache_path(), key)] = rec
            sup = Supervisor(cfg, {"x": np.zeros((), np.int64)})
            sup.run({"x": np.zeros((), np.int64)}, 0, 2, _counter_step)
        # "restarted on another host": fresh caches, different cache path
        autotune.tuned_cache_clear()
        with autotune.tuning_config(cache_path=cache_b):
            sup2 = Supervisor(cfg, {"x": np.zeros((), np.int64)})
            _state, start = sup2.resume()
            assert start == 2
            assert sup2.resume_prewarmed >= 1
            assert autotune._MEM[(autotune.cache_path(), key)]["ms"] == 0.5
    finally:
        autotune.tuned_cache_clear()
