"""Kernel-level benchmark: modeled TPU-v5e time per ff_* kernel call from
each kernel's exact tile-schedule cost model (the CPU container cannot
time real TPU kernels), plus modeled FF-vs-baseline and M2C2 deltas."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import TPU_V5E, Pipe, Workload, estimate_baseline, \
    estimate_feedforward
from repro.kernels.ff_attention import attention_cost
from repro.kernels.ff_chunk_scan import chunk_scan_cost
from repro.kernels.ff_decode_attention import decode_attention_cost
from repro.kernels.ff_gather import gather_cost
from repro.kernels.ff_matmul import matmul_cost

CASES = [
    ("ff_matmul/4096", matmul_cost(4096, 4096, 4096, dtype=jnp.bfloat16),
     True, 128 * 128 * 2 * 2),
    ("ff_attention/prefill8k", attention_cost(32, 8192, 128), True,
     128 * 128 * 2 * 2),
    ("ff_decode_attention/32k", decode_attention_cost(8, 64, 8, 32768, 128),
     True, 128 * 128 * 2 * 2),
    ("ff_chunk_scan/mamba4k", chunk_scan_cost(64, 4096, 64, 64), True,
     64 * (3 * 64 + 64) * 2),
    ("ff_gather/1M", gather_cost(1 << 20, 512), False, 8 * 512 * 4),
]


def rows():
    out = []
    for name, cost, regular, word_bytes in CASES:
        n_words = max(int(cost.hbm_bytes / word_bytes), 1)
        w = Workload(n_words=n_words, word_bytes=word_bytes,
                     flops_per_word=cost.flops / n_words, regular=regular)
        base = estimate_baseline(w, TPU_V5E)
        ff = estimate_feedforward(w, TPU_V5E, Pipe(tile=(8, 128), depth=4))
        m2c2 = estimate_feedforward(w, TPU_V5E,
                                    Pipe(tile=(8, 128), depth=4, streams=2))
        out.append({
            "name": name,
            "us_per_call": ff.total_s * 1e6,
            "ff_speedup": base.total_s / ff.total_s,
            "m2c2_extra": ff.total_s / m2c2.total_s,
            "hbm_gb": cost.hbm_bytes / 1e9,
            "gflops": cost.flops / 1e9,
            "bottleneck": ff.bottleneck,
            "vmem_kib": cost.vmem_bytes / 1024,
        })
    return out


def main():
    print("# Kernel suite: modeled v5e time per call (tile-schedule costs)")
    print("name,us_per_call,derived")
    for r in rows():
        print(f"kernels/{r['name']},{r['us_per_call']:.1f},"
              f"ff={r['ff_speedup']:.2f}x_m2c2+{(r['m2c2_extra']-1)*100:.0f}%"
              f"_{r['bottleneck']}")
        print(f"#  {r['name']:28s} {r['gflops']:9.1f} GF "
              f"{r['hbm_gb']:7.2f} GB  vmem {r['vmem_kib']:6.0f} KiB")


if __name__ == "__main__":
    main()
