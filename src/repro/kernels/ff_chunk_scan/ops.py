"""Public op wrapper + cost model for ff_chunk_scan."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.emitter import cdiv, pad_to
from repro.core.pipeline_model import Workload
from repro.core.program import PipePolicy, make_entrypoint
from repro.kernels.ff_chunk_scan.kernel import build_program, chunk_scan_ff
from repro.kernels.ff_chunk_scan.ref import chunk_scan_ref, chunk_scan_xla
from repro.kernels.registry import KernelCost, register_kernel


def chunk_scan_cost(bh: int, s: int, n: int, p: int, *, chunk: int = 64,
                    depth: int = 2, dtype=jnp.bfloat16) -> KernelCost:
    nc = max(s // chunk, 1)
    # per chunk: inter [L,N]@[N,P], intra ~L^2(N+P)/2, state [N,L]@[L,P]
    per_chunk = 2.0 * chunk * n * p * 2 + chunk * chunk * (n + p)
    itemsize = jnp.dtype(dtype).itemsize
    hbm = bh * s * (3 * n + 2 * p) * itemsize     # q,k,w in; v in; y out
    vmem = depth * chunk * (3 * n + p) * itemsize + n * p * 4
    return KernelCost(flops=bh * nc * per_chunk, hbm_bytes=float(hbm),
                      vmem_bytes=vmem)


def chunk_scan_workload(bh: int, s: int, n: int, p: int, *, chunk: int = 64,
                        dtype=jnp.bfloat16) -> Tuple[Workload, Tuple[int, int]]:
    """One word per (bh, chunk): q/k/w [L,N] and v [L,P] tiles. The chunk-
    boundary state is the DLCD — carried in the consumer, so the streams
    pipeline at full depth regardless (the paper's Fig. 3 move)."""
    itemsize = jnp.dtype(dtype).itemsize
    nc = max(cdiv(s, chunk), 1)
    per_chunk = 2.0 * chunk * n * p * 2 + chunk * chunk * (n + p)
    w = Workload(
        n_words=bh * nc,
        word_bytes=float(chunk * (3 * n + p) * itemsize),
        flops_per_word=per_chunk,
        regular=True,
        dlcd_cycles=2.0 * n,      # h update chain per chunk, consumer-side
        store_bytes_per_word=float(chunk * p * itemsize),
    )
    return w, (chunk, n)


# chunk-length candidates for mode="autotune": the pipe word is a whole
# chunk, so this trades word size against the number of carried-state steps
_TILE_OPTIONS = (
    {"chunk": 32},
    {"chunk": 128},
    {"chunk": 256},
)


def _apply(q, k, v, log_w, u=None, *, chunk: int = 64, subtile: int = 16,
           inclusive: bool = True, policy: PipePolicy):
    """Gated linear-attention scan over [BH, S, *] streams.

    policy.mode="ff"|"autotune"(measured plan)|"baseline"(depth=1)|
    "ref"(naive scan)|"xla"|"xla_tiled" (chunked, HLO-visible; _tiled =
    tile-pair factorized intra-chunk).
    Pads S up to a chunk multiple (decay 1, zero k/v contribute nothing).
    """
    if policy.mode == "ref":
        return chunk_scan_ref(q, k, v, log_w, u, inclusive=inclusive)
    if policy.mode in ("xla", "xla_tiled"):
        s = q.shape[1]
        qp, kp, vp = (pad_to(x, chunk, 1) for x in (q, k, v))
        lwp = pad_to(log_w, chunk, 1)
        return chunk_scan_xla(qp, kp, vp, lwp, u, chunk=chunk,
                              inclusive=inclusive,
                              tiled=policy.mode == "xla_tiled")[:, :s]
    bh, s, n = q.shape
    p = v.shape[2]

    def _run(ck, depth, streams):
        st = min(subtile, ck)
        if ck % st != 0:
            raise ValueError(f"chunk={ck} not a multiple of subtile={st}")
        qp, kp, vp = (pad_to(x, ck, 1) for x in (q, k, v))
        lwp = pad_to(log_w, ck, 1)
        return chunk_scan_ff(qp, kp, vp, lwp, u, chunk=ck, subtile=st,
                             inclusive=inclusive, depth=depth,
                             streams=streams, interpret=policy.interpret)

    w, tile = chunk_scan_workload(bh, s, n, p, chunk=chunk, dtype=q.dtype)
    arrays = (q, k, v, log_w) + (() if u is None else (u,))
    choice = autotune.resolve_call(
        "ff_chunk_scan", policy, workload=w, tile=tile, dtype=q.dtype,
        workload_fn=lambda tk: chunk_scan_workload(
            bh, s, n, p, chunk=tk.get("chunk", chunk), dtype=q.dtype),
        runner=None if autotune.has_tracers(*arrays) else
        lambda tk, dep, st: lambda: _run(tk.get("chunk", chunk), dep, st),
        tile_options=_TILE_OPTIONS,
        # statics outside the Workload that change the measured kernel
        extra_key=f"subtile={subtile}|inclusive={int(inclusive)}"
                  f"|u={int(u is not None)}",
        site={"bh": bh, "s": s, "n": n, "p": p, "chunk": chunk,
              "subtile": subtile, "inclusive": inclusive,
              "has_u": u is not None},
        site_dynamic=("bh", "s"))
    out = _run(choice.tile_kwargs.get("chunk", chunk), choice.depth,
               choice.streams)
    return out[:, :s]


chunk_scan = make_entrypoint(
    "ff_chunk_scan", _apply,
    modes=("ff", "baseline", "ref", "autotune", "xla", "xla_tiled"))


def _make_inputs(key):
    bh, s, n, p = 2, 128, 16, 32
    q = 0.5 * jax.random.normal(key, (bh, s, n), jnp.float32)
    k = 0.5 * jax.random.normal(jax.random.fold_in(key, 1), (bh, s, n),
                                jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, p), jnp.float32)
    lw = -0.5 * jnp.exp(jax.random.normal(jax.random.fold_in(key, 3),
                                          (bh, s, n)))
    return (q, k, v, lw), {"chunk": 64, "subtile": 16, "inclusive": True}


def _sweep_inputs(key, site):
    # rebuild concrete operands at a recorded call-site shape (plan sweep)
    bh, s = int(site["bh"]), int(site["s"])
    n, p = int(site["n"]), int(site["p"])
    dt = jnp.dtype(site.get("dtype", "float32"))
    q = 0.5 * jax.random.normal(key, (bh, s, n), dt)
    k = 0.5 * jax.random.normal(jax.random.fold_in(key, 1), (bh, s, n), dt)
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, p), dt)
    lw = -0.5 * jnp.exp(jax.random.normal(jax.random.fold_in(key, 3),
                                          (bh, s, n), dt))
    args = (q, k, v, lw)
    if site.get("has_u"):
        args += (jax.random.normal(jax.random.fold_in(key, 4),
                                   (bh, s, p), dt),)
    return args, {"chunk": int(site.get("chunk", 64)),
                  "subtile": int(site.get("subtile", 16)),
                  "inclusive": bool(site.get("inclusive", True))}


def _smoke_program(*, depth: int = 2, streams: int = 1, tile=None):
    # the smoke shape point of _make_inputs
    chunk = (tile or {}).get("chunk", 64)
    return build_program(2, 128, 16, 32, chunk=chunk,
                         subtile=min(16, chunk),
                         inclusive=True, has_u=False, dtype=jnp.float32,
                         depth=depth, streams=streams)


register_kernel(
    name="ff_chunk_scan",
    alias="chunk_scan",
    op=chunk_scan,
    ref=chunk_scan_ref,
    cost=chunk_scan_cost,
    workload=chunk_scan_workload,
    program=_smoke_program,
    make_inputs=_make_inputs,
    bench_kwargs={"bh": 64, "s": 4096, "n": 64, "p": 64,
                  "dtype": jnp.bfloat16},
    tile_options=_TILE_OPTIONS,
    regular=True,
    tol=1e-3,
    doc="gated linear-attention scan (Mamba2 / RWKV6)",
    shard_dims=(0, 0, 0, 0),     # head-batch dim data-parallel
    shard_out_dim=0,
    sweep_inputs=_sweep_inputs,
)
