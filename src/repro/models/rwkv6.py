"""RWKV6 ("Finch") — attention-free, data-dependent decay.

Per layer: a time-mixing block whose wkv operator is the *exclusive* gated
linear-attention scan with a per-channel data-dependent decay w_t and a
current-token bonus u (both the paper-relevant DLCD and the assignment's
"data-dependent decay"), plus a channel-mixing (squared-ReLU) FFN. Token
shift uses the static per-channel lerp plus a low-rank data-dependent term
for the decay, following the RWKV6 design (per-component LoRA mixers are
reduced to the decay path; noted in DESIGN.md).

Applicability note (DESIGN.md §Arch-applicability): rwkv6 has no attention
operator, so ff_attention does not apply; the feed-forward technique applies
to the wkv scan via ff_chunk_scan (exclusive mode + u bonus).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.ff_chunk_scan import chunk_scan
from repro.models import layers as L
from repro.runtime.sharding import constrain

_DECAY_LORA = 64


def _dims(cfg: ArchConfig):
    hd = cfg.ssm_head_dim or 64
    return cfg.d_model // hd, hd     # (n_heads, head_dim)


def time_mix_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    nh, hd = _dims(cfg)
    return {
        "mu_r": L.ParamSpec((d,), ("embed",), init="small"),
        "mu_k": L.ParamSpec((d,), ("embed",), init="small"),
        "mu_v": L.ParamSpec((d,), ("embed",), init="small"),
        "mu_w": L.ParamSpec((d,), ("embed",), init="small"),
        "mu_g": L.ParamSpec((d,), ("embed",), init="small"),
        "wr": L.ParamSpec((d, d), ("embed", "heads")),
        "wk": L.ParamSpec((d, d), ("embed", "heads")),
        "wv": L.ParamSpec((d, d), ("embed", "heads")),
        "wg": L.ParamSpec((d, d), ("embed", "heads")),
        "w0": L.ParamSpec((d,), ("heads",), init="small"),
        "w_lora_a": L.ParamSpec((d, _DECAY_LORA), ("embed", None), init="small"),
        "w_lora_b": L.ParamSpec((_DECAY_LORA, d), (None, "heads"), init="small"),
        "u": L.ParamSpec((nh, hd), ("ssm_heads", None), init="small"),
        "ln_w": L.ParamSpec((d,), ("heads",), init="ones"),
        "ln_b": L.ParamSpec((d,), ("heads",), init="zeros"),
        "wo": L.ParamSpec((d, d), ("heads", "embed")),
    }


def channel_mix_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": L.ParamSpec((d,), ("embed",), init="small"),
        "mu_r": L.ParamSpec((d,), ("embed",), init="small"),
        "wk": L.ParamSpec((d, f), ("embed", "mlp")),
        "wv": L.ParamSpec((f, d), ("mlp", "embed")),
        "wr": L.ParamSpec((d, d), ("embed", None)),
    }


def _shift(x, prev: Optional[jnp.ndarray]):
    """Token shift: x_{t-1} (zeros / carried state at t=0).
    x: [B,S,D]; prev: [B,D] or None. Returns (shifted, new_prev)."""
    if x.shape[1] == 1 and prev is not None:
        return prev[:, None, :], x[:, -1, :]
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1), x[:, -1, :]


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu[None, None, :].astype(x.dtype)


def time_mix_apply(cfg: ArchConfig, p, x, *, cache=None
                   ) -> Tuple[jnp.ndarray, Dict]:
    b, s, d = x.shape
    nh, hd = _dims(cfg)
    prev = cache["shift_tm"] if cache is not None else None
    x_prev, new_prev = _shift(x, prev)

    r = _lerp(x, x_prev, p["mu_r"]) @ p["wr"].astype(x.dtype)
    k = _lerp(x, x_prev, p["mu_k"]) @ p["wk"].astype(x.dtype)
    v = _lerp(x, x_prev, p["mu_v"]) @ p["wv"].astype(x.dtype)
    g = _lerp(x, x_prev, p["mu_g"]) @ p["wg"].astype(x.dtype)
    xw = _lerp(x, x_prev, p["mu_w"])
    w_dd = jnp.tanh(xw @ p["w_lora_a"].astype(x.dtype)) @ \
        p["w_lora_b"].astype(x.dtype)
    # log decay, guaranteed < 0: w = exp(-exp(w0 + lora)). Carried in the
    # compute dtype across sharding boundaries (§Perf rwkv6 it6); the scan
    # re-upcasts for its f32 cumsum.
    log_w = -jnp.exp(jnp.clip(
        p["w0"][None, None, :].astype(jnp.float32) + w_dd.astype(jnp.float32),
        -8.0, 8.0))
    log_w = log_w.astype(x.dtype)                                 # [B,S,D]

    def heads(t):
        return t.reshape(b, s, nh, hd).transpose(0, 2, 1, 3) \
            .reshape(b * nh, s, hd)

    u = jnp.broadcast_to(p["u"][None], (b, nh, hd)).reshape(b * nh, hd)

    if cache is None or x.shape[1] > 1:
        mode = cfg.scan_impl if cfg.scan_impl in ("xla", "xla_tiled", "ff") \
            else "xla"
        y = chunk_scan(heads(r), heads(k), heads(v), heads(log_w),
                       u, inclusive=False, chunk=cfg.scan_chunk,
                       policy=L._session_scan_policy(mode))
        # final state for prefill->decode handoff (low-precision operands,
        # f32 accumulation)
        lw = heads(log_w).astype(jnp.float32)
        cw = jnp.cumsum(lw, axis=1)
        k2 = heads(k) * jnp.exp(cw[:, -1:, :] - cw).astype(x.dtype)
        h_new = jnp.einsum("bsn,bsp->bnp", k2, heads(v),
                           preferred_element_type=jnp.float32)
        if cache is not None and "h" in cache:
            # prefill on top of existing state: decay it through the window
            h_new = h_new + jnp.exp(cw[:, -1, :])[:, :, None] * cache["h"]
    else:
        h = cache["h"]                                            # [B*NH,N,P]
        rr, kk, vv = heads(r)[:, 0], heads(k)[:, 0], heads(v)[:, 0]
        lw = heads(log_w)[:, 0].astype(jnp.float32)
        kv = kk[:, :, None].astype(jnp.float32) * vv[:, None, :]
        y = jnp.einsum("bn,bnp->bp",
                       rr.astype(jnp.float32),
                       h + u[:, :, None] * kv)[:, None, :].astype(x.dtype)
        h_new = jnp.exp(lw)[:, :, None] * h + kv
        y = y.reshape(b * nh, 1, hd)

    y = y.reshape(b, nh, s, hd).transpose(0, 2, 1, 3).reshape(b, s, d)
    # per-head group norm
    y = y.reshape(b, s, nh, hd)
    y = (y - jnp.mean(y, axis=-1, keepdims=True)) * jax.lax.rsqrt(
        jnp.var(y, axis=-1, keepdims=True) + 64e-5)
    y = y.reshape(b, s, d) * p["ln_w"].astype(x.dtype) + \
        p["ln_b"].astype(x.dtype)
    y = y * jax.nn.silu(g)
    y = constrain(y, ("batch", "seq", "heads"))
    out = y @ p["wo"].astype(x.dtype)
    return out, {"shift_tm": new_prev, "h": h_new}


def channel_mix_apply(cfg: ArchConfig, p, x, *, cache=None
                      ) -> Tuple[jnp.ndarray, Dict]:
    prev = cache["shift_cm"] if cache is not None else None
    x_prev, new_prev = _shift(x, prev)
    xk = _lerp(x, x_prev, p["mu_k"])
    xr = _lerp(x, x_prev, p["mu_r"])
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    k = constrain(k, ("batch", "seq", "mlp"))
    kv = k @ p["wv"].astype(x.dtype)
    out = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) * kv
    return out, {"shift_cm": new_prev}


def rwkv_cache_spec(cfg: ArchConfig, batch: int):
    nh, hd = _dims(cfg)
    spec = {
        "shift_tm": jax.ShapeDtypeStruct((batch, cfg.d_model), cfg.cdtype),
        "shift_cm": jax.ShapeDtypeStruct((batch, cfg.d_model), cfg.cdtype),
        "h": jax.ShapeDtypeStruct((batch * nh, hd, hd), jnp.float32),
    }
    axes = {"shift_tm": ("batch", "embed"), "shift_cm": ("batch", "embed"),
            "h": ("ssm_heads", "state", None)}
    return spec, axes
