import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimb driver (§Perf): re-lowers a cell with one cfg/rule change
per iteration and reports the roofline-term deltas vs. the recorded
baseline.

Usage:
  PYTHONPATH=src python experiments/hillclimb.py --cell qwen2_72b:train_4k \
      --tag it1_losschunk --patch loss_chunk=8
  PYTHONPATH=src python experiments/hillclimb.py --cell qwen2_72b:train_4k \
      --tag it2_seqsp --rule seq_sp=model --patch loss_chunk=8
"""

import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import get_config  # noqa: E402
from repro.launch import dryrun  # noqa: E402
from repro.launch.roofline import analyze_cell  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "dryrun")


def parse_val(v: str):
    if v in ("True", "False"):
        return v == "True"
    try:
        return int(v)
    except ValueError:
        try:
            return float(v)
        except ValueError:
            return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--patch", nargs="*", default=[], help="k=v cfg fields")
    ap.add_argument("--rule", nargs="*", default=[],
                    help="k=v logical-rule overrides (v='None' clears)")
    ap.add_argument("--mesh", default=None,
                    help="axis=size,... mesh refactor (same chip count)")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    mesh_axes = None
    if args.mesh:
        mesh_axes = tuple((kv.split("=")[0], int(kv.split("=")[1]))
                          for kv in args.mesh.split(","))

    patch = {k: parse_val(v) for k, v in (p.split("=", 1) for p in args.patch)}
    if args.rule:
        rules = dict(get_config(arch).rule_overrides or {})
        for r in args.rule:
            k, v = r.split("=", 1)
            rules[k] = (None if v == "None"
                        else tuple(v.split("+")) if "+" in v else v)
        patch["rule_overrides"] = rules

    r = dryrun.run_cell(arch, shape, multi_pod=False, cfg_patch=patch,
                        tag="__" + args.tag, out_dir=OUT,
                        mesh_axes=mesh_axes)
    if not r.get("ok"):
        print("FAILED:", r.get("error"))
        print(r.get("traceback", "")[-1500:])
        raise SystemExit(1)

    base_path = os.path.join(OUT, f"{arch}__{shape}__pod16x16.json")
    with open(base_path) as f:
        base = json.load(f)
    a0, a1 = analyze_cell(base), analyze_cell(r)
    print(f"{'term':14s} {'baseline':>12s} {'variant':>12s} {'delta':>8s}")
    for key, label in (("t_compute_s", "compute s"), ("t_memory_s", "memory s"),
                       ("t_collective_s", "collective s"),
                       ("peak_hbm_gib", "peak HBM GiB"),
                       ("useful_ratio", "useful/HLO"),
                       ("roofline_fraction", "roofline frac")):
        b, v = a0[key], a1[key]
        d = (v - b) / b * 100 if b else float("nan")
        print(f"{label:14s} {b:12.4f} {v:12.4f} {d:+7.1f}%")
    print(f"bottleneck: {a0['bottleneck']} -> {a1['bottleneck']}")


if __name__ == "__main__":
    main()
