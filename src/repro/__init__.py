"""repro: feed-forward (decoupled access/execute) design model for JAX/TPU.

Reproduction + extension of "Enabling The Feed-Forward Design Model in
OpenCL Using Pipes" (Eghbali Zarch & Becchi, PACT'22) as a production-grade
multi-pod training/serving framework. See DESIGN.md.
"""

__version__ = "0.1.0"
