"""Oracles for ff_chunk_scan.

``chunk_scan_ref``      — naive per-timestep scan (the ground truth).
``chunk_scan_xla``      — scalable pure-XLA chunked formulation with an
                          associative scan across chunk boundaries; used in
                          the model graphs (dry-run / CPU paths) because it
                          is HLO-visible (cost analysis) and log-depth.
Both implement:
    h_t = diag(w_t) h_{t-1} + k_t (x) v_t
    inclusive:  y_t = q_t . h_t
    exclusive:  y_t = q_t . (h_{t-1} + diag(u) k_t (x) v_t)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def chunk_scan_ref(q, k, v, log_w, u=None, *, inclusive: bool = True):
    """Naive scan. q,k,log_w: [BH,S,N]; v: [BH,S,P]; u: [BH,N] or None."""
    q, k, v = (x.astype(jnp.float32) for x in (q, k, v))
    lw = jnp.minimum(log_w.astype(jnp.float32), 0.0)
    bh, s, n = q.shape
    p = v.shape[2]

    def step(h, xs):
        qt, kt, vt, lwt = xs
        kv = kt[:, :, None] * vt[:, None, :]            # [BH,N,P]
        h_new = jnp.exp(lwt)[:, :, None] * h + kv
        if inclusive:
            y = jnp.einsum("bn,bnp->bp", qt, h_new)
        else:
            eff = h + (u[:, :, None] * kv if u is not None else 0.0)
            y = jnp.einsum("bn,bnp->bp", qt, eff)
        return h_new, y

    h0 = jnp.zeros((bh, n, p), jnp.float32)
    xs = (jnp.swapaxes(q, 0, 1), jnp.swapaxes(k, 0, 1),
          jnp.swapaxes(v, 0, 1), jnp.swapaxes(lw, 0, 1))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.swapaxes(ys, 0, 1).astype(q.dtype)


def _intra_chunk(q, k, v, lw, u, inclusive):
    """Exact pairwise intra-chunk term. q,k,lw: [..., L, N]; v: [..., L, P]."""
    cw = jnp.cumsum(lw, axis=-2)
    e = cw[..., :, None, :] - cw[..., None, :, :]       # [..., L, L, N]
    if not inclusive:
        e = e - lw[..., :, None, :]
    e = jnp.minimum(e, 0.0)
    a = jnp.einsum("...tn,...tsn,...sn->...ts", q, jnp.exp(e), k)
    L = q.shape[-2]
    rows = jnp.arange(L)[:, None]
    cols = jnp.arange(L)[None, :]
    keep = (rows >= cols) if inclusive else (rows > cols)
    a = jnp.where(keep, a, 0.0)
    y = jnp.einsum("...ts,...sp->...tp", a, v)
    if u is not None and not inclusive:
        c = jnp.sum(q * u[..., None, :] * k, axis=-1, keepdims=True)
        y = y + c * v
    return y, cw


def _intra_chunk_tiled(q, k, v, lw, u, inclusive, subtile: int = 16,
                       compute_dtype=None):
    """Tile-pair intra-chunk term (the kernel's factorization, vectorized):
    never materializes the [L, L, N] pairwise-decay tensor — only [T, T, N]
    diagonal tiles (T=16) and [T, prefix] matmul scores. All decay exponents
    are <= 0 ("decay-to-boundary"), so f32-stable. §Perf 'tiled chunk scan'.
    ``compute_dtype``: operand dtype for the matmuls (decay-scaled operands
    cast down, f32 accumulation) — §Perf it3 'bf16 scan operands'.

    q,k,lw: [..., L, N]; v: [..., L, P]. Returns (y, cw) like _intra_chunk.
    """
    L, n = q.shape[-2], q.shape[-1]
    p = v.shape[-1]
    t = subtile
    nt = L // t
    cw = jnp.cumsum(lw, axis=-2)
    cd = compute_dtype or q.dtype

    # diagonal tiles: exact pairwise within each T-tile
    def tiles(x):
        return x.reshape(*x.shape[:-2], nt, t, x.shape[-1])

    qt, kt, vt, lwt, cwt = map(tiles, (q, k, v, lw, cw))
    e = cwt[..., :, None, :] - cwt[..., None, :, :]      # [..., nt, T, T, N]
    if not inclusive:
        e = e - lwt[..., :, None, :]
    e = jnp.minimum(e, 0.0)
    a = jnp.einsum("...tn,...tsn,...sn->...ts", qt.astype(cd),
                   jnp.exp(e).astype(cd), kt.astype(cd),
                   preferred_element_type=jnp.float32)
    rows = jnp.arange(t)[:, None]
    cols = jnp.arange(t)[None, :]
    a = jnp.where((rows >= cols) if inclusive else (rows > cols), a, 0.0)
    y = jnp.einsum("...ts,...sp->...tp", a.astype(cd), vt.astype(cd),
                   preferred_element_type=jnp.float32)   # [..., nt, T, P]
    y = y.reshape(*q.shape[:-2], L, p)

    # cross-tile pairs via boundary-factorized matmuls
    for i in range(1, nt):
        t0 = i * t
        cwb = cw[..., t0 - 1, :]                         # [..., N]
        q_exp = cw[..., t0:t0 + t, :] - cwb[..., None, :]
        if not inclusive:
            q_exp = q_exp - lw[..., t0:t0 + t, :]
        q_i = (q[..., t0:t0 + t, :] * jnp.exp(q_exp)).astype(cd)
        k_pre = (k[..., :t0, :] *
                 jnp.exp(cwb[..., None, :] - cw[..., :t0, :])).astype(cd)
        scores = jnp.einsum("...tn,...sn->...ts", q_i, k_pre,
                            preferred_element_type=jnp.float32)
        y_i = jnp.einsum("...ts,...sp->...tp", scores.astype(cd),
                         v[..., :t0, :].astype(cd),
                         preferred_element_type=jnp.float32)
        y = y.at[..., t0:t0 + t, :].add(y_i)

    if u is not None and not inclusive:
        c = jnp.sum(q * u[..., None, :] * k, axis=-1, keepdims=True)
        y = y + c * v
    return y, cw


def chunk_scan_xla(q, k, v, log_w, u=None, *, chunk: int = 64,
                   inclusive: bool = True, tiled: bool = False):
    """Chunked formulation, fully vectorized; associative scan over chunks.

    Same signature as chunk_scan_ref. S must be a multiple of ``chunk``
    (callers pad with log_w=0, k=v=0). ``tiled=True`` uses the tile-pair
    intra-chunk factorization (O(S*T*N) live memory instead of O(S*L*N)).
    """
    orig_dtype = q.dtype
    q, k, v = (x.astype(jnp.float32) for x in (q, k, v))
    lw = jnp.minimum(log_w.astype(jnp.float32), 0.0)
    bh, s, n = q.shape
    p = v.shape[2]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    qc = q.reshape(bh, c, chunk, n)
    kc = k.reshape(bh, c, chunk, n)
    vc = v.reshape(bh, c, chunk, p)
    lwc = lw.reshape(bh, c, chunk, n)

    uc = u[:, None, :].astype(jnp.float32) if u is not None else None
    cd = orig_dtype if tiled else jnp.float32   # bf16 operands (f32 accum)
    if tiled:
        y_intra, cw = _intra_chunk_tiled(qc, kc, vc, lwc, uc, inclusive,
                                         compute_dtype=cd)
    else:
        y_intra, cw = _intra_chunk(qc, kc, vc, lwc, uc, inclusive)

    # per-chunk transition: h' = diag(D) h + S
    d_c = jnp.exp(cw[..., -1, :])                                   # [bh,c,n]
    k2 = (kc * jnp.exp(cw[..., -1:, :] - cw)).astype(cd)             # <= 0
    s_c = jnp.einsum("bcln,bclp->bcnp", k2, vc.astype(cd),
                     preferred_element_type=jnp.float32)             # [bh,c,n,p]

    def combine(a, b):
        d1, s1 = a
        d2, s2 = b
        return d2 * d1, d2[..., None] * s1 + s2

    # scan over the chunk axis (moved to front for associative_scan)
    d_s = jnp.moveaxis(d_c, 1, 0)                                    # [c,bh,n]
    s_s = jnp.moveaxis(s_c, 1, 0)                                    # [c,bh,n,p]
    d_acc, s_acc = jax.lax.associative_scan(combine, (d_s, s_s))
    h_after = jnp.moveaxis(s_acc, 0, 1)                              # [bh,c,n,p]
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_after[:, :1]), h_after[:, :-1]], axis=1)

    q_decay = cw if inclusive else cw - lwc
    qd = (qc * jnp.exp(q_decay)).astype(cd)
    y_inter = jnp.einsum("bcln,bcnp->bclp", qd, h_prev.astype(cd),
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(bh, s, p)
    return y.astype(orig_dtype)
