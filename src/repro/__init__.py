"""repro: feed-forward (decoupled access/execute) design model for JAX/TPU.

Reproduction + extension of "Enabling The Feed-Forward Design Model in
OpenCL Using Pipes" (Eghbali Zarch & Becchi, PACT'22) as a production-grade
multi-pod training/serving framework. See DESIGN.md.

Public API surface (lazily imported, so ``import repro`` stays cheap):

  repro.ops.<name>(...)       registry-generated kernel entrypoints
                              (matmul, attention, decode_attention,
                              chunk_scan, gather, ...)
  repro.PipePolicy            the unified pipe policy dataclass
  repro.policy(...)           session-default policy context manager
  repro.current_policy()      the active policy
  repro.MeshSpec              hashable mesh topology (PipePolicy.mesh /
                              plan-cache key component)
  repro.plans                 fleet plan service: traffic recording,
                              offline sweeps, mergeable PlanDB artifacts
"""

__version__ = "0.1.0"

_LAZY = {
    "PipePolicy": ("repro.core.program", "PipePolicy"),
    "policy": ("repro.core.program", "policy"),
    "current_policy": ("repro.core.program", "current_policy"),
    "MeshSpec": ("repro.core.meshspec", "MeshSpec"),
    "ops": ("repro.ops", None),
    "plans": ("repro.plans", None),
    "obs": ("repro.obs", None),
}


def __getattr__(name):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    import importlib

    mod = importlib.import_module(mod_name)
    return mod if attr is None else getattr(mod, attr)


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
