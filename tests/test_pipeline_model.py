"""Property tests (hypothesis) for the analytic DAE pipeline model — the
paper's qualitative findings must hold as *theorems* of the model."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    ARRIA_CX,
    TPU_V5E,
    Pipe,
    Workload,
    estimate_baseline,
    estimate_feedforward,
    plan_pipe,
    speedup,
)

workloads = st.builds(
    Workload,
    n_words=st.integers(64, 4096),
    word_bytes=st.floats(64.0, 1 << 20),
    flops_per_word=st.floats(1.0, 1e8),
    regular=st.booleans(),
    divergence=st.floats(0.0, 2.0),
    dlcd_cycles=st.floats(0.0, 512.0),
    false_mlcd_ii=st.floats(0.0, 512.0),
)

hws = st.sampled_from([ARRIA_CX, TPU_V5E])


@given(workloads, hws)
@settings(max_examples=200, deadline=None)
def test_ff_never_slower_when_equally_provisioned(w, hw):
    """With the pipe provisioned to at least the baseline LSU's outstanding
    transactions (depth 17 -> 16 in flight), the FF design is never slower
    than the baseline beyond fill overhead (overlap can only help)."""
    base = estimate_baseline(w, hw)
    ff = estimate_feedforward(w, hw, Pipe(tile=(8, 128), depth=17))
    fill = hw.dma_latency_s + 17 * ff.t_mem_word_s
    assert ff.total_s <= base.total_s + fill + 1e-12


@given(workloads, hws)
@settings(max_examples=200, deadline=None)
def test_depth_insensitivity(w, hw):
    """Paper: 'channel depth does not significantly affect performance'.
    Regular streams amortize latency at any depth >= 2 (identical steady
    state); irregular streams improve monotonically with depth."""
    est = [estimate_feedforward(w, hw, Pipe(tile=(8, 128), depth=d))
           for d in (4, 8, 16)]
    word_times = [e.t_mem_word_s for e in est]
    if w.regular:
        assert max(word_times) - min(word_times) < 1e-15
    else:
        assert word_times[0] >= word_times[1] >= word_times[2] - 1e-18


@given(workloads, hws)
@settings(max_examples=200, deadline=None)
def test_false_mlcd_only_hurts_baseline(w, hw):
    """Removing the false MLCD is the FF speedup driver: baseline time is
    monotone in II, FF time is independent of it."""
    w_hi = Workload(**{**w.__dict__, "false_mlcd_ii": w.false_mlcd_ii + 300})
    pipe = Pipe(tile=(8, 128), depth=4)
    assert estimate_baseline(w_hi, hw).total_s >= \
        estimate_baseline(w, hw).total_s - 1e-12
    assert abs(estimate_feedforward(w_hi, hw, pipe).total_s -
               estimate_feedforward(w, hw, pipe).total_s) < 1e-12


@given(workloads, hws, st.integers(1, 4))
@settings(max_examples=200, deadline=None)
def test_streams_saturate(w, hw, s):
    """Aggregate bandwidth never exceeds the memory system peak, and
    irregular contention keeps multi-stream gains below linear."""
    bw1 = hw.stream_bandwidth(1, w.regular)
    bws = hw.stream_bandwidth(s, w.regular)
    eff = 1.0 if w.regular else hw.irregular_eff
    assert bws <= hw.hbm_bw * eff + 1e-6
    assert bws <= s * bw1 + 1e-6


@given(workloads)
@settings(max_examples=100, deadline=None)
def test_planner_respects_budget_and_improves(w):
    plan = plan_pipe(w, tile=(128, 128), dtype="float32")
    assert plan.pipe.vmem_bytes <= 96 * 1024 * 1024
    base = estimate_baseline(w, TPU_V5E)
    # steady state no worse than 1.5x baseline; fill (latency + depth words)
    # is a fixed cost that dominates only for degenerate tiny workloads
    fill_bound = (plan.pipe.depth + 1) * (TPU_V5E.dma_latency_s
                                          + base.total_s / w.n_words)
    assert plan.predicted_s <= base.total_s * 1.5 + fill_bound


def test_paper_shape_fw_like():
    """FW-like kernel (false MLCD II=285, regular loads) must show a large
    FF speedup, paper-magnitude (65x there; >10x required here)."""
    w = Workload(n_words=1 << 16, word_bytes=768, flops_per_word=200,
                 regular=True, false_mlcd_ii=285.0)
    s = speedup(w, ARRIA_CX, Pipe(tile=(8, 128), depth=4))
    assert s > 10.0


def test_paper_shape_already_optimal():
    """PageRank/Hotspot-like kernels (no false MLCD, bandwidth saturated)
    see ~1x, as in Table 2 (0.85-1.02)."""
    w = Workload(n_words=1 << 16, word_bytes=1 << 14, flops_per_word=100,
                 regular=True, false_mlcd_ii=0.0)
    s = speedup(w, ARRIA_CX, Pipe(tile=(8, 128), depth=4))
    assert 0.7 < s < 1.5
