"""Measured autotuner: search (tile, depth, streams) per call site.

The paper sizes pipes *empirically* — profiler-guided depth/stream choices
per kernel, with the observation that the best configuration is device- and
access-pattern-specific. The analytic planner (:mod:`repro.core.planner`)
encodes the paper's reasoning but never measures anything; The Memory
Controller Wall (arXiv 1910.06726) documents exactly the gap between
modeled and achieved memory bandwidth that opens up. This module closes it:

* **Candidate generation** is seeded and pruned by the analytic model —
  for every tile option the kernel declares (``KernelSpec.tile_options``)
  and every (depth, streams) the planner considers feasible (VMEM budget,
  divisibility), candidates are ranked by :func:`estimate_feedforward`
  predicted time and only the top-K are measured. The analytic plan's own
  configuration is always measured first, so every tuned plan records a
  measured-vs-analytic comparison and can never select something slower
  than the analytic choice (it is the argmin over a set containing it).
* **Measurement** runs the real compiled kernel at the call site's shapes:
  warmup + median-of-N wall times with ``jax.block_until_ready``.
* **Persistence**: selected plans land in an on-disk JSON cache
  (``~/.cache/repro/plans.json``, override with the ``REPRO_PLAN_CACHE``
  env var or :func:`tuning_config`), keyed by
  ``(op, workload, dtype, hw, mesh topology, PLAN_FORMAT_VERSION)``. The
  mesh component (axis names/sizes + device count, from ``policy.mesh`` or
  the ambient ShardingContext) scopes tuned plans to the topology they
  were measured under. The disk cache fronts
  an in-memory dict the same way the planner's ``lru_cache`` fronts
  ``plan_pipe``, so a fresh process reloads tuned plans without
  re-measuring.

Entry point for kernels: :func:`resolve_call` — a drop-in superset of
``PipePolicy.resolve`` that returns a :class:`TunedChoice` (tile override +
depth + streams). Policies opt in with ``PipePolicy(mode="autotune")``
(full tile/depth/streams search) or ``depth="measured"`` /
``streams="measured"`` (measured sizing at the kernel's default tile). Call
sites that cannot be measured (traced arguments inside a user ``jax.jit``,
or no runner) fall back to the analytic plan with a warning.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import json
import os
import statistics
import threading
import time
import warnings
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import planner, profiling
from repro.core.meshspec import MeshSpec, SINGLE_DEVICE, resolve_mesh
from repro.core.pipe import DEFAULT_VMEM_BUDGET_BYTES, Pipe, \
    required_depth, vmem_budget_ok
from repro.core.pipeline_model import estimate_feedforward

# Bump whenever the record schema or the meaning of a key field changes:
# stale on-disk plans from an older format are ignored (their keys embed the
# version), and CI keys its plan-cache restore on this constant.
# v2: keys gained the mesh-topology component (axis names/sizes + device
# count) — plans tuned on one topology must never be served to another, so
# every pre-mesh entry is invalidated wholesale.
# v3: whole-layer graphs widened the joint search space — one (tile, depth,
# streams) choice now covers a 4-6 node decode_layer graph with epilogues
# and multi-consumer edges, and the VMEM budget is split across every fused
# chain stage — so a v2 record tuned against the old per-pair space could
# silently pin a layer-wide plan it never measured.
PLAN_FORMAT_VERSION = 3

_DEFAULT_CACHE_PATH = os.path.join("~", ".cache", "repro", "plans.json")
_VMEM_BUDGET_BYTES = DEFAULT_VMEM_BUDGET_BYTES
_DEPTH_CAP = 17


@dataclasses.dataclass(frozen=True)
class TunedChoice:
    """One resolved call-site configuration.

    ``tile_kwargs`` is the kernel-specific tile override (e.g.
    ``{"block": (256, 128, 128)}`` or ``{"block_kv": 64}``); empty means
    the call site's default tile. ``source`` records where the choice came
    from: "analytic" (policy did not ask for measurement),
    "analytic-fallback" (asked but unmeasurable), "measured" (tuned now),
    "memory"/"disk"/"plandb" (served from the plan cache). ``origin``
    names the tier that originally produced the record ("disk" /
    "plandb" / "measured" / "snapshot") — for a memory hit, the tier that
    installed the in-memory entry, so a cache hit stays distinguishable
    from the layer it shadows; empty for analytic resolutions, which are
    never cached.
    """

    tile_kwargs: Mapping[str, Any]
    depth: int
    streams: int
    source: str
    origin: str = ""


@dataclasses.dataclass(frozen=True)
class TuningConfig:
    """Knobs of one tuning session (see :func:`tuning_config`)."""

    warmup: int = 1
    iters: int = 3
    top_k: int = 6
    budget_s: Optional[float] = None
    cache_path: Optional[str] = None
    # release PlanDB (repro.plans.plandb) consulted between the per-host
    # disk cache and measurement; None = $REPRO_PLAN_DB (or nothing)
    plan_db: Optional[str] = None
    # tracing sink for the scope: passing trace_path= to tuning_config
    # enables obs spans to that JSONL file (None explicitly disables);
    # leaving the field untouched keeps the ambient REPRO_TRACE state
    trace_path: Optional[str] = None


class _ConfigStack(threading.local):
    def __init__(self):
        self.stack = [TuningConfig()]


_configs = _ConfigStack()


def current_tuning_config() -> TuningConfig:
    return _configs.stack[-1]


@contextlib.contextmanager
def tuning_config(**fields):
    """Override tuning knobs for a scope (thread-local, nests).

    ``with tuning_config(budget_s=12, iters=2): ...`` bounds the wall time
    and sampling of any tuning triggered inside; ``cache_path=`` redirects
    the persistent plan cache (tests point it at a tmpdir);
    ``trace_path=`` turns on obs tracing spans to that JSONL file for the
    scope (``trace_path=None`` explicitly disables; omitting the field
    keeps the ambient ``REPRO_TRACE`` state).
    """
    cfg = dataclasses.replace(current_tuning_config(), **fields)
    _configs.stack.append(cfg)
    trace_state = None
    if "trace_path" in fields:
        trace_state = (obs.enable(cfg.trace_path) if cfg.trace_path
                       else obs.disable())
    try:
        yield cfg
    finally:
        if trace_state is not None:
            obs.restore(trace_state)
        _configs.stack.pop()


def cache_path() -> str:
    """Resolve the plan-cache file: tuning_config > $REPRO_PLAN_CACHE >
    ``~/.cache/repro/plans.json``."""
    cfg = current_tuning_config()
    if cfg.cache_path:
        return cfg.cache_path
    return os.path.expanduser(
        os.environ.get("REPRO_PLAN_CACHE") or _DEFAULT_CACHE_PATH)


def plan_db_path() -> Optional[str]:
    """Resolve the release PlanDB file: tuning_config > $REPRO_PLAN_DB >
    none. The DB sits *after* the per-host cache in the lookup chain
    (host-measured plans are fresher than the shipped artifact) and is
    read-only: newly measured plans go to the host cache, never the DB."""
    cfg = current_tuning_config()
    p = cfg.plan_db or os.environ.get("REPRO_PLAN_DB")
    return os.path.expanduser(p) if p else None


# ---------------------------------------------------------------------------
# Persistent plan cache (disk JSON fronted by an in-memory dict)
# ---------------------------------------------------------------------------

_MEM: Dict[Tuple[str, str], dict] = {}   # (cache path, plan_key) -> record
# which tier installed each _MEM record ("disk" / "plandb" / "measured" /
# "snapshot"): repeat resolutions report source="memory", and this map is
# what keeps a prewarmed-PlanDB hit distinguishable from a self-measured
# one in plan_stats_snapshot() / the obs counters
_MEM_ORIGIN: Dict[Tuple[str, str], str] = {}
_DISK: Dict[str, Dict[str, dict]] = {}   # cache file path -> parsed plans
_LAST: Dict[str, dict] = {}         # op -> last record resolved (for bench)
# (op, plan_key) pairs already warned about: the traced-call-site fallback
# fires once per distinct (op, workload/constraints), not per traced call
_warned_fallback_ops = set()

# per-source resolution counters for measured policies (memory / disk /
# plandb / measured / analytic-fallback) plus "analytic" for unmeasured
# policies — the plan service's hit-rate metric (BENCH_plans.json).
# "memory" hits additionally count under "memory.<origin>" (disk / plandb /
# measured / snapshot), naming the tier that originally installed the
# in-memory record: a PlanDB prewarm followed by hits is distinguishable
# from records this process measured itself.
_STATS: "collections.Counter[str]" = collections.Counter()

# sources that served a plan without re-measurement at the call site
HIT_SOURCES = ("memory", "disk", "plandb")


def plan_stats_snapshot() -> Dict[str, int]:
    """Resolution counts by source since the last :func:`plan_stats_clear`.

    ``hits``/``lookups``/``hit_rate`` summarize measured-policy resolutions:
    a hit is any plan served without measuring (in-memory, per-host disk
    cache, or the release PlanDB); "measured" and "analytic-fallback" are
    the misses. Unmeasured ("analytic") resolutions are reported but not
    counted as lookups. ``memory.<origin>`` keys split the in-memory hits
    by the tier that installed the record.

    The same counts flow into the obs metrics registry as
    ``plan_resolutions_total{source=...}`` — ``obs.metrics_snapshot()`` is
    the unified surface; this accessor remains for plan-service internals
    and benches."""
    out: Dict[str, Any] = dict(_STATS)
    lookups = sum(_STATS[s] for s in
                  HIT_SOURCES + ("measured", "analytic-fallback"))
    hits = sum(_STATS[s] for s in HIT_SOURCES)
    out["lookups"] = lookups
    out["hits"] = hits
    out["hit_rate"] = (hits / lookups) if lookups else None
    return out


_warned_plan_stats_deprecated = False


def plan_stats() -> Dict[str, int]:
    """Deprecated alias of :func:`plan_stats_snapshot` — the obs metrics
    registry (``obs.metrics_snapshot()``) subsumes the ad-hoc stat surface;
    use that or :func:`plan_stats_snapshot` directly."""
    global _warned_plan_stats_deprecated
    if not _warned_plan_stats_deprecated:
        _warned_plan_stats_deprecated = True
        warnings.warn(
            "plan_stats() is deprecated: use obs.metrics_snapshot() "
            "(plan_resolutions_total counters) or plan_stats_snapshot()",
            DeprecationWarning, stacklevel=2)
    return plan_stats_snapshot()


def plan_stats_clear() -> None:
    _STATS.clear()
    obs.metrics_clear("plan_resolutions_total")


def plan_key(op: str, workload, dtype, hw, constraints: str = "",
             mesh: MeshSpec = SINGLE_DEVICE) -> str:
    """Cache key of one call site: (op, workload, dtype, hw, mesh, search
    constraints, format). ``constraints`` carries everything that shapes
    the search or the measurement besides the workload — policy pins,
    interpret flag, kernel statics — so a cached plan is only served to
    call sites it is actually valid for. ``mesh`` is the call site's
    topology (axis names/sizes + device count): a plan measured under one
    mesh never leaks to another (or to single-device call sites)."""
    wl = json.dumps(dataclasses.asdict(workload), sort_keys=True)
    return (f"{op}|{hw.name}|{jnp.dtype(dtype).name}"
            f"|fmt{PLAN_FORMAT_VERSION}"
            f"|mesh{mesh.token}|dev{mesh.device_count}"
            f"|{constraints}|{wl}")


def _policy_constraints(policy, extra_key: str = "") -> str:
    """The search-space signature of a policy: pinned ints (and, outside
    mode="autotune", planner-pinned "auto" fields) constrain the
    candidates, mode="autotune" enables the tile search, and interpret
    changes what is being timed — plans cached under one signature must
    not be served to another."""
    sig = (f"tiles{int(policy.mode == 'autotune')}"
           f"|d{policy.depth}|s{policy.streams}"
           f"|so{','.join(map(str, policy.stream_options))}"
           f"|interp{int(policy.interpret)}")
    return f"{sig}|{extra_key}" if extra_key else sig


def tuned_cache_clear() -> None:
    """Drop the in-memory tuned-plan caches (the disk *file* is untouched:
    the next lookup re-reads it, like a fresh process would)."""
    _MEM.clear()
    _MEM_ORIGIN.clear()
    _DISK.clear()
    _LAST.clear()


def _key_mesh_component(mesh: MeshSpec) -> str:
    return f"|mesh{mesh.token}|dev{mesh.device_count}|"


def invalidate_mesh(keep: MeshSpec, *, keep_single: bool = True) -> int:
    """Drop in-memory tuned-plan entries keyed by a mesh other than
    ``keep`` (the elastic-recovery hook, mirroring
    ``planner.invalidate_mesh_plans``).

    Only the in-memory front is touched: the on-disk cache and the PlanDB
    are already partitioned by mesh token inside every key, so entries for
    other topologies can never be *served* to the surviving mesh — what
    must go is the warm state (``_MEM``/``_LAST``) a long-lived process
    accumulated under the lost topology, so a remesh's memory footprint
    and introspection surface reflect the new world. Returns the number of
    records dropped."""
    kept_components = {_key_mesh_component(keep)}
    kept_tokens = {keep.token}
    if keep_single:
        kept_components.add(_key_mesh_component(SINGLE_DEVICE))
        kept_tokens.add(SINGLE_DEVICE.token)
    stale = [mk for mk in _MEM
             if not any(c in mk[1] for c in kept_components)]
    for mk in stale:
        del _MEM[mk]
        _MEM_ORIGIN.pop(mk, None)
    for op in [op for op, rec in _LAST.items()
               if rec.get("mesh", SINGLE_DEVICE.token) not in kept_tokens]:
        del _LAST[op]
    return len(stale)


# ---------------------------------------------------------------------------
# Checkpoint-carried plan snapshots (runtime.fault_tolerance)
# ---------------------------------------------------------------------------


def snapshot_plans(path: Optional[str] = None) -> dict:
    """Every tuned-plan record this process can currently serve for its
    plan-cache path — the parsed disk cache overlaid with the in-memory
    front — as a JSON-serializable snapshot keyed by
    :data:`PLAN_FORMAT_VERSION`.

    The fault-tolerance supervisor embeds this in every checkpoint's
    ``extra`` so a restarted job (possibly on a *different* host with a
    cold plan cache) pre-warms the autotune chain from the checkpoint and
    skips re-measurement entirely (:func:`restore_snapshot`)."""
    path = path or cache_path()
    plans: Dict[str, dict] = dict(load_plans(path))
    plans.update({k: rec for (p, k), rec in _MEM.items() if p == path})
    return {"format": PLAN_FORMAT_VERSION, "plans": plans}


def restore_snapshot(snapshot: Optional[Mapping[str, Any]],
                     path: Optional[str] = None) -> int:
    """Pre-warm the in-memory tuned-plan cache from a checkpoint-carried
    snapshot (:func:`snapshot_plans`).

    A snapshot from another plan format is ignored with a warning (every
    plan key embeds its format, so stale records could never be *served* —
    but silently carrying them forward would hide that the restarted job
    is re-measuring). Records never overwrite fresher entries this
    process already measured. Returns the number of records installed."""
    if not snapshot:
        return 0
    if snapshot.get("format") != PLAN_FORMAT_VERSION:
        warnings.warn(
            f"ignoring checkpoint plan snapshot with format "
            f"{snapshot.get('format')!r} != {PLAN_FORMAT_VERSION}; tuned "
            f"plans will be re-measured", RuntimeWarning, stacklevel=2)
        return 0
    path = path or cache_path()
    installed = 0
    for key, rec in dict(snapshot.get("plans") or {}).items():
        if not isinstance(rec, dict):
            continue
        if (path, key) not in _MEM:
            _MEM[(path, key)] = rec
            _MEM_ORIGIN[(path, key)] = "snapshot"
            installed += 1
    return installed


def last_record(op: str) -> Optional[dict]:
    """The most recent tuned-plan record resolved for ``op`` (bench report
    hook; includes the candidate table and the measured analytic config)."""
    return _LAST.get(op)


def load_plans(path: Optional[str] = None) -> Dict[str, dict]:
    """The on-disk plan cache, parsed once per path per process (cleared
    by :func:`tuned_cache_clear`). A corrupt or wrong-format file warns
    once and reads as empty (callers then fall back to the analytic plan
    or re-measure) — it is a cache, never a source of failure."""
    path = path or cache_path()
    if path in _DISK:
        return _DISK[path]
    _DISK[path] = plans = _read_plans_file(path)
    return plans


def _read_plans_file(path: str) -> Dict[str, dict]:
    try:
        with open(path) as f:
            payload = json.load(f)
        plans = payload["plans"]
        if payload.get("format") != PLAN_FORMAT_VERSION \
                or not isinstance(plans, dict):
            raise ValueError(f"plan format {payload.get('format')!r} != "
                             f"{PLAN_FORMAT_VERSION}")
        return plans
    except FileNotFoundError:
        return {}
    except (OSError, ValueError, KeyError, TypeError) as e:
        warnings.warn(
            f"ignoring corrupt plan cache {path} ({e}); tuned plans will "
            f"be re-measured or fall back to the analytic planner",
            RuntimeWarning, stacklevel=2)
        return {}


def store_plan(key: str, record: dict, path: Optional[str] = None) -> None:
    """Merge one record into the on-disk cache (atomic tmp+rename). The
    file is re-read before writing so records tuned by concurrent
    processes are merged, not clobbered."""
    path = path or cache_path()
    plans = _read_plans_file(path)
    plans[key] = record
    _DISK[path] = plans
    payload = {"format": PLAN_FORMAT_VERSION, "plans": plans}
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
    except OSError as e:    # read-only HOME etc.: keep the in-memory plan
        warnings.warn(f"could not persist plan cache to {path}: {e}",
                      RuntimeWarning, stacklevel=2)


def _as_tuples(obj):
    """JSON round-trip turns tuples into lists; restore tuples (tile shapes
    must be hashable for the jitted kernels' static args)."""
    if isinstance(obj, list):
        return tuple(_as_tuples(v) for v in obj)
    if isinstance(obj, dict):
        return {k: _as_tuples(v) for k, v in obj.items()}
    return obj


# ---------------------------------------------------------------------------
# Measurement harness
# ---------------------------------------------------------------------------


def measure(fn: Callable[[], Any], *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of ``fn()`` over ``iters`` timed runs.

    ``warmup`` untimed runs absorb compilation; every run blocks on the
    result (``jax.block_until_ready``), so async dispatch cannot fake a
    zero-cost kernel.
    """
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn())
    times = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(statistics.median(times))


def has_tracers(*arrays) -> bool:
    """True if any argument is a JAX tracer (call site inside a user jit —
    unmeasurable: there are no concrete operands to time against)."""
    return any(isinstance(a, jax.core.Tracer) for a in arrays)


def wants_measured(policy) -> bool:
    """Does this policy resolve through the tuner?  mode="autotune", or
    depth/streams "measured" in a pipelined mode (the baseline strawman is
    depth=1 by definition — nothing to measure)."""
    if policy.mode == "autotune":
        return True
    return policy.mode not in ("baseline", "ref") and \
        "measured" in (policy.depth, policy.streams)


# ---------------------------------------------------------------------------
# Candidate generation (seeded and pruned by the analytic model)
# ---------------------------------------------------------------------------


def _candidate_depths(workload, hw) -> Tuple[int, ...]:
    """Depth candidates around the analytic latency-hiding point."""
    service = workload.word_bytes / hw.stream_bandwidth(1, workload.regular)
    need = required_depth(hw.dma_latency_s, service, cap=_DEPTH_CAP)
    return tuple(sorted({2, 3, 4, need, min(2 * need, _DEPTH_CAP)}))


def _enumerate_candidates(policy, workload_fn, tile_options, dtype,
                          pinned_depth, pinned_streams, skipped):
    """All VMEM-feasible (tile_kwargs, depth, streams) points with their
    model-predicted times. ``pinned_depth``/``pinned_streams`` fix that
    axis of the search (None = free); ``skipped`` collects rejection
    lines."""
    hw = policy.hw
    tiles = ({},)
    if policy.mode == "autotune":
        tiles += tuple(tk for tk in tile_options if tk)
    out = []
    for tk in tiles:
        try:
            w_t, plan_tile = workload_fn(_as_tuples(tk))
        except Exception as e:    # noqa: BLE001 — tile invalid at this shape
            skipped.append(f"tile {tk}: {type(e).__name__}: {e}")
            continue
        depths = (pinned_depth,) if pinned_depth else \
            _candidate_depths(w_t, hw)
        streams_opts = (pinned_streams,) if pinned_streams else \
            tuple(policy.stream_options)
        for d in depths:
            for s in streams_opts:
                if plan_tile[0] % s != 0:
                    skipped.append(f"tile {tk or 'default'} streams={s}: "
                                   f"tile[0]={plan_tile[0]} not divisible")
                    continue
                try:
                    pipe = Pipe(tile=tuple(plan_tile),
                                dtype=jnp.dtype(dtype), depth=d, streams=s)
                except ValueError as e:    # tile not TPU-alignable
                    skipped.append(f"tile {tk or 'default'} streams={s}: {e}")
                    continue
                if not vmem_budget_ok([pipe], _VMEM_BUDGET_BYTES):
                    skipped.append(
                        f"tile {tk or 'default'} depth={d} streams={s}: "
                        f"ring vmem {pipe.vmem_bytes}B over budget")
                    continue
                est = estimate_feedforward(w_t, hw, pipe)
                out.append({"tile_kwargs": dict(tk), "depth": int(d),
                            "streams": int(s),
                            "predicted_s": float(est.total_s)})
    return out


def _dedupe(cands):
    seen, out = set(), []
    for c in cands:
        k = (json.dumps(c["tile_kwargs"], sort_keys=True, default=list),
             c["depth"], c["streams"])
        if k not in seen:
            seen.add(k)
            out.append(c)
    return out


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------


def _analytic_choice(op, policy, *, workload, tile, dtype,
                     source: str, mesh: MeshSpec = SINGLE_DEVICE,
                     ) -> TunedChoice:
    # resolve_auto treats "measured" as "auto" (the documented analytic
    # approximation), so the policy can be handed over unchanged.
    depth, streams = planner.resolve_policy(op, policy, workload=workload,
                                            tile=tile, dtype=dtype, mesh=mesh)
    return TunedChoice({}, depth, streams, source)


def _tune(op, policy, *, workload, tile, dtype, workload_fn, runner,
          tile_options, mesh: MeshSpec = SINGLE_DEVICE) -> Optional[dict]:
    """Measure the pruned candidate set; return the tuned record or None
    if nothing could be measured."""
    cfg = current_tuning_config()
    t0 = time.monotonic()
    skipped: list = []

    # The analytic plan at the default tile: always candidate #0, so the
    # record carries a measured analytic reference and the argmin can only
    # improve on it. Resolved through resolve_policy so policy-pinned ints
    # constrain the reference exactly like they constrain the search.
    depth_a, streams_a = planner.resolve_policy(
        op, policy, workload=workload, tile=tuple(tile), dtype=dtype,
        mesh=mesh)
    est_a = estimate_feedforward(
        workload, policy.hw,
        Pipe(tile=tuple(tile), dtype=jnp.dtype(dtype), depth=depth_a,
             streams=streams_a))
    analytic = {"tile_kwargs": {}, "depth": depth_a, "streams": streams_a,
                "predicted_s": float(est_a.total_s)}

    # Which axes does this policy open to empirical search? Explicit ints
    # always pin. In mode="autotune" everything else is searched; in a
    # pipelined mode with depth/streams="measured", an "auto" field keeps
    # its documented meaning — planner-sized — and is pinned to the
    # analytic resolution rather than silently promoted to the search.
    def _pin(val, analytic_val):
        if isinstance(val, int):
            return val
        if val == "auto" and policy.mode != "autotune":
            return analytic_val
        return None
    cands = _enumerate_candidates(policy, workload_fn, tile_options, dtype,
                                  _pin(policy.depth, depth_a),
                                  _pin(policy.streams, streams_a), skipped)
    cands.sort(key=lambda c: c["predicted_s"])
    cands = _dedupe([analytic] + cands)[:max(cfg.top_k, 1)]

    measured = []
    for i, c in enumerate(cands):
        if i > 0 and cfg.budget_s is not None \
                and time.monotonic() - t0 >= cfg.budget_s:
            skipped.append(
                f"candidate depth={c['depth']} streams={c['streams']} "
                f"tile={c['tile_kwargs'] or 'default'}: tuning budget "
                f"{cfg.budget_s}s exhausted")
            c["measured_s"] = None
            continue
        try:
            fn = runner(_as_tuples(c["tile_kwargs"]), c["depth"],
                        c["streams"])
            c["measured_s"] = measure(fn, warmup=cfg.warmup,
                                      iters=cfg.iters)
            measured.append(c)
        except Exception as e:   # noqa: BLE001 — candidate infeasible at run
            c["measured_s"] = None
            skipped.append(
                f"candidate depth={c['depth']} streams={c['streams']} "
                f"tile={c['tile_kwargs'] or 'default'}: "
                f"{type(e).__name__}: {e}")
    if not measured:
        return None
    best = min(measured, key=lambda c: c["measured_s"])
    return {
        "format": PLAN_FORMAT_VERSION,
        "op": op,
        "hw": policy.hw.name,
        "dtype": jnp.dtype(dtype).name,
        "mesh": mesh.token,
        "devices": mesh.device_count,
        "workload": dataclasses.asdict(workload),
        "tile_kwargs": best["tile_kwargs"],
        "depth": best["depth"],
        "streams": best["streams"],
        "measured_s": best["measured_s"],
        "analytic": dict(cands[0]),     # == analytic config, now measured
        "candidates": cands,
        "skipped": skipped[:40],
        "measure": {"warmup": cfg.warmup, "iters": cfg.iters},
    }


def resolve_call(op: str, policy, *, workload, tile, dtype,
                 workload_fn: Optional[Callable] = None,
                 runner: Optional[Callable] = None,
                 tile_options: Sequence[Mapping[str, Any]] = (),
                 extra_key: str = "",
                 site: Optional[Mapping[str, Any]] = None,
                 site_dynamic: Sequence[str] = (),
                 ) -> TunedChoice:
    """Resolve one kernel call site's (tile, depth, streams) under
    ``policy`` — the measured superset of ``PipePolicy.resolve``.

    Args:
      op/workload/tile/dtype: the analytic planner inputs (default tile).
      workload_fn: ``f(tile_kwargs) -> (Workload, plan_tile)`` re-deriving
        the planner inputs for a tile candidate (``f({})`` must equal the
        defaults).
      runner: ``f(tile_kwargs, depth, streams) -> g`` where ``g()`` runs
        the real kernel once at the call-site operands under that
        configuration. ``None`` means the call site cannot be measured
        (traced operands) — measured policies then fall back to the
        analytic plan with a warning.
      tile_options: the kernel's declared tile candidates
        (``KernelSpec.tile_options``), searched only in mode="autotune".
      extra_key: kernel statics that change the measured kernel but are
        not part of the Workload (e.g. chunk_scan's subtile, attention's
        kv length) — folded into the plan-cache key so a tuned plan is
        never served across call sites it was not measured for.
      site/site_dynamic: kernel shape kwargs (mirroring the kernel's
        workload-builder signature) for the traffic recorder
        (:mod:`repro.core.profiling`) — ``site_dynamic`` names the keys
        the profile shape-buckets. Never part of the plan key.

    Resolution order for measured policies: in-memory cache -> on-disk
    per-host plan cache -> release PlanDB (:func:`plan_db_path`) ->
    measure-and-persist -> analytic fallback. The cache key also carries
    the policy's search constraints (pinned depth/streams, stream_options,
    interpret, tile-search on/off), so e.g. plans measured in interpret
    mode are never served to compiled-mode call sites.
    """
    mesh = resolve_mesh(getattr(policy, "mesh", None))
    profiling.emit_call(
        op=op, policy=policy, workload=workload, tile=tile,
        dtype=jnp.dtype(dtype).name, mesh=mesh, extra_key=extra_key,
        site=site, site_dynamic=site_dynamic)
    # resolve_call funnels into planner.resolve_policy internally — the
    # suppression scope keeps those inner calls out of the recorded profile
    with obs.span("resolve_call", op=op, mesh=mesh.token) as sp:
        with profiling.suppress_planner():
            choice = _resolve_call(
                op, policy, workload=workload, tile=tile, dtype=dtype,
                workload_fn=workload_fn, runner=runner,
                tile_options=tile_options, extra_key=extra_key, mesh=mesh)
        sp.set(source=choice.source, origin=choice.origin,
               depth=choice.depth, streams=choice.streams)
    _STATS[choice.source] += 1
    if choice.source == "memory" and choice.origin:
        _STATS[f"memory.{choice.origin}"] += 1
    # structural counter, always on: the obs registry is the unified
    # surface (metrics_snapshot) over the same counts plan_stats reports
    obs.counter("plan_resolutions_total",
                "plan resolutions by source (autotune lookup chain)",
                source=choice.source, origin=choice.origin).inc()
    return choice


def _resolve_call(op, policy, *, workload, tile, dtype, workload_fn,
                  runner, tile_options, extra_key, mesh) -> TunedChoice:
    if not wants_measured(policy):
        depth, streams = planner.resolve_policy(
            op, policy, workload=workload, tile=tile, dtype=dtype, mesh=mesh)
        return TunedChoice({}, depth, streams, "analytic")

    key = plan_key(op, workload, dtype, policy.hw,
                   _policy_constraints(policy, extra_key), mesh=mesh)
    # the in-memory front is keyed per cache file, so redirecting the
    # plan cache (tuning_config / REPRO_PLAN_CACHE) mid-process never
    # serves plans from the previously selected file
    path = cache_path()
    mem_key = (path, key)
    source = "memory"
    origin = ""
    record = _MEM.get(mem_key)
    if record is not None:
        origin = _MEM_ORIGIN.get(mem_key, "")
    if record is None:
        record = load_plans(path).get(key)
        source = "disk"
        if record is not None:
            _MEM[mem_key] = record
            _MEM_ORIGIN[mem_key] = "disk"
    if record is None:
        db = plan_db_path()
        if db is not None:
            from repro.plans import plandb as _plandb   # lazy: plans sits on core
            record = _plandb.lookup(key, path=db)
            source = "plandb"
            if record is not None:
                _MEM[mem_key] = record
                _MEM_ORIGIN[mem_key] = "plandb"
    if record is None:
        if runner is None or workload_fn is None:
            if (op, key) not in _warned_fallback_ops:
                _warned_fallback_ops.add((op, key))
                warnings.warn(
                    f"{op}: measured plan requested but the call site is "
                    f"not measurable (traced operands or no runner); "
                    f"falling back to the analytic plan", RuntimeWarning,
                    stacklevel=3)
            return _analytic_choice(op, policy, workload=workload,
                                    tile=tile, dtype=dtype,
                                    source="analytic-fallback", mesh=mesh)
        record = _tune(op, policy, workload=workload, tile=tile,
                       dtype=dtype, workload_fn=workload_fn, runner=runner,
                       tile_options=tile_options, mesh=mesh)
        if record is None:    # every candidate failed to run
            warnings.warn(
                f"{op}: no autotune candidate could be measured; using the "
                f"analytic plan", RuntimeWarning, stacklevel=3)
            return _analytic_choice(op, policy, workload=workload,
                                    tile=tile, dtype=dtype,
                                    source="analytic-fallback", mesh=mesh)
        source = "measured"
        _MEM[mem_key] = record
        _MEM_ORIGIN[mem_key] = "measured"
        store_plan(key, record, path)
    _LAST[op] = dict(record, source=source)
    # origin = which lookup layer first produced this record (every branch
    # above stamps _MEM_ORIGIN as it populates the memory front), so a
    # later memory hit stays distinguishable from the layer it shadowed
    return TunedChoice(_as_tuples(record["tile_kwargs"]),
                       int(record["depth"]), int(record["streams"]), source,
                       _MEM_ORIGIN.get(mem_key, origin))


def resolve_graph(graph_name: str, policy, *, workload, tile, dtype,
                  signature: str,
                  workload_fn: Optional[Callable] = None,
                  runner: Optional[Callable] = None,
                  tile_options: Sequence[Mapping[str, Any]] = (),
                  site: Optional[Mapping[str, Any]] = None,
                  site_dynamic: Sequence[str] = (),
                  ) -> TunedChoice:
    """Joint (shared tile, depth, streams) resolution for one compiled
    multi-kernel graph (:mod:`repro.core.graph`).

    The whole fused graph is one call site: a candidate is a shared tile
    override (the fused edge's tile is shared between producer and consumer
    by construction) plus a (depth, streams) applied to every edge — the
    graph compiler then refines per edge (planner clamps, VMEM shedding).
    ``runner(tile_kwargs, depth, streams)`` must rebuild + recompile the
    graph at that configuration and run it end to end, so what is measured
    is the *jointly* lowered program, not any node in isolation.

    ``workload`` summarizes the graph (see ``graph.graph_workload``);
    ``signature`` is the structural graph key (nodes, shapes, edges) folded
    into the plan-cache key, so tuned graph plans are cached under the
    graph — never served across graphs that happen to share a workload
    summary — and reload from disk like kernel plans do.
    """
    return resolve_call(f"graph:{graph_name}", policy, workload=workload,
                        tile=tile, dtype=dtype, workload_fn=workload_fn,
                        runner=runner, tile_options=tile_options,
                        extra_key=f"sig={signature}",
                        site=site, site_dynamic=site_dynamic)
