"""starcoder2-15b [dense] — GQA, RoPE, layernorm+gelu, learned biases.
[arXiv:2402.19173; hf]  40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="starcoder2_15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    qkv_bias=True,
    act="gelu",
    norm="layernorm",
    rope_theta=100000.0,
    rule_overrides={"kv_heads": None},   # 4 kv heads vs 16-way model axis
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=192,
    vocab=256,
    compute_dtype="float32",
)
