"""Device/backend registry: hardware fingerprints -> plan namespaces.

One PlanDB artifact serves a heterogeneous fleet by partitioning records
into *namespaces*, one per hardware class. This module maps the hardware a
process actually runs on (its *fingerprint*: JAX backend platform, device
kind, device count) to the namespace its lookups should hit.

Resolution follows the ludwig registry idiom (SNIPPETS.md): named resolver
functions self-register via a decorator; non-default resolvers are
consulted in sorted-name order and the first non-None answer wins, with
default-registered resolvers as the fallback tier. Deployments add their
own hardware classes by registering a resolver — no core edits:

    from repro.plans import registry

    @registry.register_fingerprint_resolver("my-pod")
    def _my_pod(fp):
        if fp["platform"] == "tpu" and fp["device_count"] >= 256:
            return "tpu-pod.v5e"
        return None

``REPRO_PLAN_NAMESPACE`` overrides everything (operator escape hatch), and
:data:`DEFAULT_NAMESPACE` ("default") is the shared namespace lookups fall
back to when an artifact carries no records for this hardware class.
"""

from __future__ import annotations

import os
import re
from typing import Callable, Dict, Optional

# namespace consulted when the fingerprint namespace has no record: a
# publisher can ship conservative plans for unknown fleet members here
DEFAULT_NAMESPACE = "default"

Resolver = Callable[[Dict[str, object]], Optional[str]]

_RESOLVERS: Dict[str, Resolver] = {}
_DEFAULT_RESOLVERS: Dict[str, Resolver] = {}


def register_fingerprint_resolver(name: str, default: bool = False):
    """Decorator registering ``fn(fingerprint) -> namespace | None`` under
    ``name``. ``default=True`` puts it in the fallback tier (consulted only
    when every non-default resolver abstains)."""
    def wrap(fn: Resolver) -> Resolver:
        (_DEFAULT_RESOLVERS if default else _RESOLVERS)[name] = fn
        return fn
    return wrap


def _sanitize(s: str) -> str:
    return re.sub(r"[^a-z0-9.]+", "-", str(s).lower()).strip("-") or "unknown"


def hardware_fingerprint() -> Dict[str, object]:
    """What this process runs on: JAX platform, device kind, device count.
    Degrades to an "unknown" fingerprint when no backend is reachable
    (plan tooling must work on machines with no accelerator)."""
    try:
        import jax
        devs = jax.devices()
        return {"platform": str(jax.default_backend()),
                "device_kind": str(devs[0].device_kind) if devs else "none",
                "device_count": len(devs)}
    except Exception:   # noqa: BLE001 — no backend is a valid tooling state
        return {"platform": "unknown", "device_kind": "none",
                "device_count": 0}


@register_fingerprint_resolver("generic", default=True)
def _generic(fp: Dict[str, object]) -> str:
    """Fallback namespace: ``<platform>.<device-kind>`` (e.g. ``cpu.cpu``,
    ``tpu.tpu-v5-lite``) — every fingerprint resolves somewhere."""
    return f"{_sanitize(fp['platform'])}.{_sanitize(fp['device_kind'])}"


def plan_namespace(fingerprint: Optional[Dict[str, object]] = None) -> str:
    """The namespace this process's PlanDB lookups hit.

    Order: ``$REPRO_PLAN_NAMESPACE`` > registered resolvers (sorted name
    order) > default-tier resolvers. Always returns a non-empty token."""
    env = os.environ.get("REPRO_PLAN_NAMESPACE")
    if env:
        return env
    fp = fingerprint if fingerprint is not None else hardware_fingerprint()
    for tier in (_RESOLVERS, _DEFAULT_RESOLVERS):
        for name in sorted(tier):
            ns = tier[name](fp)
            if ns:
                return str(ns)
    return DEFAULT_NAMESPACE
