"""StreamGraph: multi-kernel pipe graphs with fused/staged lowering.

The paper splits *one* kernel into a memory stage and a compute stage
joined by a pipe. MKPipe (arXiv 2002.01614) shows the bigger win comes when
the *multi-kernel* program is a first-class object the compiler schedules:
producer→consumer kernels pipeline through on-chip channels so intermediates
never round-trip global memory — exactly the memory-controller bottleneck
quantified by The Memory Controller Wall (arXiv 1910.06726). This module is
that compiler layer for the repo, one level above
:mod:`repro.core.program`:

* a :class:`StreamGraph` composes registered :class:`StreamProgram` nodes
  into a DAG whose inter-kernel edges are declared :class:`GraphEdge`\\ s
  ("node ``dst`` streams node ``src``'s output through its ``dst_input``
  stream");
* :func:`compile_graph` chooses **per edge** between

  - **fused** lowering — when the producer's output block schedule matches
    the consumer's stream slicer (checked statically via
    ``StreamProgram.out_schedule`` / ``Stream.index``), the edge becomes an
    in-VMEM ring pipe inside a *single* ``pallas_call``: the producer's
    words are inlined ahead of the consumer words that need them and the
    intermediate block lands in a VMEM ring slot, never in HBM;
  - **staged** lowering — a double-buffered HBM handoff: the producer's
    ``pallas_call`` materializes the intermediate, the consumer streams it
    back through its declared ring pipe (depth ≥ 2 double-buffers the
    reload), and the planner charges the round trip in
    :func:`repro.core.pipeline_model.estimate_graph`;

* fusion legality, the per-edge VMEM split (``planner.split_graph_budget``),
  the MKPipe-style cost model (``estimate_graph``), and the graph-keyed
  measured autotuner (``autotune.resolve_graph``) all hang off the same
  compiled plan, so every rejection is observable as a rationale line —
  never a silent fallback.

Fused word schedule
-------------------

Legality analysis runs entirely on Python ints: the producer's output
schedule is grouped into equal-length contiguous runs (one per output
block, in completion order), the consumer's declared stream schedule is
mapped onto those blocks through row-major element offsets (so an
``edge.reshape`` between a ``[BH, S, D]`` producer and a ``[BH*S, D]``
consumer is handled exactly), and the request order must walk the
completion order contiguously. The resulting per-word (block ordinal,
first-request) tables ride into the fused kernel as scalar-prefetched
int32 vectors — the TPU analogue of the FPGA address FIFO — so the kernel
needs no data-dependent control flow beyond ``pl.when``.

At consumer word ``g`` the fused kernel runs::

    b = ord[g]; fresh[g]?            # scalar-prefetched schedule tables
    when fresh:                      # first word that needs block b
        for j in range(words_per_block):       # inlined producer stage
            w = b * words_per_block + j
            acquire(w, producer pipes); producer.consumer(w -> ring[b]);
            release(w, producer pipes)
    acquire(g, consumer's other pipes)
    consumer.consumer(g, edge word served from ring[b])   # compute stage
    release(g, consumer's other pipes)

Producer ``BlockIn`` operands are promoted to ring streams (Pallas block
delivery follows the grid, but the inlined producer's words are
schedule-driven), which is why :class:`repro.core.program.BlockIn` carries
a declared dtype.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, \
    Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs
from repro.core import planner
from repro.core.emitter import GatherRingPipe, RingPipe, acquire, release
from repro.core.meshspec import MeshSpec, SINGLE_DEVICE, localize_workload, \
    resolve_sharding
from repro.core.pipe import DEFAULT_VMEM_BUDGET_BYTES, Pipe
from repro.core.pipeline_model import GraphStage, Workload, estimate_graph
from repro.core.planner import PlanError
from repro.core.program import BlockIn, ProducerCtx, ProgramCtx, ScalarIn, \
    ScheduleOpaqueError, Stream, StreamProgram, _clamped_streams, \
    compile_program, program_workload

_VMEM_BUDGET_BYTES = DEFAULT_VMEM_BUDGET_BYTES


# ---------------------------------------------------------------------------
# The graph IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GraphNode:
    """One kernel of the multi-kernel program.

    ``workload`` (optional) is the node's analytic
    :class:`~repro.core.pipeline_model.Workload` — kernels' registry
    ``workload`` builders produce it; when omitted a conservative one is
    synthesized from the program's streams. ``plan_tile`` is the tile the
    planner sizes pipes against (default: the first stream's tile).
    """

    name: str
    program: StreamProgram
    workload: Optional[Workload] = None
    plan_tile: Optional[Tuple[int, ...]] = None


@dataclasses.dataclass(frozen=True)
class GraphEdge:
    """One inter-kernel dataflow edge: ``dst`` reads ``src``'s output
    through its Stream input ``dst_input``.

    ``prefer``: "auto" fuses when legal and VMEM-feasible (staged fallback
    with a rationale otherwise), "fused" demands fusion (infeasibility
    raises :class:`~repro.core.planner.PlanError` with the per-edge
    rationale), "staged" pins the HBM handoff. ``reshape`` declares the
    view the consumer takes of the intermediate (e.g. ``[BH, S, D]`` →
    ``[BH*S, D]`` between attention and its out-projection); it must
    preserve the element count and is applied to the materialized array in
    staged mode and to the offset arithmetic of the legality check in
    fused mode.
    """

    src: str
    dst: str
    dst_input: str
    prefer: str = "auto"
    reshape: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.prefer not in ("auto", "fused", "staged"):
            raise ValueError(f"edge {self.src}->{self.dst}: prefer must be "
                             f"auto|fused|staged, got {self.prefer!r}")

    @property
    def label(self) -> str:
        return f"{self.src}->{self.dst}"


@dataclasses.dataclass(frozen=True)
class StreamGraph:
    """A DAG of stream programs joined by pipe edges.

    Validated at construction: node names unique, edges name known nodes
    and Stream inputs, no input is fed twice, and the graph is acyclic
    (a pipe cycle would deadlock the FPGA channels it models — rejected
    here, like the paper rejects true memory loop-carried dependencies).
    """

    name: str
    nodes: Tuple[GraphNode, ...]
    edges: Tuple[GraphEdge, ...] = ()

    def __post_init__(self):
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"{self.name}: duplicate node names {names}")
        by_name = {n.name: n for n in self.nodes}
        fed = set()
        for e in self.edges:
            for end in (e.src, e.dst):
                if end not in by_name:
                    raise ValueError(f"{self.name}: edge {e.label} names "
                                     f"unknown node {end!r}")
            if e.src == e.dst:
                raise ValueError(f"{self.name}: self-edge on {e.src!r}")
            try:
                by_name[e.dst].program.stream(e.dst_input)
            except KeyError as err:
                raise ValueError(
                    f"{self.name}: edge {e.label} must feed a Stream input "
                    f"of {e.dst!r}: {err}") from err
            key = (e.dst, e.dst_input)
            if key in fed:
                raise ValueError(f"{self.name}: input {e.dst}.{e.dst_input} "
                                 f"is fed by more than one edge")
            fed.add(key)
            if e.reshape is not None:
                src_prog = by_name[e.src].program
                if int(np.prod(e.reshape)) != int(np.prod(src_prog.out_shape)):
                    raise ValueError(
                        f"{self.name}: edge {e.label} reshape {e.reshape} "
                        f"does not preserve the element count of "
                        f"{src_prog.out_shape}")
        self.topo_order()    # raises on cycles

    def node(self, name: str) -> GraphNode:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(f"{self.name}: unknown node {name!r}")

    def topo_order(self) -> Tuple[GraphNode, ...]:
        """Kahn topological order (stable in declaration order); raises
        ValueError on cycles."""
        indeg = {n.name: 0 for n in self.nodes}
        for e in self.edges:
            indeg[e.dst] += 1
        order: List[GraphNode] = []
        ready = [n for n in self.nodes if indeg[n.name] == 0]
        while ready:
            n = ready.pop(0)
            order.append(n)
            for e in self.edges:
                if e.src == n.name:
                    indeg[e.dst] -= 1
                    if indeg[e.dst] == 0:
                        ready.extend(m for m in self.nodes
                                     if m.name == e.dst)
        if len(order) != len(self.nodes):
            cyc = sorted(set(indeg) - {n.name for n in order})
            raise ValueError(f"{self.name}: graph has a cycle through "
                             f"{cyc}")
        return tuple(order)

    def sinks(self) -> Tuple[str, ...]:
        """Nodes with no out-edge — the graph's outputs, in topo order."""
        srcs = {e.src for e in self.edges}
        return tuple(n.name for n in self.topo_order() if n.name not in srcs)


# ---------------------------------------------------------------------------
# Workload synthesis + graph identity (autotune key)
# ---------------------------------------------------------------------------


def node_workload(node: GraphNode) -> Workload:
    """The node's analytic workload (declared, or synthesized from the
    program's streams when the builder did not provide one)."""
    if node.workload is not None:
        return node.workload
    return program_workload(node.program)


def _node_tile(node: GraphNode) -> Tuple[int, ...]:
    return tuple(node.plan_tile or node.program.streams[0].spec.tile)


def _node_dtype(node: GraphNode):
    return jnp.dtype(node.program.streams[0].spec.dtype)


def graph_workload(graph: StreamGraph) -> Tuple[Workload, Tuple[int, ...]]:
    """Summarize the whole graph as one Workload (the joint tuner's call
    site): total words, byte/flop averages, irregular if any node is."""
    ws = [node_workload(n) for n in graph.topo_order()]
    n_words = max(sum(w.n_words for w in ws), 1)
    w = Workload(
        n_words=n_words,
        word_bytes=sum(w.word_bytes * w.n_words for w in ws) / n_words,
        flops_per_word=sum(w.flops_per_word * w.n_words for w in ws) / n_words,
        regular=all(w.regular for w in ws),
        store_bytes_per_word=sum(w.store_bytes_per_word * w.n_words
                                 for w in ws) / n_words,
    )
    return w, _node_tile(graph.topo_order()[0])


def graph_signature(graph: StreamGraph) -> str:
    """Structural identity of the graph for the tuned-plan cache key:
    nodes (program, words, shapes, pipe tiles) + edges. Two graphs with
    the same signature lower identically, so a tuned plan transfers."""
    parts = []
    for n in graph.topo_order():
        p = n.program
        tiles = ",".join("x".join(map(str, s.spec.tile)) for s in p.streams)
        parts.append(f"{n.name}={p.name}/{p.n_words}w/"
                     f"{'x'.join(map(str, p.out_shape))}"
                     f"{jnp.dtype(p.out_dtype).name}/[{tiles}]")
    for e in graph.edges:
        parts.append(f"{e.label}.{e.dst_input}.{e.prefer}"
                     + (f".r{'x'.join(map(str, e.reshape))}"
                        if e.reshape else ""))
    return ";".join(parts)


# ---------------------------------------------------------------------------
# Fusion legality
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusionReport:
    """Outcome of the static legality analysis of one edge.

    When ``ok``: ``wpb`` producer words complete each of ``n_blocks``
    output blocks (contiguous, in ordinal order); ``ord_seq[g]`` is the
    block ordinal consumer word ``g`` reads; ``squeeze`` leading unit dims
    of the producer block are dropped to match the consumer tile;
    ``inter_depth`` sizes the in-VMEM intermediate ring.
    """

    ok: bool
    reason: str
    wpb: int = 1
    n_blocks: int = 0
    ord_seq: Tuple[int, ...] = ()
    squeeze: int = 0
    inter_depth: int = 1


def _strides(shape: Sequence[int]) -> List[int]:
    st = [1] * len(shape)
    for i in range(len(shape) - 2, -1, -1):
        st[i] = st[i + 1] * shape[i + 1]
    return st


def _block_offset(idx, block, shape) -> int:
    return sum(int(i) * b * s for i, b, s in zip(idx, block, _strides(shape)))


def _is_contiguous_slab(block, shape) -> bool:
    """Is a block at any grid-aligned start a contiguous row-major slab?
    Leading unit dims are free; after the first non-unit dim every dim must
    be full."""
    dims = list(zip(block, shape))
    i = 0
    while i < len(dims) and dims[i][0] == 1:
        i += 1
    return all(b == d for b, d in dims[i + 1:])


def check_fusion(producer: StreamProgram, consumer: StreamProgram,
                 edge: GraphEdge) -> FusionReport:
    """Static legality of fusing ``edge`` (pure-Python schedule analysis).

    Legal iff the producer's output block schedule *is* the consumer's
    stream schedule: same tile (modulo leading unit dims), blocks completed
    in contiguous equal-length word runs, and the consumer's declared
    request order walks the completion order contiguously (revisits allowed
    — a block may serve several consecutive consumer words, the ring slot
    simply stays live). Anything else returns ``ok=False`` with the
    rationale that ends up in the plan / bench JSON.
    """

    def no(reason: str) -> FusionReport:
        return FusionReport(False, reason)

    try:
        st = consumer.stream(edge.dst_input)
    except KeyError as e:
        return no(str(e))
    if st.gather:
        return no(f"consumer stream {edge.dst_input!r} is an irregular "
                  f"gather (data-dependent addresses)")
    try:
        pout = producer.out_schedule()
    except ScheduleOpaqueError as e:
        return no(f"producer schedule opaque: {e}")
    try:
        creq = consumer.stream_schedule(edge.dst_input)
    except ScheduleOpaqueError as e:
        return no(f"consumer schedule opaque: {e}")

    pblock = tuple(producer.out_block)
    tile = tuple(st.spec.tile)
    squeeze = 0
    while len(pblock) - squeeze > len(tile) and pblock[squeeze] == 1:
        squeeze += 1
    if pblock[squeeze:] != tile:
        return no(f"mismatched block schedules: producer out_block {pblock} "
                  f"vs consumer tile {tile}")
    if jnp.dtype(producer.out_dtype) != jnp.dtype(st.spec.dtype):
        return no(f"dtype mismatch: producer {jnp.dtype(producer.out_dtype).name} "
                  f"vs consumer pipe {jnp.dtype(st.spec.dtype).name}")
    cshape = tuple(edge.reshape) if edge.reshape else tuple(producer.out_shape)
    if len(cshape) != len(tile):
        return no(f"consumer operand rank {len(cshape)} (shape {cshape}) "
                  f"!= stream tile rank {len(tile)}")
    if not _is_contiguous_slab(producer.out_block, producer.out_shape):
        return no(f"producer blocks {pblock} of {producer.out_shape} are "
                  f"not contiguous slabs (cannot be matched through a "
                  f"reshape)")
    if not _is_contiguous_slab(tile, cshape):
        return no(f"consumer tiles {tile} of {cshape} are not contiguous "
                  f"slabs (k-dim must fit one tile)")
    for b in (i for i in producer.inputs if isinstance(i, BlockIn)):
        try:
            Pipe(tile=tuple(b.block), dtype=b.dtype, depth=2)
        except ValueError as e:
            return no(f"producer BlockIn {b.name!r} cannot be promoted to a "
                      f"ring stream: {e}")

    # rank guards: _block_offset zips index against block dims, so a
    # short/long tuple would silently drop schedule components and could
    # legalize a fusion that reads the wrong ring slot
    bad = {len(b) for b in pout} - {len(producer.out_block)}
    if bad:
        return no(f"producer out_index_map rank {sorted(bad)} != out_block "
                  f"rank {len(producer.out_block)}")
    bad = {len(b) for b in creq} - {len(tile)}
    if bad:
        return no(f"consumer stream index rank {sorted(bad)} != tile rank "
                  f"{len(tile)}")

    # producer completion runs: contiguous, equal length, each block once
    runs: List[List[Any]] = []    # [block, start, length]
    for w, blk in enumerate(pout):
        if runs and runs[-1][0] == blk:
            runs[-1][2] += 1
        else:
            runs.append([blk, w, 1])
    ordinal: Dict[Tuple[int, ...], int] = {}
    for o, (blk, _, _) in enumerate(runs):
        if blk in ordinal:
            return no(f"producer revisits output block {blk} "
                      f"non-contiguously")
        ordinal[blk] = o
    lengths = {r[2] for r in runs}
    if len(lengths) != 1:
        return no(f"producer block runs have unequal lengths "
                  f"{sorted(lengths)}")
    wpb, n_blocks = runs[0][2], len(runs)

    # map consumer requests onto producer ordinals through element offsets
    # (offsets survive the edge reshape; block tuples do not)
    p_by_off = {_block_offset(blk, producer.out_block, producer.out_shape): o
                for blk, o in ordinal.items()}
    ord_seq: List[int] = []
    prev = -1
    for g, blk in enumerate(creq):
        off = _block_offset(blk, tile, cshape)
        if off not in p_by_off:
            return no(f"consumer word {g} requests block {blk} (offset "
                      f"{off}) the producer never writes")
        o = p_by_off[off]
        if o not in (prev, prev + 1):
            return no(f"consumer request order is not contiguous "
                      f"non-decreasing (ordinal {prev}->{o} at word {g})")
        prev = o
        ord_seq.append(o)
    if prev != n_blocks - 1:
        return no(f"consumer consumes {prev + 1} of {n_blocks} produced "
                  f"blocks — the rest would never be scheduled")
    return FusionReport(
        ok=True,
        reason=(f"fusable: {n_blocks} blocks x {wpb} producer words each, "
                f"tile {tile}, consumer revisits "
                f"{len(ord_seq) / n_blocks:.1f}x"),
        wpb=wpb,
        n_blocks=n_blocks,
        ord_seq=tuple(ord_seq),
        squeeze=squeeze,
        inter_depth=1 if n_blocks == 1 else 2,
    )


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------


def _stream_overrides(program: StreamProgram, depth: int,
                      streams: int) -> Dict[str, Pipe]:
    """Re-size every stream of a program to (depth, streams), clamping
    streams to the tile's divisibility per stream (the planner's global
    choice refined per edge)."""
    return {
        st.name: dataclasses.replace(
            st.spec, depth=depth,
            streams=_clamped_streams(st.spec.tile[0], streams))
        for st in program.streams
    }


def _promote_blockin(b: BlockIn, scalar_names: Sequence[str],
                     depth: int) -> Stream:
    """Promote a producer BlockIn to a regular ring stream: the slicer
    replays the declared index map at the (dynamic) producer word."""
    def slicer(ctx, word, _b=b, _names=tuple(scalar_names)):
        scalars = [ctx.ref(n) for n in _names]
        idx = _b.index_map(word, *scalars) if _names else _b.index_map(word)
        sl = tuple(pl.ds(i * d, d) for i, d in zip(idx, _b.block))
        return ctx.ref(_b.name).at[sl]

    return Stream(b.name,
                  Pipe(tile=tuple(b.block), dtype=b.dtype, depth=depth),
                  slicer)


class _InterSlot:
    """The consumer-side endpoint of a fused edge: serves the current
    block from the in-VMEM intermediate ring (``ctx.word`` protocol)."""

    __slots__ = ("_buf", "_slot", "_squeeze")

    def __init__(self, buf, slot, squeeze):
        self._buf = buf
        self._slot = slot
        self._squeeze = squeeze

    def slot(self, word):
        del word    # the ring position tracks the block ordinal, not g
        return self._buf.at[(self._slot,) + (0,) * self._squeeze]


def _wrap_index_map(orig: Callable, lo: int, hi: int, takes_scalars: bool):
    """Adapt a node's index map to the fused kernel's scalar-prefetch
    signature: it sees only its own scalar refs (slice [lo:hi])."""
    if takes_scalars:
        return lambda g, *s: orig(g, *s[lo:hi])
    return lambda g, *s: orig(g)


def _compile_fused(pnode: GraphNode, cnode: GraphNode, edge: GraphEdge,
                   rep: FusionReport, p_sizing: Tuple[int, int],
                   c_sizing: Tuple[int, int], *, interpret: bool):
    """Lower one fused pair into a single ``pallas_call``.

    Returns ``(fn, operands)`` where ``operands`` names the external inputs
    in call order as ``(node_name, input_name)`` pairs. The schedule tables
    (block ordinal + first-request flag per consumer word) are closed over
    and passed as scalar-prefetch operands ahead of the user's scalars.
    """
    P, C = pnode.program, cnode.program
    (p_depth, p_streams_n), (c_depth, c_streams_n) = p_sizing, c_sizing

    p_scalars = [i for i in P.inputs if isinstance(i, ScalarIn)]
    c_scalars = [i for i in C.inputs if isinstance(i, ScalarIn)]
    p_tensors = [i for i in P.inputs if not isinstance(i, ScalarIn)]
    c_tensors = [i for i in C.inputs
                 if not isinstance(i, ScalarIn) and i.name != edge.dst_input]

    p_over = _stream_overrides(P, p_depth, p_streams_n)
    c_over = _stream_overrides(C, c_depth, c_streams_n)
    p_scal_names = [s.name for s in p_scalars]
    p_streams: Dict[str, Stream] = {}
    promoted = set()
    for i in p_tensors:
        if isinstance(i, Stream):
            p_streams[i.name] = dataclasses.replace(i, spec=p_over[i.name])
        else:
            promoted.add(i.name)
            p_streams[i.name] = _promote_blockin(i, p_scal_names, p_depth)
    c_streams = {
        i.name: dataclasses.replace(i, spec=c_over[i.name])
        for i in c_tensors if isinstance(i, Stream)
    }

    rings_p = {n: (GatherRingPipe if st.gather else RingPipe)(st.spec)
               for n, st in p_streams.items()}
    rings_c = {n: (GatherRingPipe if st.gather else RingPipe)(st.spec)
               for n, st in c_streams.items()}

    ord_arr = jnp.asarray(rep.ord_seq, jnp.int32)
    fresh_arr = jnp.asarray(
        [1 if g == 0 or rep.ord_seq[g] != rep.ord_seq[g - 1] else 0
         for g in range(C.n_words)], jnp.int32)
    n_scal = 2 + len(p_scalars) + len(c_scalars)
    c_lo, c_hi = 2 + len(p_scalars), n_scal
    c_takes = C.num_scalar_prefetch > 0

    def kernel(*refs):
        it = iter(refs)
        ord_ref, fresh_ref = next(it), next(it)
        p_named = {s.name: next(it) for s in p_scalars}
        c_named = {s.name: next(it) for s in c_scalars}
        for i in p_tensors:
            p_named[i.name] = next(it)
        for i in c_tensors:
            c_named[i.name] = next(it)
        out = next(it)
        c_scratch = {s.name: next(it) for s in C.scratch}
        p_scratch = {s.name: next(it) for s in P.scratch}
        inter = next(it)

        p_raw = ProducerCtx(p_named)
        bound_p = {}
        for name, st in p_streams.items():
            buf, sems = next(it), next(it)
            if st.gather:
                bound_p[name] = rings_p[name].bind(
                    buf, sems, lambda word, r, s=st: s.slicer(p_raw, word, r))
            else:
                bound_p[name] = rings_p[name].bind(
                    buf, sems, lambda word, s=st: s.slicer(p_raw, word))
        c_raw = ProducerCtx(c_named)
        bound_c = {}
        for name, st in c_streams.items():
            buf, sems = next(it), next(it)
            if st.gather:
                bound_c[name] = rings_c[name].bind(
                    buf, sems, lambda word, r, s=st: s.slicer(c_raw, word, r))
            else:
                bound_c[name] = rings_c[name].bind(
                    buf, sems, lambda word, s=st: s.slicer(c_raw, word))

        g = pl.program_id(0)
        b = ord_ref[g]
        p_list = list(bound_p.values())
        c_list = list(bound_c.values())

        # -- inlined producer stage: run block b's words on first request --
        @pl.when(fresh_ref[g] == 1)
        def _():
            for j in range(rep.wpb):
                w = b * rep.wpb + j
                acquire(w, P.n_words, p_list)
                body_refs = dict(p_named)
                for name in promoted:
                    body_refs[name] = bound_p[name].slot(w)
                pctx = ProgramCtx(w, P.n_words, body_refs, bound_p,
                                  inter.at[b % rep.inter_depth], p_scratch)
                P.consumer(pctx)
                release(w, P.n_words, p_list)

        # -- consumer stage: edge word served from the intermediate ring --
        acquire(g, C.n_words, c_list)
        pipes_view = dict(bound_c)
        pipes_view[edge.dst_input] = _InterSlot(
            inter, b % rep.inter_depth, rep.squeeze)
        cctx = ProgramCtx(g, C.n_words, c_named, pipes_view, out, c_scratch)
        C.consumer(cctx)
        release(g, C.n_words, c_list)

    in_specs = [pl.BlockSpec(memory_space=pl.ANY) for _ in p_tensors]
    for i in c_tensors:
        if isinstance(i, Stream):
            in_specs.append(pl.BlockSpec(memory_space=pl.ANY))
        else:
            in_specs.append(pl.BlockSpec(
                i.block, _wrap_index_map(i.index_map, c_lo, c_hi, c_takes)))
    scratch_shapes = [pltpu.VMEM(s.shape, s.dtype) for s in C.scratch]
    scratch_shapes += [pltpu.VMEM(s.shape, s.dtype) for s in P.scratch]
    scratch_shapes.append(
        pltpu.VMEM((rep.inter_depth, *P.out_block), P.out_dtype))
    for name in p_streams:
        scratch_shapes.extend(rings_p[name].scratch_shapes)
    for name in c_streams:
        scratch_shapes.extend(rings_c[name].scratch_shapes)

    call = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=n_scal,
            grid=(C.n_words,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                C.out_block,
                _wrap_index_map(C.out_index_map, c_lo, c_hi, c_takes)),
            scratch_shapes=scratch_shapes,
        ),
        out_shape=jax.ShapeDtypeStruct(C.out_shape, C.out_dtype),
        interpret=interpret,
    )

    def fn(*ops):
        return call(ord_arr, fresh_arr, *ops)

    operands = ([(pnode.name, s.name) for s in p_scalars]
                + [(cnode.name, s.name) for s in c_scalars]
                + [(pnode.name, i.name) for i in p_tensors]
                + [(cnode.name, i.name) for i in c_tensors])
    return fn, operands


def _fused_vmem_parts(P: StreamProgram, C: StreamProgram, edge: GraphEdge,
                      rep: FusionReport, p_sizing, c_sizing
                      ) -> Dict[str, int]:
    """Itemized VMEM footprint of a fused pair (for the planner's split
    budget check)."""
    p_over = _stream_overrides(P, *p_sizing)
    c_over = _stream_overrides(C, *c_sizing)
    p_rings = sum(p.vmem_bytes for p in p_over.values())
    for b in (i for i in P.inputs if isinstance(i, BlockIn)):
        p_rings += Pipe(tile=tuple(b.block), dtype=b.dtype,
                        depth=p_sizing[0]).vmem_bytes
    c_rings = sum(p.vmem_bytes for n, p in c_over.items()
                  if n != edge.dst_input)
    inter = rep.inter_depth * int(np.prod(P.out_block)) \
        * jnp.dtype(P.out_dtype).itemsize
    scratch = sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
                  for s in P.scratch + C.scratch)
    scratch += int(np.prod(C.out_block)) * jnp.dtype(C.out_dtype).itemsize
    return {"producer-rings": int(p_rings), "intermediate-ring": int(inter),
            "consumer-rings": int(c_rings), "scratch": int(scratch)}


# ---------------------------------------------------------------------------
# compile_graph
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EdgePlan:
    """One edge's lowering decision, with the rationale that justifies it
    (fused: legality + VMEM line; staged: why fusion was rejected)."""

    edge: GraphEdge
    mode: str                     # "fused" | "staged"
    rationale: str
    hbm_bytes_saved: float = 0.0


@dataclasses.dataclass(frozen=True)
class GraphPlan:
    """The compiled graph's plan: per-edge decisions, per-node pipe sizing,
    the VMEM budget split, and the MKPipe-style estimate (whose ``skipped``
    lines surface fusion rejections in bench JSON, like ``Plan.skipped``
    does for the kernel planner)."""

    edges: Tuple[EdgePlan, ...]
    sizing: Mapping[str, Tuple[int, int]]       # node -> (depth, streams)
    budgets: Mapping[str, int]                  # node -> vmem share
    estimate: Any                               # pipeline_model.GraphEstimate

    @property
    def fused(self) -> Tuple[EdgePlan, ...]:
        return tuple(e for e in self.edges if e.mode == "fused")

    @property
    def hbm_bytes_saved(self) -> float:
        return sum(e.hbm_bytes_saved for e in self.edges)


@dataclasses.dataclass(frozen=True)
class _Unit:
    """One executable of the compiled graph: a single node's pallas_call
    or a fused pair's."""

    kind: str                     # "node" | "fused"
    out_node: str
    fn: Callable
    operands: Tuple[Tuple[str, str], ...]     # (node, input) per call arg


class CompiledGraph:
    """The compiled multi-kernel program.

    Call it with the graph's external operands in :attr:`arg_names` order
    (``"node.input"`` labels; edge-fed inputs are internal). Returns the
    sink node's output (or a tuple for multi-sink graphs). ``plan`` carries
    the per-edge fused/staged decisions, rationales, and the analytic
    estimate; ``units`` shows the pallas_call structure (one "fused" unit =
    one kernel for two nodes — the acceptance check that an edge really
    lowered into a single kernel).
    """

    def __init__(self, graph: StreamGraph, policy, plan: GraphPlan,
                 units: Tuple[_Unit, ...], arg_names: Tuple[str, ...],
                 edges_in: Mapping[Tuple[str, str], GraphEdge]):
        self.graph = graph
        self.policy = policy
        self.plan = plan
        self.units = units
        self.arg_names = arg_names
        self._edges_in = dict(edges_in)
        self._sinks = graph.sinks()
        # one jit over the whole unit chain: staged intermediates stay
        # device-resident between pallas_calls and repeat calls replay the
        # compiled program (parity with the jitted repro.ops entrypoints)
        self._jit = jax.jit(self._run)

    def __call__(self, *args):
        if len(args) != len(self.arg_names):
            raise TypeError(
                f"{self.graph.name}: expected {len(self.arg_names)} operands "
                f"{list(self.arg_names)}, got {len(args)}")
        return self._jit(*args)

    def _run(self, *args):
        vals = dict(zip(self.arg_names, args))
        outs: Dict[str, Any] = {}
        for unit in self.units:
            ops = []
            for node, name in unit.operands:
                e = self._edges_in.get((node, name))
                if e is not None:
                    v = outs[e.src]
                    ops.append(v.reshape(e.reshape) if e.reshape else v)
                else:
                    ops.append(vals[f"{node}.{name}"])
            outs[unit.out_node] = unit.fn(*ops)
        res = tuple(outs[s] for s in self._sinks)
        return res[0] if len(res) == 1 else res


def _resolve_node(graph: StreamGraph, node: GraphNode, policy,
                  budget: int, mesh: MeshSpec = SINGLE_DEVICE,
                  shards: int = 1) -> Tuple[Workload, int, int]:
    """Per-node (depth, streams) under the node's split VMEM budget:
    explicit policy ints pass through; "auto"/"measured" resolve through
    the planner (the graph-keyed *measured* path resolves above
    compile_graph, in ``registry.run_graph``, and arrives here as ints).
    ``shards`` localizes the node's word schedule to the mesh's per-shard
    view before planning (local shapes, not global); ``mesh`` keys the
    plan so topologies never share cache entries."""
    w = localize_workload(node_workload(node), shards)
    depth, streams = policy.depth, policy.streams
    if isinstance(depth, str) or isinstance(streams, str):
        try:
            plan = planner.planned_pipe(
                f"graph:{graph.name}/{node.name}", w, _node_tile(node),
                _node_dtype(node), policy.hw,
                stream_options=tuple(policy.stream_options),
                vmem_budget_bytes=budget, mesh=mesh)
            d_plan, s_plan = plan.pipe.depth, plan.pipe.streams
        except PlanError:
            # the split budget is too tight for the latency-hiding depth:
            # degrade to the shallowest ring that fits (double-buffer, else
            # synchronous) — the fused-pair VMEM check downstream is where
            # a genuinely infeasible fusion turns into PlanError/staging
            tile, dt = _node_tile(node), _node_dtype(node)
            d_plan = 2 if Pipe(tile=tile, dtype=dt,
                               depth=2).vmem_bytes <= budget else 1
            s_plan = 1
        depth = d_plan if isinstance(depth, str) else int(depth)
        streams = s_plan if isinstance(streams, str) else int(streams)
    depth, streams = int(depth), int(streams)
    if policy.mode == "baseline":
        depth = 1
    return w, depth, streams


def _traced_compile_graph(fn):
    """Wrap the graph compile in an obs span carrying the per-edge
    fused/staged decision and rationale (no-op when tracing is off)."""
    @functools.wraps(fn)
    def wrapper(graph, **kw):
        with obs.span("compile_graph", graph=graph.name,
                      nodes=len(graph.nodes)) as sp:
            compiled = fn(graph, **kw)
            sp.set(
                hbm_bytes_saved=compiled.plan.hbm_bytes_saved,
                edges={f"{e.edge.src}->{e.edge.dst}":
                       {"mode": e.mode, "rationale": e.rationale}
                       for e in compiled.plan.edges})
            return compiled
    return wrapper


@_traced_compile_graph
def compile_graph(graph: StreamGraph, *, policy=None,
                  vmem_budget_bytes: int = _VMEM_BUDGET_BYTES,
                  prefer: Optional[str] = None,
                  sharding=None) -> CompiledGraph:
    """Compile a :class:`StreamGraph`, choosing fused/staged per edge.

    Per edge: "auto" fuses when the static legality analysis passes *and*
    the fused pair fits the planner's split VMEM budget, else stages with
    the rejection line as the edge rationale. ``prefer`` (or
    ``edge.prefer``) = "fused" turns an infeasible fusion into a
    :class:`~repro.core.planner.PlanError` carrying those lines; "staged"
    pins the HBM handoff (the A/B baseline for BENCH_graph.json).

    ``sharding`` makes the compile mesh-aware: pass a
    :class:`~repro.runtime.sharding.ShardingContext` (or a bare
    :class:`~repro.core.meshspec.MeshSpec`), or leave ``None`` to pick up
    the ambient context. Each node's workload is localized to the mesh's
    per-shard word schedule before planning (local shapes, not global) and
    every node plan is cache-keyed by the mesh topology, so a graph
    compiled under a mesh never reuses single-device plans or vice versa.

    Current fusion scope: one fused edge per kernel (a producer with one
    consumer, a consumer with one fused in-edge); longer chains stage
    between fused pairs. The producer must not feed anything else — fusing
    it away means its output never materializes in HBM.
    """
    from repro.core.program import current_policy
    policy = policy or current_policy()
    sh = sharding if sharding is not None else policy.mesh
    mesh, shards = resolve_sharding(sh)
    order = graph.topo_order()
    nodes = {n.name: n for n in graph.nodes}
    budgets = planner.split_graph_budget(
        [n.name for n in order], vmem_budget_bytes)

    resolved = {n.name: _resolve_node(graph, n, policy, budgets[n.name],
                                      mesh=mesh, shards=shards)
                for n in order}

    out_degree: Dict[str, int] = {}
    for e in graph.edges:
        out_degree[e.src] = out_degree.get(e.src, 0) + 1

    pos = {n.name: i for i, n in enumerate(order)}
    edge_plans: Dict[GraphEdge, EdgePlan] = {}
    reports: Dict[GraphEdge, FusionReport] = {}
    fused_in: Dict[str, GraphEdge] = {}       # consumer -> its fused edge
    in_pair = set()
    for e in sorted(graph.edges, key=lambda e: (pos[e.dst], pos[e.src])):
        pref = prefer or e.prefer
        P, C = nodes[e.src].program, nodes[e.dst].program
        if pref == "staged":
            edge_plans[e] = EdgePlan(e, "staged", "staged by request")
            continue
        rep = check_fusion(P, C, e)
        reason = None
        if not rep.ok:
            reason = rep.reason
        elif out_degree.get(e.src, 0) > 1:
            reason = (f"producer {e.src!r} output has "
                      f"{out_degree[e.src]} consumers; fusing would "
                      f"unmaterialize it for the others")
        elif e.src in in_pair or e.dst in in_pair:
            reason = "node already participates in a fused pair"
        else:
            _, pd, ps = resolved[e.src]
            _, cd, cs = resolved[e.dst]
            parts = _fused_vmem_parts(P, C, e, rep, (pd, ps), (cd, cs))
            fits, line = planner.check_fused_vmem(
                e.label, parts, budgets[e.src] + budgets[e.dst])
            if fits:
                st = C.stream(e.dst_input)
                saved = (float(np.prod(P.out_shape))
                         * jnp.dtype(P.out_dtype).itemsize
                         + float(C.n_words) * st.spec.word_bytes)
                edge_plans[e] = EdgePlan(e, "fused",
                                         f"{rep.reason}; {line}", saved)
                reports[e] = rep
                fused_in[e.dst] = e
                in_pair.update((e.src, e.dst))
                continue
            reason = line
        if pref == "fused":
            raise PlanError(resolved[e.dst][0],
                            budgets[e.src] + budgets[e.dst],
                            [f"{e.label}: {reason}"])
        edge_plans[e] = EdgePlan(e, "staged", reason)

    # -- build executable units (fused pairs collapse into one kernel) -----
    # only staged edges feed a materialized operand; a fused edge's
    # intermediate never exists outside the kernel
    edges_in = {(e.dst, e.dst_input): e for e in graph.edges
                if edge_plans[e].mode == "staged"}
    fused_producers = {e.src for e in fused_in.values()}
    units: List[_Unit] = []
    for n in order:
        if n.name in fused_producers:
            continue    # emitted inside its consumer's fused unit
        if n.name in fused_in:
            e = fused_in[n.name]
            rep = reports[e]
            pn, cn = nodes[e.src], nodes[e.dst]
            _, pd, ps = resolved[e.src]
            _, cd, cs = resolved[e.dst]
            fn, operands = _compile_fused(pn, cn, e, rep, (pd, ps), (cd, cs),
                                          interpret=policy.interpret)
            units.append(_Unit("fused", n.name, fn, tuple(operands)))
        else:
            _, d, s = resolved[n.name]
            fn = compile_program(
                n.program, interpret=policy.interpret,
                pipe_overrides=_stream_overrides(n.program, d, s))
            units.append(_Unit(
                "node", n.name, fn,
                tuple((n.name, i.name) for i in n.program.inputs)))

    fed_any = {(e.dst, e.dst_input) for e in graph.edges}
    arg_names = tuple(
        f"{n.name}.{i.name}" for n in order for i in n.program.inputs
        if (n.name, i.name) not in fed_any)

    # -- analytic estimate (MKPipe stage overlap + per-edge traffic) --------
    # stages follow the *execution* order of the units (a fused pair's
    # producer immediately precedes its consumer even when the declaration
    # topo order interleaves an unrelated node), so estimate_graph's
    # consecutive-stage fusion model lines up with plan.edges
    stage_order: List[GraphNode] = []
    for u in units:
        if u.kind == "fused":
            stage_order.append(nodes[fused_in[u.out_node].src])
        stage_order.append(nodes[u.out_node])
    stages = []
    for n in stage_order:
        w, d, s = resolved[n.name]
        tile = _node_tile(n)
        pipe = Pipe(tile=tile, dtype=_node_dtype(n), depth=max(d, 1),
                    streams=_clamped_streams(tile[0], s))
        e = fused_in.get(n.name)
        in_edges = [ed for ed in graph.edges if ed.dst == n.name]
        rationale = ""
        if e is not None:
            rationale = edge_plans[e].rationale
        elif in_edges:
            rationale = "; ".join(
                edge_plans[ed].rationale for ed in in_edges)
        prev_name = stages[-1].name if stages else None
        fused_with_prev = e is not None and e.src == prev_name
        saved_load = saved_store = 0.0
        if fused_with_prev:
            P = nodes[e.src].program
            st = nodes[e.dst].program.stream(e.dst_input)
            saved_store = float(np.prod(P.out_shape)) \
                * jnp.dtype(P.out_dtype).itemsize
            saved_load = float(nodes[e.dst].program.n_words) \
                * st.spec.word_bytes
        stages.append(GraphStage(
            name=n.name, workload=w, pipe=pipe,
            fused_with_prev=fused_with_prev,
            saved_load_bytes=saved_load, saved_store_bytes=saved_store,
            rationale=rationale))
    estimate = estimate_graph(tuple(stages), policy.hw)

    plan = GraphPlan(
        edges=tuple(edge_plans[e] for e in graph.edges),
        sizing={k: (d, s) for k, (_, d, s) in resolved.items()},
        budgets=budgets,
        estimate=estimate,
    )
    return CompiledGraph(graph, policy, plan, tuple(units), arg_names,
                         edges_in)
