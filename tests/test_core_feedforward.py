"""StreamSpec semantics, the MLCD legality checker, and multistream
reference equivalence (the core/ contract every kernel is tested against)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    Footprint,
    Pipe,
    StreamSpec,
    check_no_mlcd,
    reduction_stream,
    run_multistream_reference,
    run_reference,
    split_words_static,
)


def test_reduction_stream_matches_sum():
    x = jnp.arange(64 * 128, dtype=jnp.float32).reshape(64, 128)
    spec = reduction_stream(x, tile_rows=8)
    out = run_reference(spec, x)
    np.testing.assert_allclose(out, x.sum(), rtol=1e-6)


def test_multistream_matches_single():
    x = jax.random.normal(jax.random.key(0), (64, 128))
    spec = reduction_stream(x, tile_rows=8)
    single = run_reference(spec, x)
    multi = run_multistream_reference(spec, x, streams=2,
                                      combine=lambda outs: sum(outs))
    np.testing.assert_allclose(single, multi, rtol=1e-5)


def test_static_split_covers_all_words():
    words = split_words_static(10, 3)
    flat = sorted(w for ws in words for w in ws)
    assert flat == list(range(10))


def test_mlcd_detector_flags_raw():
    """Figure 3(a): out[t] written at word t, read at word t+1 -> true MLCD."""
    fps = [Footprint(reads=(("out", t - 1, t),) if t else (),
                     writes=(("out", t, t + 1),)) for t in range(4)]
    ok, why = check_no_mlcd(fps)
    assert not ok and "true MLCD" in why


def test_mlcd_detector_allows_disjoint():
    """Paper's transformed kernels: each word reads its own region only."""
    fps = [Footprint(reads=(("inp", 8 * t, 8 * t + 8),),
                     writes=(("out", t, t + 1),)) for t in range(8)]
    ok, _ = check_no_mlcd(fps)
    assert ok


def test_mlcd_detector_allows_war():
    """WAR across words (read early, written later) is not a RAW MLCD."""
    fps = [
        Footprint(reads=(("buf", 0, 8),), writes=()),
        Footprint(reads=(), writes=(("buf", 0, 8),)),
    ]
    ok, _ = check_no_mlcd(fps)
    assert ok


@given(st.integers(1, 64), st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_split_words_property(n, s):
    words = split_words_static(n, s)
    assert len(words) == s
    flat = sorted(w for ws in words for w in ws)
    assert flat == list(range(n))


def test_pipe_validation():
    with pytest.raises(ValueError):
        Pipe(tile=(8, 100))          # lanes not 8-aligned
    with pytest.raises(ValueError):
        Pipe(tile=(9, 128))          # sublanes not 8-aligned
    with pytest.raises(ValueError):
        Pipe(tile=(8, 128), depth=0)
    with pytest.raises(ValueError):
        Pipe(tile=(8, 128), streams=3)   # does not divide tile rows
    p = Pipe(tile=(16, 128), depth=3, streams=2)
    assert p.vmem_bytes == 3 * 16 * 128 * 4
    assert p.buffer_shape == (3, 16, 128)
    assert p.stream_tile == (8, 128)
