"""End-to-end training driver.

Wires together: model registry, logical sharding, host data pipe, optimizer,
fault-tolerant supervisor (checkpoint/resume/preemption), straggler
watchdog. Runs on whatever devices exist (CPU smoke -> TPU pods): pass
``--mesh host`` for a local mesh or ``--mesh pod`` for the production mesh.

Example (CPU, ~100M-param llama-style model, a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b --smoke \
      --steps 300 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config, smoke_config
from repro.data import HostPipeline, SyntheticSpec, batch_at
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim import adamw
from repro.runtime import sharding as shlib
from repro.runtime.fault_tolerance import FTConfig, Supervisor
from repro.runtime.stragglers import (BatchRebalancer, StragglerConfig,
                                      StragglerWatchdog)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3_2_1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced per-arch config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--quantized-accum", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", choices=("host", "pod", "pod2"), default="host")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (tests)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--policy-mode", choices=("ff", "baseline", "autotune"),
                    default=None,
                    help="install a session PipePolicy of this mode (mesh-"
                         "tagged) around the train-step body, so stream-"
                         "kernel call sites inside the model plan under "
                         "the training mesh; default: no policy override")
    ap.add_argument("--record-profile", default=None, metavar="PATH",
                    help="record every plan resolution into a "
                         "TrafficProfile JSON at PATH (the input of "
                         "`python -m repro.plans sweep`)")
    ap.add_argument("--plan-db", default=None, metavar="PATH",
                    help="release PlanDB consulted after the per-host plan "
                         "cache and before measuring (pre-warmed at "
                         "startup; overrides $REPRO_PLAN_DB)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="enable live telemetry and write "
                         "obs.metrics_snapshot() to PATH at exit")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    from repro.models import build_model
    model = build_model(cfg)

    mesh = (make_production_mesh(multi_pod=args.mesh == "pod2")
            if args.mesh.startswith("pod") else make_host_mesh())
    opt_cfg = adamw.AdamWConfig(lr_peak=args.lr, warmup_steps=20,
                                total_steps=args.steps)

    spec = SyntheticSpec(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        n_frames=cfg.n_frames if cfg.family == "encdec" else 0,
        n_patches=cfg.n_patches if cfg.family == "vlm" else 0,
        d_model=cfg.d_model)

    policy = None
    if args.policy_mode is not None:
        from repro.core.program import PipePolicy
        policy = PipePolicy(mode=args.policy_mode, interpret=True)

    # plan-service hooks (same contract as launch/serve.py): --plan-db
    # feeds the autotune lookup chain, --record-profile captures the
    # training traffic for an offline sweep
    import contextlib

    stack = contextlib.ExitStack()
    if args.metrics_json:
        from repro import obs
        if not obs.enabled():
            prev_obs = obs.enable()     # in-memory ring, no JSONL sink
            stack.callback(obs.restore, prev_obs)

        def _dump_metrics(path=args.metrics_json):
            from repro import obs as _obs
            import json
            with open(path, "w") as f:
                json.dump(_obs.metrics_snapshot(), f, indent=2,
                          sort_keys=True)
            print(f"# wrote live metrics snapshot -> {path}")
        stack.callback(_dump_metrics)
    if args.plan_db:
        from repro.core import autotune
        from repro.plans import plandb as plandb_lib
        stack.enter_context(autotune.tuning_config(plan_db=args.plan_db))
        pre = plandb_lib.prewarm(args.plan_db)
        print(f"# plan-db {args.plan_db}: {pre['records_in_namespace']} "
              f"records for namespace {pre['namespace']}")
    if args.record_profile:
        from repro.plans import record_traffic
        profile = stack.enter_context(record_traffic(args.record_profile))

    overrides = dict(cfg.rule_overrides or {})
    with stack, shlib.use_sharding(mesh, overrides=overrides):
        params = model.init(jax.random.key(0))
        opt_init, _ = steps_lib.opt_init_and_update(cfg.optimizer, opt_cfg)
        opt_state = opt_init(params)
        train_step = jax.jit(
            steps_lib.make_train_step(
                model, optimizer=cfg.optimizer, opt_cfg=opt_cfg,
                accum_steps=args.accum,
                quantized_accum=args.quantized_accum, policy=policy),
            donate_argnums=(0, 1))

        sup = stack.enter_context(
            Supervisor(FTConfig(ckpt_dir=args.ckpt_dir,
                                ckpt_every=args.ckpt_every),
                       state_like={"params": params, "opt": opt_state,
                                   "data_step": np.zeros((), np.int64)},
                       fail_at_step=args.fail_at))
        state, start = sup.resume()
        if start:
            print(f"resumed from checkpoint at step {start}"
                  + (f" ({sup.resume_prewarmed} tuned plans pre-warmed)"
                     if sup.resume_prewarmed else ""))
        params, opt_state = state["params"], state["opt"]

        pipe = HostPipeline(lambda s: batch_at(spec, s), depth=2,
                            producers=2, start_step=start)

        # watchdog actions are real: "rebalance" shrinks this host's batch
        # share and re-plans the stream kernels at the shrunk local shape
        # (the next tuned resolution repopulates the caches); "replace" is
        # the elastic path — single-host smoke can only log it, a pod
        # driver wires elastic.replace_host here
        def replan(host, share):
            from repro.core import planner
            print(f"# straggler {host}: share -> {share}; re-planning "
                  f"local pipes ({planner.plan_cache_info().currsize} "
                  f"cached plans)", flush=True)
            return share

        rebalancer = BatchRebalancer({"host0": max(args.batch, 1)},
                                     replan=replan)
        watchdog = StragglerWatchdog(
            StragglerConfig(), hosts=["host0"], rebalancer=rebalancer,
            on_replace=lambda h: print(f"# straggler {h}: replace "
                                       f"requested (elastic.replace_host "
                                       f"on a pod driver)", flush=True))

        t_hist = []

        def step_fn(state, step):
            params, opt_state = state["params"], state["opt"]
            batch = {k: jnp.asarray(v) for k, v in pipe.get().items()}
            t0 = time.time()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            metrics = jax.device_get(metrics)
            dt = time.time() - t0
            t_hist.append(dt)
            watchdog.step({"host0": dt})
            if step % args.log_every == 0:
                print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics.get('grad_norm', 0)):.3f} "
                      f"lr={float(metrics.get('lr', 0)):.2e} {dt*1e3:.0f}ms",
                      flush=True)
            return {"params": params, "opt": opt_state,
                    "data_step": np.asarray(step + 1, np.int64)}

        try:
            state = sup.run({"params": params, "opt": opt_state,
                             "data_step": np.asarray(start, np.int64)},
                            start, args.steps, step_fn)
        finally:
            pipe.stop()
        print(f"done at step {args.steps}; median step "
              f"{np.median(t_hist)*1e3:.0f} ms")
        if args.record_profile:
            print(f"# recorded traffic profile: {len(profile)} buckets -> "
                  f"{args.record_profile}")
        return state


if __name__ == "__main__":
    main()
