"""Step builders: train / prefill / decode, with shardings derived from the
logical rules. Used identically by the real trainer, the server, and the
dry-run (which lowers these very functions with ShapeDtypeStructs).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.optim import adafactor, adamw
from repro.optim.compression import QuantizedAccumulator
from repro.runtime import sharding as shlib


def opt_init_and_update(optimizer: str, opt_cfg=None):
    if optimizer == "adafactor":
        cfg = opt_cfg or adafactor.AdafactorConfig()
        return (lambda p: adafactor.init(p),
                lambda g, s, p: adafactor.update(cfg, g, s, p))
    cfg = opt_cfg or adamw.AdamWConfig()
    return (lambda p: adamw.init(p),
            lambda g, s, p: adamw.update(cfg, g, s, p))


def opt_state_axes(optimizer: str, param_axes):
    """Logical axes for the optimizer state (mirrors param axes)."""
    if optimizer == "adafactor":
        def st(ax):
            if len(ax) >= 2:
                return {"vr": tuple(ax[:-1]), "vc": tuple(ax[:-2]) + (ax[-1],)}
            return {"v": tuple(ax)}
        return {"v": jax.tree.map(st, param_axes,
                                  is_leaf=lambda x: isinstance(x, tuple)),
                "step": ()}
    return {"m": param_axes, "v": param_axes, "step": ()}


def make_train_step(model, *, optimizer: str = "adamw", opt_cfg=None,
                    accum_steps: int = 1, quantized_accum: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). With accum_steps > 1 the batch splits into microbatches along
    dim 0 and gradients accumulate (optionally in int8 w/ error feedback)
    before one optimizer update — collective-frugal: the DP all-reduce
    happens once per step, not per microbatch."""
    _, opt_update = opt_init_and_update(optimizer, opt_cfg)
    grad_fn = jax.value_and_grad(model.loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]), batch)

            if quantized_accum:
                acc0 = QuantizedAccumulator.init(params)

                def body(acc, mb):
                    (l, m), g = grad_fn(params, mb)
                    return QuantizedAccumulator.add(acc, g), (l, m)

                acc, (losses, metricses) = jax.lax.scan(body, acc0, micro)
                grads = jax.tree.map(lambda g: g / accum_steps,
                                     QuantizedAccumulator.read(acc))
            else:
                acc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def body(acc, mb):
                    (l, m), g = grad_fn(params, mb)
                    return jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32), acc, g), \
                        (l, m)

                acc, (losses, metricses) = jax.lax.scan(body, acc0, micro)
                grads = jax.tree.map(lambda g: g / accum_steps, acc)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricses)
        new_params, new_opt, opt_metrics = opt_update(grads, opt_state, params)
        return new_params, new_opt, {**metrics, **opt_metrics}

    return train_step


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model):
    def decode_step(params, batch, cache):
        logits, new_cache = model.decode_step(params, batch, cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache
    return decode_step


# ---------------------------------------------------------------------------
# Sharding assembly for the jit entry points
# ---------------------------------------------------------------------------


def shardings_for_cell(model, shape: ShapeConfig, ctx, *,
                       optimizer: str = "adamw"):
    """(in_shardings pytrees per entry point) for the given mesh context."""
    tupleish = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    sh = lambda axes_tree: jax.tree.map(
        lambda ax: shlib.sharding_for(ax, ctx), axes_tree, is_leaf=tupleish)

    p_sh = sh(model.param_axes())
    batch_sh = sh(model.input_axes(shape))
    out = {"params": p_sh, "batch": batch_sh}
    if shape.kind == "train":
        out["opt"] = sh(opt_state_axes(optimizer, model.param_axes()))
    if shape.kind == "decode":
        _, cache_axes = model.cache_spec(shape)
        out["cache"] = sh(cache_axes)
    return out
