"""Feed-forward flash attention (prefill), GQA-aware, as a StreamProgram.

Paper mapping: XLA's *un-fused* attention materializes the [S, S] score
matrix in HBM — the TPU analogue of the baseline kernel whose loads round-
trip global memory. The feed-forward version streams K/V tiles through VMEM
ring pipes (two producer stages) while the online-softmax consumer never
touches HBM for intermediates. The softmax running state (m, l, acc) is the
DLCD of the paper's Fig. 3: it is loop-carried in the *consumer only*, so
the K/V stream pipelines at full depth regardless.

Layout: q,k,v are [BH, S, D] with KV heads already broadcast-indexed by the
wrapper (GQA: q head h reads kv head h // group). Grid is 1-D over
(bh, qi, kj), kj innermost, causal blocks skipped via predication.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pipe import Pipe
from repro.core.program import BlockIn, ScratchSpec, Stream, StreamProgram, \
    compile_program

_NEG_INF = -1e30


def build_program(bh: int, s: int, skv: int, d: int, *,
                  kv_groups: int = 1, block_q: int = 128, block_kv: int = 128,
                  causal: bool = True, dtype=jnp.float32, k_dtype=None,
                  v_dtype=None, out_dtype=None,
                  depth: int = 2, streams: int = 1) -> StreamProgram:
    """Declare the prefill-attention stream program at one shape point.
    ``dtype`` is the q/out element type; ``k_dtype``/``v_dtype`` (default
    ``dtype``) size their own pipe edges."""
    assert s % block_q == 0 and skv % block_kv == 0, (s, skv, block_q, block_kv)
    nq, nkv = s // block_q, skv // block_kv
    scale = 1.0 / (d ** 0.5)
    out_dtype = out_dtype or dtype
    k_spec = Pipe(tile=(block_kv, d), dtype=k_dtype or dtype, depth=depth,
                  streams=streams)
    v_spec = Pipe(tile=(block_kv, d), dtype=v_dtype or dtype, depth=depth,
                  streams=streams)

    def kv_slicer(name):
        def f(ctx, word):
            w_kj = word % nkv
            w_bh = (word // (nkv * nq)) // kv_groups
            return ctx.ref(name).at[w_bh, pl.ds(w_kj * block_kv, block_kv), :]
        return f

    def consumer(ctx):
        kj = ctx.g % nkv
        qi = (ctx.g // nkv) % nq
        m_sc, l_sc = ctx.scratch("m"), ctx.scratch("l")
        acc = ctx.scratch("acc")

        @pl.when(kj == 0)
        def _():
            m_sc[...] = jnp.full_like(m_sc, _NEG_INF)
            l_sc[...] = jnp.zeros_like(l_sc)
            acc[...] = jnp.zeros_like(acc)

        q_end = (qi + 1) * block_q - 1
        kv_start = kj * block_kv
        live = (kv_start <= q_end) if causal else True

        @pl.when(live)
        def _():
            q = ctx.ref("q")[0]                       # [bq, d]
            k = ctx.word("k")[...]                    # [bkv, d]
            v = ctx.word("v")[...]                    # [bkv, d]
            s_ = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale   # [bq, bkv]
            if causal:
                rows = qi * block_q + jax.lax.broadcasted_iota(
                    jnp.int32, (block_q, block_kv), 0)
                cols = kv_start + jax.lax.broadcasted_iota(
                    jnp.int32, (block_q, block_kv), 1)
                s_ = jnp.where(rows >= cols, s_, _NEG_INF)
            m_prev = m_sc[:, :1]                      # [bq, 1]
            m_new = jnp.maximum(m_prev, jnp.max(s_, axis=1, keepdims=True))
            p = jnp.exp(s_ - m_new)                   # [bq, bkv]
            alpha = jnp.exp(m_prev - m_new)           # [bq, 1]
            l_new = l_sc[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
            acc[...] = acc[...] * alpha + jnp.dot(
                p.astype(v.dtype), v, preferred_element_type=jnp.float32)
            m_sc[...] = jnp.broadcast_to(m_new, m_sc.shape)
            l_sc[...] = jnp.broadcast_to(l_new, l_sc.shape)

        @pl.when(kj == nkv - 1)
        def _():
            l = l_sc[:, :1]
            l = jnp.where(l == 0.0, 1.0, l)           # fully-masked rows -> 0
            ctx.out[0] = (acc[...] / l).astype(out_dtype)

    q_index_map = lambda g: (g // (nkv * nq), (g // nkv) % nq, 0)
    # k/v block schedule in the pipe's own (block_kv, d) blocking of the
    # row-flattened [BKVH*Skv, d] operand view (an upstream producer edge
    # must declare reshape=(bkvh*skv, d)); matches kv_slicer exactly
    kv_index = lambda w: (((w // (nkv * nq)) // kv_groups) * nkv + w % nkv,
                          0)
    return StreamProgram(
        name="ff_attention",
        n_words=bh * nq * nkv,
        inputs=(
            # dtype on the BlockIn sizes its ring when a fused graph
            # promotes q to a stream; index declares the k/v schedules
            BlockIn("q", (1, block_q, d), q_index_map, dtype=dtype),
            Stream("k", k_spec, kv_slicer("k"), index=kv_index),
            Stream("v", v_spec, kv_slicer("v"), index=kv_index),
        ),
        consumer=consumer,
        out_shape=(bh, s, d),
        out_dtype=out_dtype,
        out_block=(1, block_q, d),
        out_index_map=q_index_map,
        scratch=(
            ScratchSpec("m", (block_q, 128), jnp.float32),
            ScratchSpec("l", (block_q, 128), jnp.float32),
            ScratchSpec("acc", (block_q, d), jnp.float32),
        ),
    )


@functools.partial(
    jax.jit,
    static_argnames=("kv_groups", "block_q", "block_kv", "depth", "streams",
                     "causal", "interpret"))
def flash_attention_ff(
    q: jnp.ndarray,               # [BH, S, D]
    k: jnp.ndarray,               # [BKVH, S, D]
    v: jnp.ndarray,               # [BKVH, S, D]
    *,
    kv_groups: int = 1,
    block_q: int = 128,
    block_kv: int = 128,
    depth: int = 2,
    streams: int = 1,
    causal: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    bh, s, d = q.shape
    kvbh, skv, dk = k.shape
    assert d == dk and v.shape == k.shape and bh == kvbh * kv_groups
    program = build_program(bh, s, skv, d, kv_groups=kv_groups,
                            block_q=block_q, block_kv=block_kv, causal=causal,
                            dtype=q.dtype, k_dtype=k.dtype, v_dtype=v.dtype,
                            depth=depth, streams=streams)
    return compile_program(program, interpret=interpret)(q, k, v)
