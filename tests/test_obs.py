"""Observability stack: tracing spans, metrics registry, bandwidth
accounting, and the live-telemetry wiring through autotune and serve."""

import json
import os
import time
import types
import warnings

import pytest

from repro import obs
from repro.core import TPU_V5E, Workload, autotune


@pytest.fixture
def obs_memory():
    """Tracing on, in-memory ring, drained before and after."""
    prev = obs.enable()
    obs.drain()
    yield
    obs.drain()
    obs.restore(prev)


@pytest.fixture
def obs_off():
    prev = obs.disable()
    yield
    obs.restore(prev)


# ---------------------------------------------------------------------------
# Tracing spans
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_noop(obs_off):
    s = obs.span("anything", k=1)
    assert s is obs.NOOP_SPAN
    assert s.set(more=2) is obs.NOOP_SPAN     # chainable, still no-op
    with s:
        assert obs.current_span() is obs.NOOP_SPAN
    assert obs.drain() == []                  # nothing was emitted
    assert obs.trace_path() is None


def test_disabled_span_overhead_is_negligible(obs_off):
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.span("hot", a=1):
            pass
    per_call = (time.perf_counter() - t0) / n
    # the disabled path is one bool check + a shared singleton; anything
    # near 10us/call means an allocation or clock read snuck in
    assert per_call < 10e-6


def test_span_nesting_records_parent_ids(obs_memory):
    with obs.span("outer", op="o") as so:
        with obs.span("inner") as si:
            assert obs.current_span() is si
        assert obs.current_span() is so
    recs = {r["name"]: r for r in obs.drain()}
    assert recs["inner"]["parent"] == recs["outer"]["id"]
    assert recs["outer"]["parent"] is None
    assert recs["inner"]["dur_s"] <= recs["outer"]["dur_s"]
    assert recs["outer"]["status"] == "ok"


def test_span_closes_under_exception_and_unwinds_stack(obs_memory):
    with pytest.raises(ValueError):
        with obs.span("outer"):
            with obs.span("boom"):
                raise ValueError("x")
    recs = {r["name"]: r for r in obs.drain()}
    assert recs["boom"]["status"] == "error"
    assert recs["boom"]["error"] == "ValueError"
    assert recs["outer"]["status"] == "error"
    # the thread-local stack fully unwound: a fresh span is a root again
    with obs.span("after"):
        pass
    assert obs.drain()[0]["parent"] is None


def test_span_set_attaches_late_attributes(obs_memory):
    with obs.span("resolve", op="ff_x") as sp:
        sp.set(source="memory", origin="plandb")
    (rec,) = obs.drain()
    assert rec["attrs"] == {"op": "ff_x", "source": "memory",
                            "origin": "plandb"}


def test_trace_jsonl_sink(tmp_path):
    path = os.path.join(tmp_path, "trace.jsonl")
    prev = obs.enable(path)
    try:
        with obs.span("a", n=1):
            with obs.span("b"):
                pass
    finally:
        obs.restore(prev)
    lines = [json.loads(x) for x in open(path)]
    assert [r["name"] for r in lines] == ["b", "a"]
    assert lines[0]["parent"] == lines[1]["id"]


def test_tuning_config_trace_path_scopes_tracing(tmp_path, obs_off):
    path = os.path.join(tmp_path, "scoped.jsonl")
    with autotune.tuning_config(trace_path=path):
        assert obs.enabled() and obs.trace_path() == path
        with obs.span("scoped"):
            pass
    assert not obs.enabled()                  # prior state restored
    assert json.loads(open(path).readline())["name"] == "scoped"


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_snapshot_and_text_roundtrip():
    obs.metrics_clear("t_")
    obs.counter("t_requests_total", "requests", route="a").inc()
    obs.counter("t_requests_total", route="a").inc(2)
    obs.counter("t_requests_total", route="b").inc()
    obs.gauge("t_depth", "queue depth").set(7.5)
    h = obs.histogram("t_latency_seconds", "latency")
    for v in (0.001, 0.002, 0.004):
        h.observe(v)
    snap = obs.metrics_snapshot()
    assert snap["counters"]["t_requests_total{route=a}"] == 3
    assert snap["counters"]["t_requests_total{route=b}"] == 1
    assert snap["gauges"]["t_depth"] == 7.5
    assert snap["histograms"]["t_latency_seconds"]["count"] == 3
    assert snap["histograms"]["t_latency_seconds"]["min"] == 0.001

    text = obs.render_text()
    parsed = obs.parse_text(text)
    assert parsed['t_requests_total{route="a"}'] == 3
    assert parsed["t_depth"] == 7.5
    assert parsed["t_latency_seconds_count"] == 3
    assert parsed["t_latency_seconds_sum"] == pytest.approx(0.007)
    obs.metrics_clear("t_")
    assert not [k for k in obs.metrics_snapshot() if k.startswith("t_")]


def test_metric_kind_collision_raises():
    obs.metrics_clear("t_kind")
    obs.counter("t_kind_x", "a counter").inc()
    with pytest.raises(ValueError):
        obs.gauge("t_kind_x")
    obs.metrics_clear("t_kind")


def test_histogram_quantiles_track_percentiles():
    obs.metrics_clear("t_q")
    h = obs.histogram("t_q_seconds")
    vals = [0.001 + 0.001 * i / 999 for i in range(1000)]   # uniform [1,2]ms
    for v in vals:
        h.observe(v)
    s = h.summary()
    # exponential buckets at 2**(1/8) spacing: <= ~4.4% quantile error
    assert s["p50"] == pytest.approx(0.0015, rel=0.05)
    assert s["p99"] == pytest.approx(0.00199, rel=0.05)
    assert s["min"] == 0.001 and s["max"] == 0.002
    assert s["p50"] <= s["p90"] <= s["p99"] <= s["max"]
    obs.metrics_clear("t_q")


# ---------------------------------------------------------------------------
# Bandwidth accounting
# ---------------------------------------------------------------------------

W = Workload(n_words=1024, word_bytes=65536.0, flops_per_word=1e5,
             store_bytes_per_word=4096.0)


def test_kernel_utilization_in_unit_interval():
    total_bytes = 1024 * (65536.0 + 4096.0)
    # measured exactly at the roofline -> utilization 1.0
    at_roof = obs.kernel_utilization(W, TPU_V5E,
                                     total_bytes / TPU_V5E.hbm_bw)
    assert at_roof["utilization"] == pytest.approx(1.0)
    # 10x slower than the roofline -> 0.1
    slow = obs.kernel_utilization(W, TPU_V5E,
                                  10 * total_bytes / TPU_V5E.hbm_bw)
    assert slow["utilization"] == pytest.approx(0.1)
    assert slow["hbm_bytes"] == total_bytes
    assert slow["achieved_gb_s"] == pytest.approx(
        TPU_V5E.hbm_bw / 10 / 1e9)
    assert 0.0 < slow["utilization"] <= 1.0
    # a byte model claiming more than the roofline clamps, keeps the raw
    fast = obs.kernel_utilization(W, TPU_V5E,
                                  0.5 * total_bytes / TPU_V5E.hbm_bw)
    assert fast["utilization"] == 1.0
    assert fast["utilization_raw"] == pytest.approx(2.0)


def _stage(bw, total_s):
    return types.SimpleNamespace(achieved_bw=bw, total_s=total_s)


def _edge(label, mode):
    return types.SimpleNamespace(edge=label, mode=mode,
                                 hbm_bytes_saved=111, rationale="test")


def test_graph_utilization_attributes_wall_by_model_share():
    est = types.SimpleNamespace(
        total_s=3e-3,
        hbm_bytes_saved=111,
        per_stage=[("a", _stage(100e9, 1e-3)), ("b", _stage(100e9, 2e-3))],
        edges=[_edge("a->b", "fused")],
    )
    rep = obs.graph_utilization(est, TPU_V5E, measured_s=6e-3)
    # measured wall split 1:2 by modeled share
    assert rep["stages"]["a"]["attributed_s"] == pytest.approx(2e-3)
    assert rep["stages"]["b"]["attributed_s"] == pytest.approx(4e-3)
    # bytes recovered from modeled bw * modeled time
    assert rep["stages"]["a"]["hbm_bytes"] == pytest.approx(100e9 * 1e-3)
    (edge,) = rep["edges"]
    assert edge["edge"] == "a->b" and edge["mode"] == "fused"
    assert edge["hbm_bytes"] == pytest.approx(100e9 * 3e-3)
    assert edge["attributed_s"] == pytest.approx(6e-3)
    assert edge["hbm_bytes_saved"] == 111
    # 2x slower than modeled -> utilization = modeled_bw/2 / roofline
    want = (100e9 / 2) / TPU_V5E.hbm_bw
    assert edge["utilization"] == pytest.approx(want)
    assert 0.0 < edge["utilization"] <= 1.0
    assert rep["graph"]["measured_s"] == 6e-3
    assert rep["graph"]["modeled_s"] == 3e-3


def test_graph_utilization_multi_consumer_edge_not_double_counted():
    """A producer feeding two edges (decode_layer's oproj -> gateup and
    oproj -> down residual) must contribute its bytes/wall once across the
    graph: edge rows split the shared stage so their sum equals the total."""
    est = types.SimpleNamespace(
        total_s=4e-3,
        hbm_bytes_saved=222,
        per_stage=[("p", _stage(100e9, 2e-3)),
                   ("c1", _stage(100e9, 1e-3)),
                   ("c2", _stage(100e9, 1e-3))],
        edges=[_edge("p->c1", "fused"), _edge("p->c2", "fused")],
    )
    rep = obs.graph_utilization(est, TPU_V5E, measured_s=4e-3)
    total_bytes = 100e9 * 4e-3
    assert rep["graph"]["hbm_bytes"] == pytest.approx(total_bytes)
    edge_bytes = sum(e["hbm_bytes"] for e in rep["edges"])
    edge_s = sum(e["attributed_s"] for e in rep["edges"])
    # shared producer p split across its two edges, not counted twice
    assert edge_bytes == pytest.approx(total_bytes)
    assert edge_s == pytest.approx(4e-3)
    for e in rep["edges"]:
        # each edge: half of p (1e-3 worth) + its own consumer (1e-3 worth)
        assert e["hbm_bytes"] == pytest.approx(100e9 * 2e-3)
        assert e["attributed_s"] == pytest.approx(2e-3)


# ---------------------------------------------------------------------------
# Autotune wiring: plan-source counters, origin split, deprecation shim
# ---------------------------------------------------------------------------

def test_plan_stats_deprecation_shim():
    autotune._warned_plan_stats_deprecated = False
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        old = autotune.plan_stats()
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert old == autotune.plan_stats_snapshot()


def test_memory_hit_keeps_plandb_origin(tmp_path, monkeypatch):
    """Satellite: a PlanDB-prewarm-then-hit is distinguishable from a
    plain memory hit — the second resolution counts under
    ``memory.plandb`` and tags the plan_resolutions_total counter."""
    import jax
    import jax.numpy as jnp

    from repro.core.program import PipePolicy
    from repro.kernels.ff_gather import gather
    from repro.plans import plandb as plandb_lib
    from repro.plans import record_traffic, sweep_profile

    monkeypatch.setenv("REPRO_PLAN_CACHE",
                       os.path.join(tmp_path, "host.json"))
    monkeypatch.delenv("REPRO_PLAN_DB", raising=False)
    monkeypatch.delenv("REPRO_PLAN_NAMESPACE", raising=False)
    autotune.tuned_cache_clear()
    plandb_lib.clear_cache()
    autotune.plan_stats_clear()
    obs.metrics_clear("plan_resolutions_total")

    pol = PipePolicy(mode="autotune", depth=2, streams=1, interpret=True)
    tab = jax.random.normal(jax.random.key(0), (64, 8), jnp.float32)
    idx = jax.random.randint(jax.random.key(1), (16,), 0, 64)

    with record_traffic() as prof, \
            autotune.tuning_config(cache_path=os.path.join(tmp_path,
                                                           "rec.json")):
        gather(tab, idx, policy=pol)
    sweep = sweep_profile(
        prof, scratch_cache=os.path.join(tmp_path, "scratch.json"),
        warmup=0, iters=1)
    dbp = os.path.join(tmp_path, "db.json")
    sweep.db.save(dbp)

    # fresh process simulation: only the swept DB in the lookup chain
    autotune.tuned_cache_clear()
    plandb_lib.clear_cache()
    autotune.plan_stats_clear()
    obs.metrics_clear("plan_resolutions_total")
    cold = os.path.join(tmp_path, "cold.json")
    with autotune.tuning_config(cache_path=cold, plan_db=dbp), \
            warnings.catch_warnings():
        warnings.simplefilter("error")       # a re-measure warning = failure
        gather(tab, idx, policy=pol)         # 1st: PlanDB hit -> memory
        gather(tab, idx, policy=pol)         # 2nd: memory hit, plandb origin
    stats = autotune.plan_stats_snapshot()
    assert stats.get("plandb") == 1
    assert stats.get("memory") == 1
    assert stats.get("memory.plandb") == 1   # the fix under test
    assert stats["hit_rate"] == 1.0
    counters = obs.metrics_snapshot()["counters"]
    assert counters.get(
        "plan_resolutions_total{origin=plandb,source=plandb}") == 1
    assert counters.get(
        "plan_resolutions_total{origin=plandb,source=memory}") == 1


def test_resolve_call_span_carries_source_tag(obs_memory, tmp_path,
                                              monkeypatch):
    import jax
    import jax.numpy as jnp

    from repro.core.program import PipePolicy
    from repro.kernels.ff_gather import gather

    monkeypatch.setenv("REPRO_PLAN_CACHE",
                       os.path.join(tmp_path, "host.json"))
    monkeypatch.delenv("REPRO_PLAN_DB", raising=False)
    autotune.tuned_cache_clear()
    tab = jax.random.normal(jax.random.key(0), (64, 8), jnp.float32)
    idx = jax.random.randint(jax.random.key(1), (16,), 0, 64)
    obs.drain()
    gather(tab, idx, policy=PipePolicy(mode="ff", interpret=True))
    spans = [r for r in obs.drain() if r["name"] == "resolve_call"]
    assert spans, "op entrypoint did not open a resolve_call span"
    assert spans[0]["attrs"]["op"] == "ff_gather"
    assert spans[0]["attrs"]["source"]   # plan-source tag present


# ---------------------------------------------------------------------------
# Serve: --metrics-json live telemetry
# ---------------------------------------------------------------------------

def test_serve_metrics_json_snapshot_parses(tmp_path):
    import argparse

    from repro.launch import serve as serve_lib

    obs.metrics_clear("serve_")
    path = os.path.join(tmp_path, "serve_metrics.json")
    ap = argparse.ArgumentParser()
    serve_lib.add_serve_args(ap)
    args = ap.parse_args(
        ["--smoke", "--requests", "3", "--slots", "2", "--prompt-len", "8",
         "--max-new", "4", "--rate", "50", "--metrics-json", path])
    result = serve_lib.serve_bench(args)
    assert result["metrics_json"] == path
    assert not obs.enabled()                 # bench restored the prior state
    snap = json.load(open(path))
    lock = snap["histograms"]["serve_token_latency_seconds{scheduler=lockstep}"]
    paged = snap["histograms"]["serve_token_latency_seconds{scheduler=paged}"]
    assert lock["count"] == paged["count"] == result["paged"]["tokens"]
    # the gauge tracks live pool utilization; at drain end it reads 0
    assert 0.0 <= snap["gauges"]["serve_kv_utilization"] <= 1.0
    # live histogram vs the bench's post-hoc percentiles: same samples,
    # so only bucket resolution separates them (acceptance bar: 10%)
    for sched, m in (("lockstep", result["lockstep"]),
                     ("paged", result["paged"])):
        live = snap["histograms"][
            f"serve_token_latency_seconds{{scheduler={sched}}}"]
        assert live["p50"] * 1e3 == pytest.approx(m["p50_ms"], rel=0.10)
        assert live["p99"] * 1e3 == pytest.approx(m["p99_ms"], rel=0.10)
