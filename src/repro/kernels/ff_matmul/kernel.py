"""Feed-forward (DAE) blocked matmul: C = A @ B.

The paper's transformation, applied to the canonical MXU workload:

* memory kernel  = async HBM->VMEM copies of A/B tiles, issued ``depth-1``
  words ahead through two ring pipes (one per operand);
* compute kernel = MXU dot over the landed tiles, accumulating in VMEM f32;
* pipe           = the ring buffers; ``streams`` splits each tile copy into
  parallel sub-DMAs (multi-producer M2C2 analogue).

``depth=1`` degenerates to synchronous copy-then-compute — the "single
work-item" baseline used by the Table-2 benchmark.

Word schedule: 1-D grid over (mi, ni, ki) with k innermost; the output block
(mi, ni) is revisited for nK consecutive steps and written on the last.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.emitter import RingPipe, acquire, release
from repro.core.pipe import Pipe


def _kernel(a_hbm, b_hbm, o_ref, acc, a_buf, a_sems, b_buf, b_sems,
            *, nm: int, nn: int, nk: int, a_ring: RingPipe, b_ring: RingPipe,
            out_dtype):
    g = pl.program_id(0)
    n_words = nm * nn * nk
    ki = g % nk
    bm, bk = a_ring.spec.tile
    _, bn = b_ring.spec.tile

    def a_slice(word):
        w_ki = word % nk
        w_mi = word // (nk * nn)
        return a_hbm.at[pl.ds(w_mi * bm, bm), pl.ds(w_ki * bk, bk)]

    def b_slice(word):
        w_ki = word % nk
        w_ni = (word // nk) % nn
        return b_hbm.at[pl.ds(w_ki * bk, bk), pl.ds(w_ni * bn, bn)]

    pipes = [
        a_ring.bind(a_buf, a_sems, a_slice),
        b_ring.bind(b_buf, b_sems, b_slice),
    ]
    acquire(g, n_words, pipes)

    @pl.when(ki == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    a_tile = a_ring.slot(g)[...]
    b_tile = b_ring.slot(g)[...]
    acc[...] += jnp.dot(a_tile, b_tile, preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _():
        o_ref[...] = acc[...].astype(out_dtype)

    release(g, n_words, pipes)


@functools.partial(
    jax.jit,
    static_argnames=("block", "depth", "streams", "out_dtype", "interpret"))
def matmul_ff(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block: Tuple[int, int, int] = (128, 128, 128),
    depth: int = 2,
    streams: int = 1,
    out_dtype=None,
    interpret: bool = True,
) -> jnp.ndarray:
    """DAE-pipelined matmul. Shapes must be multiples of ``block`` (use
    ops.matmul for auto-padding)."""
    (m, k), (k2, n) = a.shape, b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = block
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, block)
    nm, nn, nk = m // bm, n // bn, k // bk
    out_dtype = out_dtype or a.dtype

    a_ring = RingPipe(Pipe(tile=(bm, bk), dtype=a.dtype, depth=depth,
                           streams=streams))
    b_ring = RingPipe(Pipe(tile=(bk, bn), dtype=b.dtype, depth=depth,
                           streams=streams))

    kernel = functools.partial(
        _kernel, nm=nm, nn=nn, nk=nk, a_ring=a_ring, b_ring=b_ring,
        out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(nm * nn * nk,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec(
            (bm, bn), lambda g: (g // (nn * nk), (g // nk) % nn)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            *a_ring.scratch_shapes,
            *b_ring.scratch_shapes,
        ],
        interpret=interpret,
    )(a, b)
