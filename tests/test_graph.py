"""StreamGraph subsystem tests (repro.core.graph).

Covers the acceptance surface of the multi-kernel graph layer: fused ==
staged == XLA-reference numerics for both shipped graphs, fusion-legality
rejection (mismatched block schedules stage, they do not error), cycle
detection, VMEM-split feasibility (degrade + staged fallback on "auto",
PlanError on requested fusion), and the graph-keyed autotune cache.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core.graph import (
    CompiledGraph,
    GraphEdge,
    GraphNode,
    StreamGraph,
    check_fusion,
    compile_graph,
    graph_signature,
    graph_workload,
)
from repro.core.pipeline_model import TPU_V5E, estimate_graph
from repro.core.planner import PlanError
from repro.core.program import PipePolicy, ScheduleOpaqueError
from repro.kernels import registry as R
from repro.kernels.ff_gather.kernel import build_program as gather_program
from repro.kernels.ff_matmul.kernel import build_program as matmul_program


def _toy_graph(block_m=8, prefer="auto"):
    """gather(64 rows of a [96, 128] table) -> matmul(@ [128, 128])."""
    disp = gather_program(64, 128, dtype=jnp.float32, depth=2, streams=1)
    mm = matmul_program(64, 128, 128, block=(block_m, 128, 128),
                        dtype=jnp.float32, depth=2, streams=1)
    return StreamGraph(
        "toy", (GraphNode("d", disp), GraphNode("e", mm)),
        (GraphEdge("d", "e", "a", prefer=prefer),))


def _toy_inputs(key=None):
    key = key or jax.random.key(0)
    tab = jax.random.normal(key, (96, 128), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (64,), 0, 96,
                             dtype=jnp.int32)
    w = jax.random.normal(jax.random.fold_in(key, 2), (128, 128),
                          jnp.float32) / jnp.sqrt(128.0)
    return idx, tab, w


# ---------------------------------------------------------------------------
# Shipped graphs: fused == staged == XLA reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["moe_dispatch_ffn", "attention_proj",
                                  "paged_decode_attention", "decode_layer"])
def test_shipped_graph_fused_matches_reference(name):
    spec = R.get_graph(name)
    out, ref, err, compiled = R.run_graph_smoke(spec)
    assert isinstance(compiled, CompiledGraph)
    assert err <= spec.tol, (name, err)
    assert any(e.mode == "fused" for e in compiled.plan.edges), \
        [(e.edge.label, e.rationale) for e in compiled.plan.edges]


@pytest.mark.parametrize("name", ["moe_dispatch_ffn", "attention_proj",
                                  "paged_decode_attention", "decode_layer"])
def test_shipped_graph_staged_matches_fused(name):
    spec = R.get_graph(name)
    out_f, _, err_f, _ = R.run_graph_smoke(spec)
    out_s, _, err_s, staged = R.run_graph_smoke(spec, prefer="staged")
    assert err_f <= spec.tol and err_s <= spec.tol
    assert all(e.mode == "staged" for e in staged.plan.edges)
    np.testing.assert_allclose(np.float32(out_f), np.float32(out_s),
                               atol=2 * spec.tol)


def test_moe_fused_edge_is_single_pallas_call():
    """The acceptance check: dispatch->matmul collapses into one fused
    unit (one pallas_call for two nodes), combine stays its own call."""
    spec = R.get_graph("moe_dispatch_ffn")
    _, _, _, compiled = R.run_graph_smoke(spec)
    kinds = [(u.kind, u.out_node) for u in compiled.units]
    assert kinds == [("fused", "expert"), ("node", "combine")], kinds
    plan = {e.edge.label: e.mode for e in compiled.plan.edges}
    assert plan == {"dispatch->expert": "fused",
                    "expert->combine": "staged"}


def test_moe_staged_is_three_pallas_calls():
    spec = R.get_graph("moe_dispatch_ffn")
    _, _, _, compiled = R.run_graph_smoke(spec, prefer="staged")
    assert [u.kind for u in compiled.units] == ["node"] * 3


def test_gather_edge_never_fuses():
    """The combine's table stream is an irregular gather: data-dependent
    addresses, no declared schedule — the edge must stage with rationale."""
    spec = R.get_graph("moe_dispatch_ffn")
    _, _, _, compiled = R.run_graph_smoke(spec)
    staged = [e for e in compiled.plan.edges if e.mode == "staged"]
    assert len(staged) == 1
    assert "gather" in staged[0].rationale


# ---------------------------------------------------------------------------
# Legality / schedule exposure
# ---------------------------------------------------------------------------


def test_out_schedule_runs():
    mm = matmul_program(256, 256, 256, block=(128, 128, 128))
    sched = mm.out_schedule()
    assert len(sched) == mm.n_words
    # k-innermost word order: each (mi, ni) block written over nk words
    assert sched[0] == sched[1] == (0, 0)
    assert sched[2] == sched[3] == (0, 1)


def test_stream_schedule_requires_declaration():
    disp = gather_program(64, 128)
    with pytest.raises(ScheduleOpaqueError):
        disp.stream_schedule("table")    # gather: data-dependent


def test_mismatched_block_schedule_stages_not_errors():
    g = _toy_graph(block_m=16)    # 16-row A tile vs 8-row gather bundle
    compiled = compile_graph(g)
    (plan,) = compiled.plan.edges
    assert plan.mode == "staged"
    assert "mismatched block schedules" in plan.rationale
    idx, tab, w = _toy_inputs()
    np.testing.assert_allclose(np.asarray(compiled(idx, tab, w)),
                               np.asarray(tab[idx] @ w), atol=1e-4)


def test_forced_fusion_of_illegal_edge_raises_plan_error():
    g = _toy_graph(block_m=16, prefer="fused")
    with pytest.raises(PlanError) as ei:
        compile_graph(g)
    assert "mismatched block schedules" in str(ei.value)


def test_check_fusion_reports_geometry():
    disp = gather_program(64, 128)
    mm = matmul_program(64, 128, 128, block=(8, 128, 128))
    rep = check_fusion(disp, mm, GraphEdge("d", "e", "a"))
    assert rep.ok
    assert rep.n_blocks == 8 and rep.wpb == 1
    assert rep.ord_seq == tuple(range(8))


# ---------------------------------------------------------------------------
# Graph validation
# ---------------------------------------------------------------------------


def test_cycle_detection():
    disp = gather_program(64, 128)
    mm = matmul_program(64, 128, 128, block=(8, 128, 128))
    with pytest.raises(ValueError, match="cycle"):
        StreamGraph("cyc", (GraphNode("d", disp), GraphNode("e", mm)),
                    (GraphEdge("d", "e", "a"),
                     GraphEdge("e", "d", "table")))


def test_edge_must_feed_a_stream():
    disp = gather_program(64, 128)
    mm = matmul_program(64, 128, 128, block=(8, 128, 128))
    with pytest.raises(ValueError, match="Stream input"):
        StreamGraph("bad", (GraphNode("d", disp), GraphNode("e", mm)),
                    (GraphEdge("d", "e", "nope"),))


def test_input_fed_twice_rejected():
    disp = gather_program(64, 128)
    disp2 = gather_program(64, 128)
    mm = matmul_program(64, 128, 128, block=(8, 128, 128))
    with pytest.raises(ValueError, match="more than one edge"):
        StreamGraph("bad", (GraphNode("d", disp), GraphNode("d2", disp2),
                            GraphNode("e", mm)),
                    (GraphEdge("d", "e", "a"), GraphEdge("d2", "e", "a")))


def test_bad_reshape_rejected():
    disp = gather_program(64, 128)
    mm = matmul_program(64, 128, 128, block=(8, 128, 128))
    with pytest.raises(ValueError, match="element count"):
        StreamGraph("bad", (GraphNode("d", disp), GraphNode("e", mm)),
                    (GraphEdge("d", "e", "a", reshape=(3, 5)),))


# ---------------------------------------------------------------------------
# VMEM-split feasibility
# ---------------------------------------------------------------------------


def test_vmem_split_infeasible_fusion_stages_on_auto():
    g = _toy_graph()
    compiled = compile_graph(g, vmem_budget_bytes=64 * 1024)
    (plan,) = compiled.plan.edges
    assert plan.mode == "staged"
    assert "exceeds" in plan.rationale and "budget" in plan.rationale
    idx, tab, w = _toy_inputs()
    np.testing.assert_allclose(np.asarray(compiled(idx, tab, w)),
                               np.asarray(tab[idx] @ w), atol=1e-4)


def test_vmem_split_infeasible_forced_fusion_raises():
    g = _toy_graph(prefer="fused")
    with pytest.raises(PlanError) as ei:
        compile_graph(g, vmem_budget_bytes=64 * 1024)
    assert "exceeds" in str(ei.value)
    assert ei.value.rejected    # per-edge rationale attached


def test_budget_split_evenly_across_nodes():
    g = _toy_graph()
    compiled = compile_graph(g, vmem_budget_bytes=1 << 20)
    assert compiled.plan.budgets == {"d": (1 << 20) // 2,
                                    "e": (1 << 20) // 2}


# ---------------------------------------------------------------------------
# Estimate (MKPipe overlap + per-edge traffic)
# ---------------------------------------------------------------------------


def test_estimate_fused_beats_unfused_and_saves_bytes():
    _, _, _, compiled = R.run_graph_smoke(R.get_graph("moe_dispatch_ffn"))
    est = compiled.plan.estimate
    assert est.total_s < est.unfused_s
    assert est.hbm_bytes_saved > 0
    modes = {e.edge: e.mode for e in est.edges}
    assert modes["dispatch->expert"] == "fused"
    assert modes["expert->combine"] == "staged"
    # staged rejections surfaced like Plan.skipped
    assert any("gather" in s for s in est.skipped)


def test_estimate_graph_staged_everything_matches_sum():
    _, _, _, compiled = R.run_graph_smoke(R.get_graph("moe_dispatch_ffn"),
                                          prefer="staged")
    est = compiled.plan.estimate
    assert est.hbm_bytes_saved == 0
    assert est.total_s == pytest.approx(est.unfused_s)


# ---------------------------------------------------------------------------
# Graph-keyed autotune
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_graph_autotune_cache_hit(tmp_path):
    spec = R.get_graph("moe_dispatch_ffn")
    args = spec.make_inputs(jax.random.key(0))
    cache = os.path.join(tmp_path, "plans.json")
    pol = PipePolicy(mode="autotune")
    with autotune.tuning_config(cache_path=cache, warmup=0, iters=1,
                                top_k=3):
        out, _ = R.run_graph(spec, args, policy=pol)
        rec = autotune.last_record(f"graph:{spec.name}")
        assert rec is not None and rec["source"] == "measured"
        # second resolve: served from the in-memory cache, no re-measure
        R.run_graph(spec, args, policy=pol)
        rec2 = autotune.last_record(f"graph:{spec.name}")
        assert rec2["source"] == "memory"
        # fresh process analogue: drop memory, reload from disk
        autotune.tuned_cache_clear()
        R.run_graph(spec, args, policy=pol)
        rec3 = autotune.last_record(f"graph:{spec.name}")
        assert rec3["source"] == "disk"
    err = float(np.max(np.abs(np.float32(out)
                              - np.float32(spec.ref(*args)))))
    assert err <= spec.tol


def test_graph_signature_distinguishes_graphs():
    g1 = _toy_graph()
    g2 = _toy_graph(block_m=16)
    assert graph_signature(g1) != graph_signature(g2)
    w, tile = graph_workload(g1)
    assert w.n_words > 0 and tile == (8, 128)
    assert not w.regular    # the gather node makes the graph irregular


def test_estimate_graph_direct_api():
    """estimate_graph is usable standalone (no compile needed)."""
    from repro.core.pipe import Pipe
    from repro.core.pipeline_model import GraphStage, Workload

    w = Workload(n_words=64, word_bytes=4096.0, flops_per_word=1e6,
                 store_bytes_per_word=4096.0)
    pipe = Pipe(tile=(8, 128), depth=2)
    fused = estimate_graph((
        GraphStage("a", w, pipe),
        GraphStage("b", w, pipe, fused_with_prev=True,
                   saved_load_bytes=64 * 4096.0,
                   saved_store_bytes=64 * 4096.0),
    ), TPU_V5E)
    staged = estimate_graph((
        GraphStage("a", w, pipe),
        GraphStage("b", w, pipe, rationale="why not"),
    ), TPU_V5E)
    assert fused.total_s < staged.total_s
    assert fused.hbm_bytes_saved == 2 * 64 * 4096.0
    assert staged.skipped == ("a->b: why not",)


# ---------------------------------------------------------------------------
# Whole-layer decode graph (epilogues, multi-consumer edges, chain fusion)
# ---------------------------------------------------------------------------


def test_decode_layer_mlp_tail_is_single_pallas_call():
    """The acceptance shape: out-proj -> gate/up -> down collapses into
    ONE fused chain unit while qkv projection and attention stay their
    own calls, and every staged edge carries a rationale."""
    spec = R.get_graph("decode_layer")
    _, _, err, compiled = R.run_graph_smoke(spec)
    assert err <= spec.tol
    kinds = [(u.kind, u.out_node) for u in compiled.units]
    assert kinds == [("node", "qproj"), ("node", "attn"),
                     ("fused", "down")], kinds
    modes = {e.edge.label: e.mode for e in compiled.plan.edges}
    assert modes == {"qproj->attn": "staged", "attn->oproj": "staged",
                     "oproj->gateup": "fused", "oproj->down": "fused",
                     "gateup->down": "fused"}
    for e in compiled.plan.edges:
        if e.mode == "staged":
            assert e.rationale, e.edge.label


def test_decode_layer_multi_consumer_edge_ring_serves_residual():
    """oproj feeds two consumers — gateup's stream and down's residual
    epilogue. Both edges fuse; the residual copy is served from the
    producer's intermediate VMEM ring instead of a second HBM read, and
    the estimate credits that edge with saved bytes."""
    spec = R.get_graph("decode_layer")
    _, _, _, compiled = R.run_graph_smoke(spec)
    by_label = {e.edge.label: e for e in compiled.plan.edges}
    assert by_label["oproj->gateup"].mode == "fused"
    assert by_label["oproj->down"].mode == "fused"
    assert "ring" in by_label["oproj->down"].rationale
    saved = {e.edge: e.hbm_bytes_saved for e in compiled.plan.estimate.edges}
    assert saved["oproj->down"] > 0
    assert saved["oproj->gateup"] > 0
    assert compiled.plan.estimate.hbm_bytes_saved > 0


def test_decode_layer_multi_consumer_edges_stage_on_request():
    """The other legality direction: prefer='staged' demotes both edges
    of the shared producer — five independent pallas_calls, residual
    materialized in HBM and re-read by the epilogue BlockIn."""
    spec = R.get_graph("decode_layer")
    _, _, err, staged = R.run_graph_smoke(spec, prefer="staged")
    assert err <= spec.tol
    assert [u.kind for u in staged.units] == ["node"] * 5
    by_label = {e.edge.label: e for e in staged.plan.edges}
    assert by_label["oproj->gateup"].mode == "staged"
    assert by_label["oproj->down"].mode == "staged"


def test_decode_layer_forced_fusion_lists_every_rejection():
    """prefer='fused' across the whole layer fails with one rationale per
    unfusable edge — the BlockIn-fed attention q and the block-schedule
    mismatch out of attention — not a single opaque error."""
    spec = R.get_graph("decode_layer")
    with pytest.raises(PlanError) as ei:
        R.run_graph_smoke(spec, prefer="fused")
    msg = str(ei.value)
    assert "qproj->attn" in msg and "attn->oproj" in msg
    assert "BlockIn" in msg
    assert "mismatched block schedules" in msg
    assert len(ei.value.rejected) == 2


def test_epilogue_matches_xla_reference():
    """A residual epilogue folded into a node's output write is
    numerically the XLA dot + add (same operand, fed as a BlockIn)."""
    from repro.core.graph import Epilogue
    from repro.core.program import BlockIn
    from repro.kernels.ff_layer import build_matmul_program

    m, n, k = 32, 128, 64
    prog = build_matmul_program(m, n, k)

    def ep(ctx, idx, value):
        return value + ctx.ref("res")[...].astype(value.dtype)

    node = GraphNode("mm", prog, epilogue=Epilogue(ep, inputs=(
        BlockIn("res", (8, n), lambda g: (g, 0), dtype=jnp.float32),)))
    compiled = compile_graph(StreamGraph("ep", (node,), ()))
    key = jax.random.key(7)
    a = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n),
                          jnp.float32) / jnp.sqrt(64.0)
    res = jax.random.normal(jax.random.fold_in(key, 2), (m, n), jnp.float32)
    np.testing.assert_allclose(np.asarray(compiled(a, w, res)),
                               np.asarray(a @ w + res), atol=1e-4)
