"""Pure-jnp oracle for ff_attention (GQA, optional causal)."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, kv_groups: int = 1, causal: bool = True) -> jnp.ndarray:
    """q: [BH, Sq, D]; k, v: [BKVH, Skv, D]; BH = BKVH * kv_groups."""
    bh, sq, d = q.shape
    kvbh, skv, _ = k.shape
    assert bh == kvbh * kv_groups
    kk = jnp.repeat(k, kv_groups, axis=0)
    vv = jnp.repeat(v, kv_groups, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        rows = jnp.arange(sq)[:, None]
        cols = jnp.arange(skv)[None, :]
        s = jnp.where(rows >= cols, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, vv.astype(jnp.float32)).astype(q.dtype)
