"""repro.optim — sharded optimizers + gradient compression."""

from repro.optim import adafactor, adamw, compression

__all__ = ["adafactor", "adamw", "compression"]
