"""Public op wrapper + cost model for ff_gather."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ff_gather.kernel import gather_ff
from repro.kernels.ff_gather.ref import gather_ref
from repro.kernels.ff_matmul.ops import KernelCost


def gather_cost(n: int, cols: int, *, depth: int = 4,
                dtype=jnp.float32) -> KernelCost:
    itemsize = jnp.dtype(dtype).itemsize
    return KernelCost(
        flops=0.0,
        hbm_bytes=float(2 * n * cols * itemsize + n * 4),
        vmem_bytes=depth * 8 * cols * itemsize,
    )


def gather(table, idx, *, depth: int = 4, mode: str = "ff",
           interpret: bool = True):
    """rows = table[idx]; mode="ff"|"baseline"(depth=1)|"ref"."""
    if mode == "ref":
        return gather_ref(table, idx)
    n = idx.shape[0]
    pad = (-n) % 8
    idx_p = jnp.pad(idx.astype(jnp.int32), (0, pad))
    if mode == "baseline":
        depth = 1
    out = gather_ff(table, idx_p, depth=depth, interpret=interpret)
    return out[:n]
