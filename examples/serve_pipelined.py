"""Batched serving example: continuous-batching greedy decode with separate
prefill/decode programs (the feed-forward model at the serving level —
prefill produces the KV-cache pipe, the decode loop consumes it).

Run:  PYTHONPATH=src python examples/serve_pipelined.py
"""

from repro.launch import serve as serve_mod

if __name__ == "__main__":
    serve_mod.main(["--arch", "qwen1_5_0p5b", "--smoke",
                    "--requests", "8", "--prompt-len", "24", "--max-new", "12"])
