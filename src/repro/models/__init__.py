"""repro.models — model families (dense GQA, MoE, MLA, Mamba2 hybrid,
RWKV6, encoder-decoder, VLM) behind one registry interface."""

from repro.models.registry import build_model, build_model_by_id

__all__ = ["build_model", "build_model_by_id"]
