"""Tracing spans: a lightweight, zero-cost-when-disabled span API.

One global switch (:func:`enabled`) gates everything. Disabled (the
default), :func:`span` returns a shared no-op singleton — no allocation,
no clock read, no lock — so instrumented hot paths (the serve decode loop,
``resolve_call``) pay a single module-global bool check. Enabled, spans
time themselves on the monotonic clock, nest through a thread-local stack
(children record their parent's span id), and emit one JSONL record per
close to the configured sink:

* ``REPRO_TRACE=/path/trace.jsonl`` enables tracing at import and appends
  records there;
* ``tuning_config(trace_path=...)`` enables it for a scope (the autotune
  config stack restores the previous state on exit);
* :func:`enable` with no path keeps records in a bounded in-memory ring
  (:func:`drain` reads and clears it — the test/bench hook).

Record schema (one JSON object per line)::

    {"name": "resolve_call", "id": 7, "parent": 3, "ts": <epoch s>,
     "dur_s": 0.0012, "thread": 140, "status": "ok"|"error",
     "attrs": {...}, ["error": "ValueError"]}

File-mode records are handed to a daemon writer thread that serializes
and writes in batches (span close is one list append; json encoding and
the flush syscall overlap kernel execution, which releases the GIL).
``disable``/``restore`` drain the writer synchronously, so a reader that
follows the restore contract always sees every record.

A failing sink disables tracing with a ``RuntimeWarning`` instead of
failing the traced workload (mirroring ``core.profiling``'s recorder
contract): telemetry must never take the job down.
"""

from __future__ import annotations

import atexit
import collections
import itertools
import json
import os
import threading
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

TRACE_ENV = "REPRO_TRACE"

_BUFFER_MAX = 16384     # in-memory ring bound (records), no-path mode
_FLUSH_EVERY = 64       # pending records that wake the writer early
_WRITER_POLL_S = 0.5    # writer wakes at least this often for small tails

_enabled = False
_path: Optional[str] = None
_file = None
_lock = threading.Lock()
_ids = itertools.count(1)
_buffer: "collections.deque[dict]" = collections.deque(maxlen=_BUFFER_MAX)


class _Local(threading.local):
    def __init__(self):
        self.stack: List["Span"] = []


_local = _Local()


def enabled() -> bool:
    """The one gate every instrumentation site checks."""
    return _enabled


def trace_path() -> Optional[str]:
    """The active JSONL sink path (None = disabled or in-memory)."""
    return _path if _enabled else None


def enable(path: Optional[str] = None) -> Tuple[bool, Optional[str]]:
    """Turn tracing on. ``path`` appends JSONL records there; ``None``
    collects into the in-memory ring (:func:`drain`). Returns the previous
    ``(enabled, path)`` state for :func:`restore`."""
    global _enabled, _path
    prev = (_enabled, _path)
    if path != _path:
        _shutdown_writer()           # drain + close the old sink first
    with _lock:
        _path = path
    _enabled = True
    return prev


def disable() -> Tuple[bool, Optional[str]]:
    """Turn tracing off, drain pending records, and close the sink.
    Returns the previous state."""
    global _enabled, _path
    prev = (_enabled, _path)
    _enabled = False
    _shutdown_writer()
    with _lock:
        _path = None
    return prev


def restore(state: Tuple[bool, Optional[str]]) -> None:
    """Re-apply a state returned by :func:`enable`/:func:`disable` (the
    scope-exit half of ``tuning_config(trace_path=...)``)."""
    was_enabled, path = state
    if was_enabled:
        enable(path)
    else:
        disable()


def drain() -> List[dict]:
    """Read and clear the in-memory record ring (no-path mode)."""
    out = []
    with _lock:
        while _buffer:
            out.append(_buffer.popleft())
    return out


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple, set, frozenset)):
        return [_jsonable(x) for x in v]
    return str(v)


# -- batched background sink -------------------------------------------------
#
# File-mode emits only append the raw record to ``_pending``; a daemon
# writer thread serializes and writes in batches (the OTel
# BatchSpanProcessor shape). json.dumps and the flush syscall are the two
# biggest per-span costs, and moving them off-thread lets them overlap
# kernel execution (which releases the GIL), so a traced hot path pays one
# list append. disable()/enable(new path) drain synchronously, so readers
# that follow the restore contract always see every record.

_pending: List[dict] = []
_wake = threading.Condition(_lock)
_writer: Optional[threading.Thread] = None
_writer_stop = False


def _serialize(rec: dict) -> str:
    try:
        return json.dumps(rec, default=str)
    except TypeError:       # e.g. non-str dict keys in attrs
        return json.dumps(_jsonable(rec))


def _writer_loop() -> None:
    global _enabled, _file
    while True:
        with _wake:
            # sleep until a full batch accumulates (threshold notify), a
            # stop request, or a poll period passes with a small tail —
            # never spin on a trickle, which would contend for the GIL
            # with the traced workload the whole time it runs
            if not _writer_stop and len(_pending) < _FLUSH_EVERY:
                _wake.wait(_WRITER_POLL_S)
            if not _writer_stop and len(_pending) < _FLUSH_EVERY:
                _wake.wait(_WRITER_POLL_S)
            if not _pending and not _writer_stop:
                continue
            batch = _pending[:]
            del _pending[:]
            stop = _writer_stop
            path = _path
        if batch and path is not None:
            try:
                if _file is None:
                    d = os.path.dirname(path)
                    if d:
                        os.makedirs(d, exist_ok=True)
                    _file = open(path, "a")
                lines = []
                for i, r in enumerate(batch):
                    lines.append(_serialize(r) + "\n")
                    if i % 8 == 7:
                        # yield the GIL each few records: a GIL-bound
                        # traced workload (interpret-mode kernels) must
                        # never stall a full switch quantum behind a
                        # batch encode
                        time.sleep(0)
                _file.write("".join(lines))
                _file.flush()
            except Exception as e:   # noqa: BLE001 — sink failure must
                _enabled = False     # not take the traced workload down
                warnings.warn(
                    f"trace sink failed ({type(e).__name__}: {e}); "
                    f"tracing disabled", RuntimeWarning, stacklevel=2)
        if stop:
            return


def _shutdown_writer() -> None:
    """Stop the writer thread (draining pending records) and close the
    sink file. Only the writer touches ``_file`` while it runs, so the
    close after join is race-free."""
    global _writer, _writer_stop, _file
    with _wake:
        w = _writer
        _writer = None
        _writer_stop = True
        _wake.notify()
    if w is not None:
        w.join(timeout=10.0)
    with _lock:
        _writer_stop = False
        del _pending[:]
        if _file is not None:
            _file.close()
            _file = None


def _emit(rec: dict) -> None:
    global _enabled, _writer
    try:
        with _wake:
            if _path is None:
                _buffer.append(rec)
                return
            _pending.append(rec)
            if _writer is None or not _writer.is_alive():
                _writer = threading.Thread(
                    target=_writer_loop, name="repro-trace-writer",
                    daemon=True)
                _writer.start()
            if len(_pending) >= _FLUSH_EVERY:
                _wake.notify()
    except Exception as e:   # noqa: BLE001 — sink failure must not propagate
        _enabled = False
        warnings.warn(f"trace sink failed ({type(e).__name__}: {e}); "
                      f"tracing disabled", RuntimeWarning, stacklevel=2)


class _NoopSpan:
    """Shared do-nothing span (the disabled path). ``set`` chains so call
    sites never branch on the enabled state themselves."""

    __slots__ = ()

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NOOP_SPAN = _NoopSpan()


class Span:
    """One timed span. Use as a context manager; :meth:`set` attaches
    attributes any time before close (e.g. a plan source known only after
    resolution). Closing under an exception records ``status="error"`` and
    the exception type, then re-raises (``__exit__`` returns False)."""

    __slots__ = ("name", "attrs", "id", "parent", "t0")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self.id = next(_ids)
        self.parent: Optional[int] = None
        self.t0 = 0.0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        stack = _local.stack
        self.parent = stack[-1].id if stack else None
        stack.append(self)
        self.t0 = time.monotonic()
        return self

    def __exit__(self, etype, exc, tb) -> bool:
        dur = time.monotonic() - self.t0
        stack = _local.stack
        # unwind any child frames a non-context-manager misuse left open,
        # so one leak cannot mis-parent every later span on this thread
        while stack and stack[-1] is not self:
            stack.pop()
        if stack:
            stack.pop()
        rec: Dict[str, Any] = {
            "name": self.name, "id": self.id, "parent": self.parent,
            "ts": time.time(), "dur_s": dur,
            "thread": threading.get_ident(),
            "status": "ok" if etype is None else "error",
        }
        if etype is not None:
            rec["error"] = etype.__name__
        if self.attrs:
            # raw reference, not a _jsonable copy: stringification happens
            # in the writer thread (file mode) or not at all (memory ring)
            rec["attrs"] = self.attrs
        _emit(rec)
        return False


def span(name: str, **attrs):
    """Open a span named ``name`` with initial attributes. Returns the
    no-op singleton when tracing is disabled."""
    if not _enabled:
        return NOOP_SPAN
    return Span(name, dict(attrs))


def current_span():
    """The innermost open span on this thread (for attaching attributes
    from nested code), or the no-op singleton."""
    if not _enabled:
        return NOOP_SPAN
    stack = _local.stack
    return stack[-1] if stack else NOOP_SPAN


# REPRO_TRACE in the environment enables tracing for the whole process —
# the zero-code-change way to trace a launch driver or bench run
if os.environ.get(TRACE_ENV):
    enable(os.path.expanduser(os.environ[TRACE_ENV]))

# drain the batched sink at interpreter exit: a process that never calls
# disable() (REPRO_TRACE mode) would otherwise lose the writer's tail
atexit.register(_shutdown_writer)
