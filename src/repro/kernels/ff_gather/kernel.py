"""Feed-forward irregular gather as a StreamProgram: rows = table[idx].

The paper's *irregular memory access* case (Table 3, M-AI10-IR; MoE
dispatch / embedding lookup in our models). The index stream is scalar-
prefetched (TPU analogue of the FPGA burst-coalesced LSU's request buffer),
and each pipe word is a bundle of single-row DMAs issued ``depth-1`` words
ahead — memory-level parallelism for a pattern the MXU pipeline cannot
prefetch on its own. The per-row bundle is emitted through the shared
:class:`~repro.core.emitter.GatherRingPipe`: the rows *are* the stream
decomposition, so the planned ``streams`` value widens the bundle
(``rows_per_word = 8 * streams`` concurrent row DMAs, the multi-producer
analogue for irregular access) instead of being dropped.

A true-MLCD variant of this op (gather from a table the same kernel is
scattering into) is *rejected* by core.check_no_mlcd and deliberately has no
kernel here — the paper's legality restriction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pipe import Pipe
from repro.core.program import ScalarIn, Stream, StreamProgram, \
    compile_program

_ROWS = 8   # base rows per pipe word (one f32 sublane granule)


def build_program(n: int, cols: int, *, dtype=jnp.float32,
                  depth: int = 4, streams: int = 1) -> StreamProgram:
    """Declare the gather stream program: ``n`` output rows (a multiple of
    the ``8 * streams`` row bundle) pulled from a [R, cols] table."""
    rows_per_word = _ROWS * streams
    assert n % rows_per_word == 0, (n, rows_per_word)

    def row_slicer(ctx, word, r):
        row = ctx.ref("idx")[word * rows_per_word + r]
        return ctx.ref("table").at[pl.ds(row, 1), :]

    def consumer(ctx):
        ctx.out[...] = ctx.word("table")[...]

    return StreamProgram(
        name="ff_gather",
        n_words=n // rows_per_word,
        inputs=(
            ScalarIn("idx"),
            Stream("table",
                   Pipe(tile=(rows_per_word, cols), dtype=dtype, depth=depth),
                   row_slicer, gather=True),
        ),
        consumer=consumer,
        out_shape=(n, cols),
        out_dtype=dtype,
        out_block=(rows_per_word, cols),
        out_index_map=lambda g, idx: (g, 0),
    )


@functools.partial(jax.jit, static_argnames=("depth", "streams", "interpret"))
def gather_ff(table: jnp.ndarray, idx: jnp.ndarray, *, depth: int = 4,
              streams: int = 1, interpret: bool = True) -> jnp.ndarray:
    """table: [R, C]; idx: [n] int32 with n % (8 * streams) == 0.
    Returns [n, C]."""
    r, c = table.shape
    n = idx.shape[0]
    program = build_program(n, c, dtype=table.dtype, depth=depth,
                            streams=streams)
    return compile_program(program, interpret=interpret)(idx, table)
