"""repro.ops — thin kernel entrypoints generated from the kernel registry.

Every registered :class:`~repro.kernels.registry.KernelSpec` exposes its
public op here under its short alias (and its full ``ff_*`` name)::

    import repro
    y = repro.ops.matmul(a, b)                      # planner-sized pipes
    y = repro.ops.gather(table, idx,
                         policy=repro.PipePolicy(mode="baseline"))
    with repro.policy(mode="baseline"):
        y = repro.ops.attention(q, k, v)            # session default

Nothing is defined by hand: attributes resolve against the registry, so a
sixth registered kernel appears here automatically.
"""

from __future__ import annotations

from typing import Tuple

_cache = (-1, {})    # (registry_version, alias -> op)


def _aliases():
    from repro.kernels.registry import all_kernels, registry_version

    global _cache
    version = registry_version()
    if _cache[0] != version or not _cache[1]:
        out = {}
        for spec in all_kernels():
            out[spec.alias] = spec.op
            out[spec.name] = spec.op
        # all_kernels() may itself register (lazy import) — re-read version
        _cache = (registry_version(), out)
    return _cache[1]


def __getattr__(name):
    ops = _aliases()
    if name in ops:
        return ops[name]
    raise AttributeError(
        f"repro.ops has no op {name!r}; registered: "
        f"{sorted(k for k in ops if not k.startswith('ff_'))}")


def names() -> Tuple[str, ...]:
    """Short aliases of every registered op."""
    return tuple(sorted(k for k in _aliases() if not k.startswith("ff_")))


def __dir__():
    return sorted(set(list(globals()) + list(_aliases()) + ["names"]))
