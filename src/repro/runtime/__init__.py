"""repro.runtime — distributed substrate: sharding rules, overlap
collectives, pipeline parallelism, fault tolerance, elastic remesh,
straggler mitigation."""

from repro.runtime import (
    collectives,
    elastic,
    fault_tolerance,
    pipeline_parallel,
    sharding,
    stragglers,
)

__all__ = [
    "collectives", "elastic", "fault_tolerance", "pipeline_parallel",
    "sharding", "stragglers",
]
