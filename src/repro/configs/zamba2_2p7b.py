"""zamba2-2.7b [hybrid] — Mamba2 + shared attention blocks.
[arXiv:2411.15242; hf]  54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64. Shared transformer block applied every 6 Mamba2
layers (9 applications, one weight set)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="zamba2_2p7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every_n=6,
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    ssm_state=16,
    ssm_head_dim=16,
    attn_every_n=2,
    compute_dtype="float32",
)
