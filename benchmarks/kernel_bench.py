"""Kernel-level benchmark: modeled TPU-v5e time per ff_* kernel call from
each kernel's exact tile-schedule cost model (the CPU container cannot
time real TPU kernels), plus modeled FF-vs-baseline and M2C2 deltas.

Cases are enumerated from the kernel registry — each registered kernel's
``workload`` builder supplies the stream program at its ``bench_kwargs``
shape point, and the roofline planner reports the (depth, streams) it would
auto-pick there. Adding a kernel to the registry adds its row here."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import TPU_V5E, estimate_baseline, estimate_feedforward, \
    planned_pipe
from repro.kernels.registry import all_kernels


def rows():
    out = []
    for spec in all_kernels():
        kw = dict(spec.bench_kwargs)
        dtype = kw.get("dtype", jnp.float32)
        cost = spec.cost(**kw)
        w, tile = spec.workload(**kw)
        plan = planned_pipe(spec.name, w, tile, dtype, TPU_V5E)
        base = estimate_baseline(w, TPU_V5E)
        ff = estimate_feedforward(w, TPU_V5E, plan.pipe.with_streams(1))
        m2c2 = estimate_feedforward(w, TPU_V5E, plan.pipe.with_streams(2))
        out.append({
            "name": spec.name,
            "us_per_call": ff.total_s * 1e6,
            "ff_speedup": base.total_s / ff.total_s,
            "m2c2_extra": ff.total_s / m2c2.total_s,
            "hbm_gb": cost.hbm_bytes / 1e9,
            "gflops": cost.flops / 1e9,
            "bottleneck": ff.bottleneck,
            "vmem_kib": cost.vmem_bytes / 1024,
            "plan": f"d{plan.pipe.depth}s{plan.pipe.streams}",
        })
    return out


def main():
    print("# Kernel suite: modeled v5e time per call (tile-schedule costs,")
    print("# registry-enumerated; plan = planner's auto (depth, streams))")
    print("name,us_per_call,derived")
    for r in rows():
        print(f"kernels/{r['name']},{r['us_per_call']:.1f},"
              f"ff={r['ff_speedup']:.2f}x_m2c2+{(r['m2c2_extra']-1)*100:.0f}%"
              f"_{r['bottleneck']}_plan={r['plan']}")
        print(f"#  {r['name']:28s} {r['gflops']:9.1f} GF "
              f"{r['hbm_gb']:7.2f} GB  vmem {r['vmem_kib']:6.0f} KiB")


if __name__ == "__main__":
    main()
