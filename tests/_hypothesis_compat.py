"""Import shim for the property-based tests.

``hypothesis`` is a declared test dependency (pyproject.toml), but some
minimal environments can't install it. Importing ``given``/``settings``/
``st`` from here instead of from hypothesis keeps those modules
*collectable* everywhere: with hypothesis present this re-exports the real
API; without it, every ``@given``-decorated test turns into an explicit
skip while the plain tests in the same module still run.
"""

from __future__ import annotations

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Stands in for hypothesis.strategies: any strategy constructor
        returns an inert placeholder (the decorated test never runs)."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None

            return strategy

    st = _StrategyStub()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def decorate(fn):
            # deliberately NOT functools.wraps: the replacement must present
            # a zero-arg signature or pytest treats the strategy parameters
            # as fixtures
            def skip():
                pytest.skip("hypothesis not installed")

            skip.__name__ = fn.__name__
            skip.__doc__ = fn.__doc__
            return skip

        return decorate


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
