"""Public op wrapper + cost model for ff_matmul."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.emitter import cdiv, pad_to
from repro.core.pipe import Pipe
from repro.core.pipeline_model import Workload
from repro.core.program import PipePolicy, make_entrypoint
from repro.kernels.ff_matmul.kernel import build_program, matmul_ff
from repro.kernels.ff_matmul.ref import matmul_ref
from repro.kernels.registry import KernelCost, register_kernel


def matmul_cost(m: int, n: int, k: int,
                block: Tuple[int, int, int] = (128, 128, 128),
                dtype=jnp.float32, depth: int = 2, streams: int = 1) -> KernelCost:
    bm, bn, bk = block
    nm, nn, nk = cdiv(m, bm), cdiv(n, bn), cdiv(k, bk)
    itemsize = jnp.dtype(dtype).itemsize
    # A tile set is re-streamed once per ni; B once per mi; C written once.
    hbm = (nm * bm * nk * bk) * nn * itemsize \
        + (nk * bk * nn * bn) * nm * itemsize \
        + nm * bm * nn * bn * itemsize
    a_pipe = Pipe(tile=(bm, bk), dtype=dtype, depth=depth, streams=streams)
    b_pipe = Pipe(tile=(bk, bn), dtype=dtype, depth=depth, streams=streams)
    return KernelCost(
        flops=2.0 * m * n * k,
        hbm_bytes=float(hbm),
        vmem_bytes=a_pipe.vmem_bytes + b_pipe.vmem_bytes + bm * bn * 4,
    )


def matmul_workload(m: int, n: int, k: int,
                    block: Tuple[int, int, int] = (128, 128, 128),
                    dtype=jnp.float32) -> Tuple[Workload, Tuple[int, int]]:
    """The kernel's stream program in pipe words: one word per (mi, ni, ki)
    grid step, loading one A and one B tile. Planning tile = the A tile."""
    bm, bn, bk = block
    nm, nn, nk = cdiv(m, bm), cdiv(n, bn), cdiv(k, bk)
    itemsize = jnp.dtype(dtype).itemsize
    n_words = nm * nn * nk
    w = Workload(
        n_words=n_words,
        word_bytes=float((bm * bk + bk * bn) * itemsize),
        flops_per_word=2.0 * bm * bn * bk,
        regular=True,
        store_bytes_per_word=float(bm * bn * itemsize) / nk,
    )
    return w, (bm, bk)


# tile candidates the measured autotuner may search (mode="autotune");
# the default (128, 128, 128) block is always candidate #0.
_TILE_OPTIONS = (
    {"block": (256, 128, 128)},
    {"block": (128, 128, 256)},
    {"block": (128, 256, 128)},
    {"block": (256, 256, 128)},
)


def _apply(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block: Tuple[int, int, int] = (128, 128, 128),
    out_dtype=None,
    policy: PipePolicy,
) -> jnp.ndarray:
    """C = A @ B with auto-padding to the block grid.

    policy.mode="ff": DAE pipeline with policy-sized pipes (depth/streams
      "auto" size via the roofline planner against policy.hw).
    policy.mode="autotune": like "ff", but (block, depth, streams) come
      from the measured autotuner's plan cache for this call-site shape.
    policy.mode="baseline": synchronous copy-then-compute (depth=1) — the
      paper's single work-item strawman.
    policy.mode="ref": pure-jnp oracle (XLA-visible; used in model graphs
      and as the correctness reference).
    """
    if policy.mode == "ref":
        return matmul_ref(a, b, out_dtype)
    m, k = a.shape
    _, n = b.shape

    def _run(x, y, blk, depth, streams):
        bm, bn, bk = blk
        xp = pad_to(pad_to(x, bm, 0), bk, 1)
        yp = pad_to(pad_to(y, bk, 0), bn, 1)
        return matmul_ff(xp, yp, block=blk, depth=depth, streams=streams,
                         out_dtype=out_dtype, interpret=policy.interpret)

    w, tile = matmul_workload(m, n, k, block, a.dtype)
    choice = autotune.resolve_call(
        "ff_matmul", policy, workload=w, tile=tile, dtype=a.dtype,
        workload_fn=lambda tk: matmul_workload(
            m, n, k, tk.get("block", block), a.dtype),
        runner=None if autotune.has_tracers(a, b) else
        lambda tk, d, s: lambda: _run(a, b, tk.get("block", block), d, s),
        tile_options=_TILE_OPTIONS,
        extra_key="" if out_dtype is None else
        f"out={jnp.dtype(out_dtype).name}",
        site={"m": m, "n": n, "k": k, "block": tuple(block)},
        site_dynamic=("m", "n", "k"))
    out = _run(a, b, choice.tile_kwargs.get("block", block), choice.depth,
               choice.streams)
    return out[:m, :n]


matmul = make_entrypoint("ff_matmul", _apply)


def _make_inputs(key):
    a = jax.random.normal(key, (192, 136), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (136, 160), jnp.float32)
    return (a, b), {"block": (128, 128, 128)}


def _smoke_program(*, depth: int = 2, streams: int = 1, tile=None):
    # the smoke shape point of _make_inputs, padded to the block grid
    block = (tile or {}).get("block", (128, 128, 128))
    return build_program(256, 256, 256, block=block,
                         dtype=jnp.float32, depth=depth, streams=streams)


def _sweep_inputs(key, site):
    # rebuild concrete operands at a recorded call-site shape (plan sweep)
    m, n, k = int(site["m"]), int(site["n"]), int(site["k"])
    dt = jnp.dtype(site.get("dtype", "float32"))
    a = jax.random.normal(key, (m, k), dt)
    b = jax.random.normal(jax.random.fold_in(key, 1), (k, n), dt)
    return (a, b), {"block": tuple(site.get("block", (128, 128, 128)))}


register_kernel(
    name="ff_matmul",
    alias="matmul",
    op=matmul,
    ref=matmul_ref,
    cost=matmul_cost,
    workload=matmul_workload,
    program=_smoke_program,
    make_inputs=_make_inputs,
    bench_kwargs={"m": 4096, "n": 4096, "k": 4096, "dtype": jnp.bfloat16},
    tile_options=_TILE_OPTIONS,
    regular=True,
    tol=5e-4,
    doc="DAE blocked matmul (regular streams)",
    shard_dims=(0, None),        # A rows data-parallel, B replicated
    shard_out_dim=0,
    sweep_inputs=_sweep_inputs,
)
