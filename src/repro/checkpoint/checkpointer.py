"""Atomic, resumable, reshardable checkpoints.

Layout:  <dir>/step_<N>/
            manifest.json      tree structure, shapes, dtypes, sha256 per leaf
            arrays.npz         one entry per flattened leaf
         <dir>/LATEST          text file with the newest complete step dir

Write protocol: serialize into ``step_N.tmp-<pid>`` -> fsync -> atomic
rename -> update LATEST. A crash mid-write leaves only tmp dirs, which
restore ignores (and cleanup removes) — the fault-tolerance kill test
asserts exactly this.

Restore takes an optional ``shardings`` pytree so a checkpoint written on
one mesh can be loaded onto another (elastic remesh): arrays round-trip
through host numpy and are re-placed with ``jax.device_put``.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_LATEST = "LATEST"


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, tree: Any, *, extra: Optional[Dict] = None,
         keep_last: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = f"{final}.tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    manifest = {
        "step": step,
        "extra": extra or {},
        "leaves": {k: {
            "shape": list(a.shape),
            "dtype": str(a.dtype),
            "sha256": hashlib.sha256(a.tobytes()).hexdigest(),
        } for k, a in arrays.items()},
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)                     # atomic publish
    with open(os.path.join(ckpt_dir, _LATEST + ".tmp"), "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(ckpt_dir, _LATEST + ".tmp"),
               os.path.join(ckpt_dir, _LATEST))
    _gc(ckpt_dir, keep_last)
    return final


def save_async(ckpt_dir: str, step: int, tree: Any, **kw) -> threading.Thread:
    """Host-offloaded async save: device->host copy happens synchronously
    (cheap), serialization on a worker thread (the slow part)."""
    host_tree = jax.tree.map(np.asarray, tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree),
                         kwargs=kw, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp") and ".tmp-" not in d)
    for d in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    # sweep crashed partial writes
    for d in os.listdir(ckpt_dir):
        if ".tmp-" in d:
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, _LATEST)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, tree_like: Any, *, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True):
    """Restore into the structure of ``tree_like`` (arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings for elastic placement. Returns (tree, step, extra)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    flat_keys = list(_flatten_with_paths(tree_like).keys())
    missing = [k for k in flat_keys if k not in data]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
    if verify:
        for k in flat_keys:
            h = hashlib.sha256(data[k].tobytes()).hexdigest()
            if h != manifest["leaves"][k]["sha256"]:
                raise IOError(f"checksum mismatch for {k} in {d}")
    arrays = {k: data[k] for k in flat_keys}

    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    flat_sh = (treedef.flatten_up_to(shardings)
               if shardings is not None else [None] * len(leaves))
    paths, _ = zip(*jax.tree_util.tree_flatten_with_path(tree_like)[0])
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path in paths]
    out = []
    for key, like, sh in zip(keys, leaves, flat_sh):
        a = arrays[key]
        if sh is not None:
            out.append(jax.device_put(a, sh))
        else:
            out.append(jax.numpy.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, out), step, manifest["extra"]
