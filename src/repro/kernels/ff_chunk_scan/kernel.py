"""Feed-forward chunked gated-linear-attention scan (Mamba2 / RWKV6
family), as a StreamProgram.

This kernel is the paper's Figure-3 move (DLCD -> compute kernel) made
literal. The recurrence

    h_t = diag(w_t) h_{t-1} + k_t (x) v_t            (true data LCD)
    y_t = q_t . h_t              (inclusive; Mamba2:  w scalar per head)
    y_t = q_t . (h_{t-1} + diag(u) k_t (x) v_t)      (exclusive+bonus; RWKV6)

serializes a naive implementation at II = chain length. The feed-forward
split streams the *LCD-free* operands (q,k,v,w chunks) through four ring-
pipe edges at full depth, while the consumer carries the only true
dependency — the O(N*P) chunk-boundary state — in VMEM across grid steps.

Numerics: all decay exponents are arranged to be <= 0 ("decay-to-boundary"
factorization), so every exp() is in (0,1] and f32-stable:

* inter-chunk:   q_t * exp(cw_t [- lw_t])                  (<= 0)
* intra, tile pair J<I with boundary b = start(I)-1:
      A_ts = (q_t e^{cw_t - cw_b [- lw_t]}) . (k_s e^{cw_b - cw_s})
  both exponents are sums of log-decays over non-empty ranges   (<= 0)
* diagonal tile: exact pairwise exponent, clamped at 0 under the mask
* state update:  k_s * exp(cw_last - cw_s)                  (<= 0)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pipe import Pipe
from repro.core.program import BlockIn, ScratchSpec, Stream, StreamProgram, \
    compile_program


def _chunk_body(q, k, v, lw, u, h_prev, *, subtile: int, inclusive: bool):
    """One chunk of the scan. All f32. Shapes: q,k,lw [L,N]; v [L,P];
    u [N] or None; h_prev [N,P]. Returns (y [L,P], h_new [N,P])."""
    L, n = q.shape
    p = v.shape[1]
    t = subtile
    nt = L // t
    cw = jnp.cumsum(lw, axis=0)                       # inclusive cumsum [L,N]
    q_decay = cw - lw if not inclusive else cw        # exponent for q side

    # ---- inter-chunk: contribution of the carried state ------------------
    qd = q * jnp.exp(q_decay)                         # [L,N], exp<=0
    y = jnp.dot(qd, h_prev, preferred_element_type=jnp.float32)   # [L,P]

    # ---- intra-chunk: tile-pair matmuls (J < I) + exact diagonal ---------
    for i in range(nt):
        t0 = i * t
        cw_b = cw[t0 - 1] if t0 > 0 else jnp.zeros((n,), jnp.float32)
        qt = q[t0:t0 + t]
        cwt = cw[t0:t0 + t]
        lwt = lw[t0:t0 + t]
        q_exp = cwt - cw_b[None, :] - (0.0 if inclusive else lwt)
        q_i = qt * jnp.exp(q_exp)                     # [t,N], exp<=0
        acc = jnp.zeros((t, p), jnp.float32)
        for j in range(i):
            s0 = j * t
            k_j = k[s0:s0 + t] * jnp.exp(cw_b[None, :] - cw[s0:s0 + t])
            a = jax.lax.dot_general(
                q_i, k_j, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)   # [t,t]
            acc += jnp.dot(a, v[s0:s0 + t], preferred_element_type=jnp.float32)
        # diagonal tile: exact pairwise exponents
        cws = cwt
        e = cwt[:, None, :] - cws[None, :, :]
        if not inclusive:
            e = e - lwt[:, None, :]
        e = jnp.minimum(e, 0.0)                       # masked entries clamped
        a_diag = jnp.sum(qt[:, None, :] * jnp.exp(e) * k[t0:t0 + t][None, :, :],
                         axis=-1)                     # [t,t]
        rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
        keep = (rows >= cols) if inclusive else (rows > cols)
        a_diag = jnp.where(keep, a_diag, 0.0)
        acc += jnp.dot(a_diag, v[t0:t0 + t], preferred_element_type=jnp.float32)
        y = jax.lax.dynamic_update_slice_in_dim(y, y[t0:t0 + t] + acc, t0, 0)

    # ---- bonus (RWKV6 u-term): current token, undecayed -------------------
    if u is not None:
        c = jnp.sum(q * u[None, :] * k, axis=1, keepdims=True)   # [L,1]
        y = y + c * v

    # ---- state update ------------------------------------------------------
    k2 = k * jnp.exp(cw[-1][None, :] - cw)            # [L,N], exp<=0
    h_new = jnp.exp(cw[-1])[:, None] * h_prev + jax.lax.dot_general(
        k2, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    return y, h_new


def build_program(bh: int, s: int, n: int, p: int, *,
                  chunk: int = 64, subtile: int = 16, inclusive: bool = True,
                  has_u: bool = False, dtype=jnp.float32, k_dtype=None,
                  v_dtype=None, w_dtype=None, out_dtype=None,
                  depth: int = 2, streams: int = 1) -> StreamProgram:
    """Declare the chunked-scan stream program at one shape point.
    ``dtype`` is the q/out element type; ``k_dtype``/``v_dtype``/``w_dtype``
    (default ``dtype``) size their own pipe edges."""
    assert s % chunk == 0 and chunk % subtile == 0, (s, chunk, subtile)
    nc = s // chunk
    out_dtype = out_dtype or dtype
    q_spec = Pipe(tile=(chunk, n), dtype=dtype, depth=depth, streams=streams)
    k_spec = Pipe(tile=(chunk, n), dtype=k_dtype or dtype, depth=depth,
                  streams=streams)
    w_spec = Pipe(tile=(chunk, n), dtype=w_dtype or dtype, depth=depth,
                  streams=streams)
    v_spec = Pipe(tile=(chunk, p), dtype=v_dtype or dtype, depth=depth,
                  streams=streams)

    def slicer(name):
        def f(ctx, word):
            w_c = word % nc
            w_bh = word // nc
            return ctx.ref(name).at[w_bh, pl.ds(w_c * chunk, chunk), :]
        return f

    def consumer(ctx):
        c = ctx.g % nc
        h_sc = ctx.scratch("h")

        @pl.when(c == 0)
        def _():
            h_sc[...] = jnp.zeros_like(h_sc)

        q = ctx.word("q")[...].astype(jnp.float32)
        k = ctx.word("k")[...].astype(jnp.float32)
        v = ctx.word("v")[...].astype(jnp.float32)
        lw = jnp.minimum(ctx.word("w")[...].astype(jnp.float32), 0.0)
        u = ctx.ref("u")[0].astype(jnp.float32) if has_u else None

        y, h_new = _chunk_body(q, k, v, lw, u, h_sc[...],
                               subtile=subtile, inclusive=inclusive)
        h_sc[...] = h_new
        ctx.out[0] = y.astype(out_dtype)

    return StreamProgram(
        name="ff_chunk_scan",
        n_words=bh * nc,
        inputs=(
            # all four streams walk (bh, chunk)-major; the index declares
            # that schedule in each pipe's (chunk, cols) blocking of the
            # row-flattened [BH*S, cols] operand view (a fused producer
            # edge declares reshape=(bh*s, cols)), matching the slicer
            Stream("q", q_spec, slicer("q"), index=lambda w: (w, 0)),
            Stream("k", k_spec, slicer("k"), index=lambda w: (w, 0)),
            Stream("v", v_spec, slicer("v"), index=lambda w: (w, 0)),
            Stream("w", w_spec, slicer("w"), index=lambda w: (w, 0)),
            BlockIn("u", (1, n), lambda g: (g // nc, 0), dtype=dtype),
        ),
        consumer=consumer,
        out_shape=(bh, s, p),
        out_dtype=out_dtype,
        out_block=(1, chunk, p),
        out_index_map=lambda g: (g // nc, g % nc, 0),
        scratch=(ScratchSpec("h", (n, p), jnp.float32),),
    )


@functools.partial(
    jax.jit,
    static_argnames=("chunk", "subtile", "inclusive", "depth", "streams",
                     "interpret"))
def chunk_scan_ff(
    q: jnp.ndarray,               # [BH, S, N]
    k: jnp.ndarray,               # [BH, S, N]
    v: jnp.ndarray,               # [BH, S, P]
    log_w: jnp.ndarray,           # [BH, S, N] log-decay (<= 0)
    u: jnp.ndarray = None,        # [BH, N] bonus (RWKV6) or None
    *,
    chunk: int = 64,
    subtile: int = 16,
    inclusive: bool = True,
    depth: int = 2,
    streams: int = 1,
    interpret: bool = True,
) -> jnp.ndarray:
    bh, s, n = q.shape
    p = v.shape[2]
    has_u = u is not None
    program = build_program(bh, s, n, p, chunk=chunk, subtile=subtile,
                            inclusive=inclusive, has_u=has_u, dtype=q.dtype,
                            k_dtype=k.dtype, v_dtype=v.dtype,
                            w_dtype=log_w.dtype, depth=depth, streams=streams)
    u_arg = u if has_u else jnp.zeros((bh, n), q.dtype)
    return compile_program(program, interpret=interpret)(
        q, k, v, log_w, u_arg)
