"""repro.runtime — distributed substrate: sharding rules, overlap
collectives, pipeline parallelism, fault tolerance, elastic remesh,
straggler mitigation."""

from repro.runtime import (
    collectives,
    elastic,
    fault_tolerance,
    pipeline_parallel,
    sharding,
    stragglers,
)

__all__ = [
    "chaos", "collectives", "elastic", "fault_tolerance",
    "pipeline_parallel", "sharding", "stragglers",
]


def __getattr__(name):
    # lazy: chaos is also an entrypoint (python -m repro.runtime.chaos);
    # importing it eagerly here would shadow the runpy execution
    if name == "chaos":
        import importlib
        return importlib.import_module("repro.runtime.chaos")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
