"""Public op wrapper + cost model for ff_gather."""

from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.emitter import cdiv
from repro.core.pipeline_model import Workload
from repro.core.planner import resolve_auto
from repro.kernels.ff_gather.kernel import _ROWS, gather_ff
from repro.kernels.ff_gather.ref import gather_ref
from repro.kernels.registry import KernelCost, register_kernel


def gather_cost(n: int, cols: int, *, depth: int = 4,
                dtype=jnp.float32) -> KernelCost:
    itemsize = jnp.dtype(dtype).itemsize
    return KernelCost(
        flops=0.0,
        hbm_bytes=float(2 * n * cols * itemsize + n * 4),
        vmem_bytes=depth * _ROWS * cols * itemsize,
    )


def gather_workload(n: int, cols: int, *,
                    dtype=jnp.float32) -> Tuple[Workload, Tuple[int, int]]:
    """One word per 8-row bundle of irregular single-row loads — the
    paper's IR access pattern: latency per word, hidden by (depth-1) x rows
    outstanding row DMAs."""
    itemsize = jnp.dtype(dtype).itemsize
    w = Workload(
        n_words=max(cdiv(n, _ROWS), 1),
        word_bytes=float(_ROWS * cols * itemsize),
        flops_per_word=0.0,
        regular=False,
        store_bytes_per_word=float(_ROWS * cols * itemsize),
    )
    return w, (_ROWS, cols)


def gather(table, idx, *, depth: Union[int, str] = 4,
           streams: Union[int, str] = 1, mode: str = "ff",
           interpret: bool = True):
    """rows = table[idx]; mode="ff"|"baseline"(depth=1)|"ref".

    depth accepts "auto" (planner-sized for the irregular stream). streams
    is accepted for API uniformity but the row bundle *is* the stream
    decomposition here (8 concurrent row DMAs per word), so the planned
    value only affects the model, not emission.
    """
    if mode == "ref":
        return gather_ref(table, idx)
    n = idx.shape[0]
    cols = table.shape[1]
    w, tile = gather_workload(n, cols, dtype=table.dtype)
    depth, _streams = resolve_auto("ff_gather", depth, streams,
                                   workload=w, tile=tile, dtype=table.dtype)
    pad = (-n) % _ROWS
    idx_p = jnp.pad(idx.astype(jnp.int32), (0, pad))
    if mode == "baseline":
        depth = 1
    out = gather_ff(table, idx_p, depth=depth, interpret=interpret)
    return out[:n]


def _make_inputs(key):
    tab = jax.random.normal(key, (96, 128), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (52,), 0, 96)
    return (tab, idx), {}


register_kernel(
    name="ff_gather",
    op=gather,
    ref=gather_ref,
    cost=gather_cost,
    workload=gather_workload,
    make_inputs=_make_inputs,
    bench_kwargs={"n": 1 << 20, "cols": 512, "dtype": jnp.float32},
    regular=False,
    tol=0.0,
    doc="irregular row gather (embedding / MoE dispatch)",
)
