"""Public op wrapper + cost model for ff_chunk_scan."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dae import pad_to
from repro.kernels.ff_chunk_scan.kernel import chunk_scan_ff
from repro.kernels.ff_chunk_scan.ref import chunk_scan_ref, chunk_scan_xla
from repro.kernels.ff_matmul.ops import KernelCost


def chunk_scan_cost(bh: int, s: int, n: int, p: int, *, chunk: int = 64,
                    depth: int = 2, dtype=jnp.bfloat16) -> KernelCost:
    nc = max(s // chunk, 1)
    # per chunk: inter [L,N]@[N,P], intra ~L^2(N+P)/2, state [N,L]@[L,P]
    per_chunk = 2.0 * chunk * n * p * 2 + chunk * chunk * (n + p)
    itemsize = jnp.dtype(dtype).itemsize
    hbm = bh * s * (3 * n + 2 * p) * itemsize     # q,k,w in; v in; y out
    vmem = depth * chunk * (3 * n + p) * itemsize + n * p * 4
    return KernelCost(flops=bh * nc * per_chunk, hbm_bytes=float(hbm),
                      vmem_bytes=vmem)


def chunk_scan(q, k, v, log_w, u=None, *, chunk: int = 64, subtile: int = 16,
               inclusive: bool = True, depth: int = 2, streams: int = 1,
               mode: str = "ff", interpret: bool = True):
    """Gated linear-attention scan over [BH, S, *] streams.

    mode="ff"|"baseline"(depth=1)|"ref"(naive scan)|"xla"|"xla_tiled"
    (chunked, HLO-visible; _tiled = tile-pair factorized intra-chunk).
    Pads S up to a chunk multiple (decay 1, zero k/v contribute nothing).
    """
    if mode == "ref":
        return chunk_scan_ref(q, k, v, log_w, u, inclusive=inclusive)
    if mode in ("xla", "xla_tiled"):
        s = q.shape[1]
        qp, kp, vp = (pad_to(x, chunk, 1) for x in (q, k, v))
        lwp = pad_to(log_w, chunk, 1)
        return chunk_scan_xla(qp, kp, vp, lwp, u, chunk=chunk,
                              inclusive=inclusive,
                              tiled=mode == "xla_tiled")[:, :s]
    s = q.shape[1]
    qp, kp, vp = (pad_to(x, chunk, 1) for x in (q, k, v))
    lwp = pad_to(log_w, chunk, 1)
    if mode == "baseline":
        depth = 1
    out = chunk_scan_ff(qp, kp, vp, lwp, u, chunk=chunk, subtile=subtile,
                        inclusive=inclusive, depth=depth, streams=streams,
                        interpret=interpret)
    return out[:, :s]
