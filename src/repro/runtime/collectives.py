"""Overlap-friendly collectives (shard_map building blocks).

The feed-forward model at mesh scale: communication is the producer, the MXU
is the consumer, and `ppermute` rings are the pipes. ``allgather_matmul``
and ``matmul_reducescatter`` interleave each ring hop with the partial
matmul it feeds — the collective version of the kernel-level DAE schedule
(hop k+1 is in flight while chunk k multiplies), XLA overlaps the
independent ppermute with the dot.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def axis_size(axis_name: str) -> int:
    """Static size of a mapped axis. jax >= 0.5 has jax.lax.axis_size;
    older versions constant-fold psum(1, axis) to the same int."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def ring_allgather(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """All-gather along ``axis_name`` via a ppermute ring (shard_map body).
    Returns the concatenation over devices along dim 0."""
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    chunks = [x]
    cur = x
    for _ in range(n - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        chunks.append(cur)

    def roll_back(i, c):
        return c  # chunk i holds shard (idx - i) mod n

    # reorder so output is device-order independent
    out = jnp.zeros((n, *x.shape), x.dtype)
    for i, c in enumerate(chunks):
        src = (idx - i) % n
        out = out.at[src].set(c)
    return out.reshape(n * x.shape[0], *x.shape[1:])


def allgather_matmul(x_shard: jnp.ndarray, w: jnp.ndarray,
                     axis_name: str) -> jnp.ndarray:
    """Compute (allgather(x) @ w) with per-hop overlap.

    x_shard: [m_shard, k] (sharded on rows over ``axis_name``); w: [k, n]
    replicated. Returns [m_shard * n_dev, n] — each hop's chunk multiplies
    while the next hop's ppermute is in flight.
    """
    n_dev = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    m = x_shard.shape[0]
    out = jnp.zeros((n_dev, m, w.shape[1]),
                    jnp.promote_types(x_shard.dtype, w.dtype))
    cur = x_shard
    for i in range(n_dev):
        src = (idx - i) % n_dev
        part = jnp.dot(cur, w, preferred_element_type=out.dtype)  # consumer
        out = out.at[src].set(part)
        if i + 1 < n_dev:
            cur = jax.lax.ppermute(cur, axis_name, perm)          # producer
    return out.reshape(n_dev * m, w.shape[1])


def matmul_reducescatter(x: jnp.ndarray, w_shard: jnp.ndarray,
                         axis_name: str) -> jnp.ndarray:
    """Compute reduce_scatter(x @ allgathered-w) in ring form: each step
    multiplies one weight shard and shifts the partial sum — the ring
    reduce-scatter fused with the matmul that produces it.

    x: [m, k_shard] (k sharded); w_shard: [k_shard, n]. Output: [m, n]
    reduced over the axis, scattered by rows: returns [m // n_dev, n].
    """
    n_dev = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i - 1) % n_dev) for i in range(n_dev)]
    m = x.shape[0]
    rows = m // n_dev
    acc = jnp.zeros((rows, w_shard.shape[1]),
                    jnp.promote_types(x.dtype, w_shard.dtype))
    for i in range(n_dev):
        blk = (idx + 1 + i) % n_dev
        x_blk = jax.lax.dynamic_slice_in_dim(x, blk * rows, rows, axis=0)
        part = jnp.dot(x_blk, w_shard, preferred_element_type=acc.dtype)
        acc = acc + part
        if i + 1 < n_dev:
            acc = jax.lax.ppermute(acc, axis_name, perm)
    return acc
