"""Shared building blocks for all model families.

Params are plain nested dicts of arrays. Each model module declares its
parameters as :class:`ParamSpec` trees, which give us three views for free:

  * ``init``      — materialized random params (smoke tests / real training)
  * ``abstract``  — ShapeDtypeStruct stand-ins (dry-run lowering, no alloc)
  * ``axes``      — logical sharding axes per leaf (runtime.sharding rules)

Attention/scan/matmul call sites go through ``repro.kernels`` wrappers with
an ``impl`` switch: "xla" (HLO-visible reference path — used when lowering
for the dry-run and on CPU) or "ff" (the feed-forward Pallas kernels — the
TPU fast path, validated in interpret mode).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.sharding import constrain

# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"          # "normal" | "zeros" | "ones" | "small"
    scale: Optional[float] = None  # override fan-in scale

    def initializer(self, key: jax.Array) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "small":
            return 0.01 * jax.random.normal(key, self.shape, self.dtype)
        # fan-in = product of all non-output dims, skipping the stacked layer
        # dim (a [d, heads, hd] projection must scale by 1/sqrt(d), not
        # 1/sqrt(heads) — the old shape[-2] rule exploded wide attention)
        dims = self.shape
        if self.axes and self.axes[0] == "layers":
            dims = dims[1:]
        fan_in = max(int(np.prod(dims[:-1])), 1) if len(dims) >= 2 \
            else dims[-1]
        scale = self.scale if self.scale is not None else 1.0 / math.sqrt(fan_in)
        return scale * jax.random.normal(key, self.shape, self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key: jax.Array):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [s.initializer(k) for s, k in zip(leaves, keys)])


def abstract_params(specs):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec)


def param_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


# NOTE (§Perf it5, refuted): applying the norm scale in bf16 (f32 stats
# only) was tried to shrink boundary collectives; collective bytes did not
# move and HBM bytes **rose** 18% (lost fusion in the backward). Reverted to
# f32-internal norms.
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_apply(kind: str, x, p):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


def norm_specs(kind: str, d: int) -> Dict[str, ParamSpec]:
    s = {"w": ParamSpec((d,), ("embed",), init="ones")}
    if kind == "layernorm":
        s["b"] = ParamSpec((d,), ("embed",), init="zeros")
    return s


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
         dim: Optional[int] = None) -> jnp.ndarray:
    """Rotary embedding. x: [..., S, H, D]; positions: [..., S]."""
    d = dim or x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs   # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                            # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:d]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if d < x.shape[-1]:
        rot = jnp.concatenate([rot, x[..., d:]], axis=-1)
    return rot.astype(x.dtype)


def sinusoidal_positions(s: int, d: int) -> jnp.ndarray:
    pos = np.arange(s)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32)


# ---------------------------------------------------------------------------
# Attention (GQA) — XLA reference path + kernel fast path
# ---------------------------------------------------------------------------


_Q_CHUNK = 1024


def _attention_xla_block(q, k, v, *, causal, q_offset, positions_q=None,
                         lengths=None) -> jnp.ndarray:
    b, s, h, d = q.shape
    kvh = k.shape[2]
    group = h // kvh
    qg = q.reshape(b, s, kvh, group, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    skv = k.shape[1]
    if causal:
        qpos = (positions_q if positions_q is not None
                else q_offset + jnp.arange(s))
        mask = qpos[:, None] >= jnp.arange(skv)[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    if lengths is not None:
        mask = jnp.arange(skv)[None, :] < lengths[:, None]      # [B, Skv]
        scores = jnp.where(mask[:, None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, v.shape[-1]).astype(q.dtype)


def attention_xla(q, k, v, *, causal: bool, positions_q=None,
                  lengths=None) -> jnp.ndarray:
    """q: [B,S,H,D]; k,v: [B,Skv,KVH,D] -> [B,S,H,D]. HLO-visible path.

    This is the roofline *baseline*: scores materialize through HBM exactly
    like the paper's baseline round-trips global memory. Long sequences are
    processed in q-chunks (scan) so the live score block stays bounded at
    [B, H, _Q_CHUNK, Skv] — the un-fused-but-not-insane baseline a careful
    XLA user would write.
    """
    b, s, h, d = q.shape
    if s <= _Q_CHUNK or s % _Q_CHUNK != 0 or positions_q is not None:
        return _attention_xla_block(q, k, v, causal=causal, q_offset=0,
                                    positions_q=positions_q, lengths=lengths)
    # statically unrolled q-chunks: a lax.map here would hide the chunk body
    # from cost_analysis (loop bodies are counted once — DESIGN.md §4)
    outs = []
    for i in range(s // _Q_CHUNK):
        qc = jax.lax.slice_in_dim(q, i * _Q_CHUNK, (i + 1) * _Q_CHUNK, axis=1)
        outs.append(_attention_xla_block(qc, k, v, causal=causal,
                                         q_offset=i * _Q_CHUNK,
                                         lengths=lengths))
    return jnp.concatenate(outs, axis=1)


def _session_kernel_policy(interpret: bool):
    """Derive the kernel policy from the session `repro.policy` context (so
    no-touch A/B runs reach model code), pinning only what the layer
    contract fixes; modes the attention kernels don't speak (e.g.
    chunk_scan's "xla") fall back to "ff". "autotune" passes through — the
    serve/train ``--policy-mode autotune`` path and the plan service
    (record/replay through the PlanDB lookup chain) depend on it."""
    from repro.core.program import current_policy
    pol = current_policy()
    if pol.mode not in ("ff", "baseline", "ref", "autotune"):
        pol = pol.replace(mode="ff")
    return pol.replace(interpret=interpret)


def _session_scan_policy(cfg_impl: str):
    """Scan-kernel policy: the model config pins the default impl, but an
    explicit session mode override (anything but the "ff" session default)
    wins — so `with repro.policy(mode="baseline")` A/B runs reach the
    chunk_scan call sites too. To force pipelined scans by default, set
    cfg.scan_impl="ff" rather than a session policy."""
    from repro.core.program import current_policy
    pol = current_policy()
    return pol.replace(mode=pol.mode if pol.mode != "ff" else cfg_impl)


def attention_op(q, k, v, *, causal: bool, impl: str = "xla",
                 lengths=None, interpret: bool = True) -> jnp.ndarray:
    """Dispatch between the XLA path and the ff_attention Pallas kernel."""
    if impl == "xla":
        return attention_xla(q, k, v, causal=causal, lengths=lengths)
    from repro.kernels.ff_attention import attention as ff_attn
    b, s, h, d = q.shape
    kvh = k.shape[2]
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kh = k.transpose(0, 2, 1, 3).reshape(b * kvh, k.shape[1], d)
    vh = v.transpose(0, 2, 1, 3).reshape(b * kvh, v.shape[1], d)
    block_q = min(128, max(8, s))
    out = ff_attn(qh, kh, vh, kv_groups=h // kvh, causal=causal,
                  block_q=block_q, block_kv=128,
                  policy=_session_kernel_policy(interpret))
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def decode_attention_op(q, k, v, lengths, *, impl: str = "xla",
                        interpret: bool = True,
                        block_kv: Optional[int] = None) -> jnp.ndarray:
    """q: [B,H,D] one token; k,v: [B,Skv,KVH,D] cache; lengths: [B].
    ``block_kv`` pins the ff KV tile (serving pins it to the paged cache's
    page size for bitwise parity); None picks the traffic heuristic."""
    if impl == "xla":
        out = attention_xla(q[:, None], k, v, causal=False, lengths=lengths)
        return out[:, 0]
    from repro.kernels.ff_decode_attention import decode_attention as ff_dec
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    # the kernel streams whole KV tiles: round the cache up to the block
    # (rows past `lengths` are masked inside the kernel, so zero-padding
    # is free of numerics). For unpinned block_kv pick the tile that
    # minimizes padded traffic (skv=130 streams 160 rows at block 32, not
    # 256 at block 128), preferring larger tiles on ties (fewer DMAs).
    skv = k.shape[1]
    if block_kv is None:
        if skv <= 128:
            block_kv = -(-skv // 8) * 8
        else:
            block_kv = min((128, 64, 32),
                           key=lambda blk: (-(-skv // blk) * blk, -blk))
    pad = -skv % block_kv
    if pad:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return ff_dec(q, kh, vh, lengths, block_kv=block_kv,
                  policy=_session_kernel_policy(interpret))


def paged_decode_attention_op(q, kv_pool, block_tables, lengths, *,
                              impl: str = "xla",
                              interpret: bool = True) -> jnp.ndarray:
    """Decode attention through a paged KV pool (continuous batching).

    q: [B,H,D] one token; kv_pool: [nb, 2, page, KVH, D] (one layer's
    block pool); block_tables: [B, n_pages] (entries >= nb are sentinels);
    lengths: [B] (0 = inactive slot). "xla" dereferences the table densely;
    "ff" runs the fused gather->attention StreamGraph.
    """
    if impl == "xla":
        nb, _, page, kvh, d = kv_pool.shape
        b = q.shape[0]
        npg = block_tables.shape[-1]
        bt = jnp.clip(block_tables.astype(jnp.int32), 0, nb - 1)
        kv = kv_pool[bt]                  # [B, npg, 2, page, KVH, D]
        k = kv[:, :, 0].reshape(b, npg * page, kvh, d)
        v = kv[:, :, 1].reshape(b, npg * page, kvh, d)
        out = attention_xla(q[:, None], k, v, causal=False, lengths=lengths)
        return out[:, 0]
    from repro.runtime.paged_kv import paged_decode_attention
    return paged_decode_attention(q, kv_pool, block_tables, lengths,
                                  policy=_session_kernel_policy(interpret))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(d: int, f: int, act: str) -> Dict[str, ParamSpec]:
    s = {"wo": ParamSpec((f, d), ("mlp", "embed"))}
    if act == "swiglu":
        s["wi"] = ParamSpec((d, 2 * f), ("embed", "mlp"))
    else:
        s["wi"] = ParamSpec((d, f), ("embed", "mlp"))
        s["bi"] = ParamSpec((f,), ("mlp",), init="zeros")
        s["bo"] = ParamSpec((d,), ("embed",), init="zeros")
    return s


def mlp_apply(p, x, act: str) -> jnp.ndarray:
    dt = x.dtype
    if act == "swiglu":
        gate_up = x @ p["wi"].astype(dt)
        gate, up = jnp.split(gate_up, 2, axis=-1)
        h = jax.nn.silu(gate) * up
        return h @ p["wo"].astype(dt)
    h = x @ p["wi"].astype(dt) + p["bi"].astype(dt)
    h = jax.nn.gelu(h)
    return h @ p["wo"].astype(dt) + p["bo"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------


@jax.custom_vjp
def bf16_grad_barrier(x):
    """Identity whose cotangent is cast to bf16: placed between the (f32)
    loss and the decoder stack so every backward all-reduce below runs in
    bf16 — halves TP-boundary collective bytes (§Perf 'bf16 grads')."""
    return x


def _bgb_fwd(x):
    return x, None


def _bgb_bwd(_, ct):
    return (ct.astype(jnp.bfloat16).astype(ct.dtype)
            if ct.dtype == jnp.float32 else ct,)


# NOTE: casting f32->bf16->f32 keeps dtypes consistent for jax while
# quantizing the cotangent mantissa; XLA then propagates the cheap form.
bf16_grad_barrier.defvjp(_bgb_fwd, _bgb_bwd)


@jax.custom_vjp
def bf16_grad_cast(x):
    """Identity fwd; bwd converts the cotangent to true bf16 (dtype change).
    Valid where the primal is bf16 (cotangent dtype must match primal)."""
    return x


def _bgc_fwd(x):
    return x, jnp.zeros((0,), x.dtype)    # dtype token (valid JAX residual)


def _bgc_bwd(tok, ct):
    return (ct.astype(tok.dtype),)


bf16_grad_cast.defvjp(_bgc_fwd, _bgc_bwd)


def embed_specs(vocab: int, d: int) -> ParamSpec:
    return ParamSpec((vocab, d), ("vocab", "embed"), scale=0.02)


def embed_lookup(table: jnp.ndarray, tokens: jnp.ndarray,
                 compute_dtype) -> jnp.ndarray:
    out = jnp.take(table, tokens, axis=0).astype(compute_dtype)
    return constrain(out, ("batch", "seq", "embed"))


def unembed_logits(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """x: [B,S,D] -> logits [B,S,V] (bf16, sharded batch x vocab)."""
    logits = x @ table.T.astype(x.dtype)
    return constrain(logits, ("batch", "seq", "vocab"))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 1e-4) -> jnp.ndarray:
    """Mean token CE in f32, with a z-loss regularizer (stabilizes bf16)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * lse ** 2
    return jnp.mean(loss)


def chunked_unembed_loss(x: jnp.ndarray, table: jnp.ndarray,
                         labels: jnp.ndarray, n_chunks: int,
                         z_loss: float = 1e-4) -> jnp.ndarray:
    """CE without materializing the full [B,S,V] logits: the unembed matmul
    + softmax run per sequence chunk (statically unrolled so cost_analysis
    sees every chunk). Cuts the dominant train-step temp (f32 logits) by
    ``n_chunks`` — §Perf iteration 'chunked-vocab loss'."""
    b, s, d = x.shape
    assert s % n_chunks == 0, (s, n_chunks)
    cs = s // n_chunks
    total = jnp.zeros((), jnp.float32)
    wt = table.T.astype(x.dtype)
    for i in range(n_chunks):
        xc = jax.lax.slice_in_dim(x, i * cs, (i + 1) * cs, axis=1)
        lc = jax.lax.slice_in_dim(labels, i * cs, (i + 1) * cs, axis=1)
        logits = constrain(xc @ wt, ("batch", "seq", "vocab"))
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        piece = lse - gold
        if z_loss:
            piece = piece + z_loss * lse ** 2
        total = total + jnp.sum(piece)
    return total / (b * s)


# ---------------------------------------------------------------------------
# StreamGraph workload: attention -> out-projection
# ---------------------------------------------------------------------------
#
# The transformer block's hottest fusion opportunity above single kernels:
# flash attention writes [BH, S, D] q-blocks in q-major order, and the out-
# projection matmul streams exactly those (block_q, d) tiles as its A
# operand — so the attention output can live in a VMEM ring inside one
# fused pallas_call instead of round-tripping HBM between two kernels
# (repro.core.graph decides per edge; a mismatched block_q stages instead).


def build_attention_proj_graph(*, bh: int = 2, s: int = 256, d: int = 64,
                               d_out: int = 256, causal: bool = True,
                               dtype=jnp.float32, depth: int = 2,
                               streams: int = 1, block_q: int = 128):
    """Declare the attention→out-projection StreamGraph at one shape point.

    The projection's M tile is pinned to ``block_q`` so the edge is fusable
    when the attention output schedule lines up; ``block_q`` is the joint
    tuner's shared-tile axis.
    """
    from repro.core.graph import GraphEdge, GraphNode, StreamGraph
    from repro.kernels.ff_attention.kernel import build_program as attn_prog
    from repro.kernels.ff_attention.ops import attention_workload
    from repro.kernels.ff_matmul.kernel import build_program as matmul_prog
    from repro.kernels.ff_matmul.ops import matmul_workload

    block = (block_q, min(128, d_out), d)
    attn = attn_prog(bh, s, s, d, block_q=block_q, block_kv=128,
                     causal=causal, dtype=dtype, depth=depth, streams=streams)
    proj = matmul_prog(bh * s, d_out, d, block=block, dtype=dtype,
                       depth=depth, streams=streams)
    w_a, t_a = attention_workload(bh, s, d, causal=causal, block_q=block_q,
                                  dtype=dtype)
    w_p, t_p = matmul_workload(bh * s, d_out, d, block, dtype)
    return StreamGraph(
        name="attention_proj",
        nodes=(
            GraphNode("attn", attn, workload=w_a, plan_tile=t_a),
            GraphNode("proj", proj, workload=w_p, plan_tile=t_p),
        ),
        edges=(
            GraphEdge("attn", "proj", "a", reshape=(bh * s, d)),
        ),
    )


def _attention_proj_inputs(key):
    """Operands in CompiledGraph.arg_names order:
    (attn.q, attn.k, attn.v, proj.b)."""
    # d_out = 2 N tiles: the projection re-reads each attention block
    # once per N tile, so the fused ring saves the re-streams too
    bh, s, d, d_out = 2, 256, 64, 256
    q = 0.3 * jax.random.normal(key, (bh, s, d), jnp.float32)
    k = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (bh, s, d),
                                jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, d),
                          jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 3), (d, d_out),
                          jnp.float32) / jnp.sqrt(d)
    return (q, k, v, w)


def _attention_proj_ref(q, k, v, w):
    bh, s, d = q.shape
    scores = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None], scores, -1e30)
    attn = jnp.einsum("bst,btd->bsd", jax.nn.softmax(scores, axis=-1),
                      v.astype(jnp.float32))
    return (attn.reshape(bh * s, d) @ w.astype(jnp.float32)).astype(q.dtype)


def _attention_proj_unfused(q, k, v, w):
    """Attention then projection as two separate repro.ops calls — the
    [BH, S, D] intermediate round-trips HBM (the BENCH_graph baseline).
    The projection is pinned to the graph's tile so the comparison
    isolates the lowering, not the tiling."""
    import repro

    bh, s, d = q.shape
    attn = repro.ops.attention(q, k, v, causal=True)
    return repro.ops.matmul(attn.reshape(bh * s, d), w,
                            block=(128, 128, d))


def attention_proj(q, k, v, w, *, causal: bool = True,
                   policy=None) -> jnp.ndarray:
    """Causal attention → out-projection through the fused StreamGraph, at
    the caller's shapes.

    q/k/v: [BH, S, D]; w: [D, D_out]. Returns [BH*S, D_out].

    Unlike ``run_graph`` (fixed smoke shapes), this entrypoint resolves the
    joint graph plan at the call site's shapes and records the site for the
    plan-service sweep — mirroring ``paged_decode_attention``.
    """
    from repro.core import autotune
    from repro.core import graph as graphlib
    from repro.core.program import current_policy

    policy = current_policy() if policy is None else policy
    if policy.mode == "ref":
        return _attention_proj_ref(q, k, v, w)
    bh, s, d = q.shape
    d_out = w.shape[1]

    def build(depth=2, streams=1, **tk):
        return build_attention_proj_graph(
            bh=bh, s=s, d=d, d_out=d_out, causal=causal, dtype=q.dtype,
            depth=depth, streams=streams, **tk)

    g0 = build()
    wl, tile = graphlib.graph_workload(g0)
    sig = graphlib.graph_signature(g0)

    def runner(tk, depth, streams):
        cg = graphlib.compile_graph(
            build(depth=depth, streams=streams, **dict(tk)),
            policy=policy.replace(mode="ff", depth=depth, streams=streams))
        return lambda: cg(q, k, v, w)

    choice = autotune.resolve_graph(
        "attention_proj", policy, workload=wl, tile=tile,
        dtype=q.dtype, signature=sig,
        workload_fn=lambda tk: graphlib.graph_workload(build(**dict(tk))),
        runner=None if autotune.has_tracers(q, k, v, w) else runner,
        site={"bh": bh, "s": s, "d": d, "d_out": d_out,
              "causal": bool(causal)},
        site_dynamic=("bh", "s"),
        tile_options=({"block_q": 64},))
    # compiled fresh per call (trace-scoped closures must not be reused)
    mode = "ff" if policy.mode == "autotune" else policy.mode
    cg = graphlib.compile_graph(
        build(depth=choice.depth, streams=choice.streams,
              **dict(choice.tile_kwargs)),
        policy=policy.replace(mode=mode, depth=choice.depth,
                              streams=choice.streams))
    return cg(q, k, v, w)


def _attention_proj_sweep_inputs(key, site):
    """Rebuild attention_proj operands at a recorded call-site shape
    (plan sweep)."""
    bh, s = int(site["bh"]), int(site["s"])
    d, d_out = int(site["d"]), int(site["d_out"])
    dt = jnp.dtype(site.get("dtype", "float32"))
    q = 0.3 * jax.random.normal(key, (bh, s, d), dt)
    k = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (bh, s, d), dt)
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, d), dt)
    w = jax.random.normal(jax.random.fold_in(key, 3), (d, d_out),
                          dt) / jnp.sqrt(d)
    kwargs = {"causal": bool(site.get("causal", True))}
    return (q, k, v, w), kwargs


def _register_attention_proj_graph():
    from repro.kernels.registry import register_graph

    register_graph(
        name="attention_proj",
        build=build_attention_proj_graph,
        make_inputs=_attention_proj_inputs,
        ref=_attention_proj_ref,
        unfused=_attention_proj_unfused,
        tile_options=({"block_q": 64},),
        tol=5e-4,
        doc="flash attention -> out-projection matmul; the [BH,S,D] "
            "intermediate stays in a VMEM ring when block_q tiles match",
        # plan-service sweep: resolve at call-site shapes through the real
        # entrypoint, not run_graph's fixed smoke point
        op=attention_proj,
        sweep_inputs=_attention_proj_sweep_inputs,
    )


_register_attention_proj_graph()


# ---------------------------------------------------------------------------
# StreamGraph workload: the whole transformer decode layer
# ---------------------------------------------------------------------------
#
# ROADMAP item 2: QKV projection -> decode attention -> out-projection ->
# gate/up MLP -> down-projection as ONE StreamGraph. RMSNorms ride as fused
# prologues inside the matmul consumers, the residual adds and RoPE as
# consumer *epilogues* (GraphNode.epilogue), and the out-projection output
# is a multi-consumer edge: it feeds the MLP gate/up node AND the final
# residual epilogue. compile_graph fuses oproj->gateup->down into one
# chain kernel (the residual rides the chain's intermediate VMEM ring, so
# the post-attention hidden state never round-trips HBM) and stages the two
# attention-adjacent edges with per-edge rationales (the q handoff is a
# block-delivered operand; the attention output's (g_pad, hd) blocks don't
# match the out-projection's (block_m, hpad) row tiles).


def build_decode_layer_graph(*, b: int = 16, d_model: int = 64,
                             kvh: int = 1, g_pad: int = 8, hd: int = 16,
                             d_ff: int = 128, s: int = 128,
                             eps: float = 1e-6, dtype=jnp.float32,
                             depth: int = 2, streams: int = 1,
                             block_m: int = 8, block_kv: int = 128):
    """Declare the whole-decode-layer StreamGraph at one shape point.

    Row-space: ``b`` decode tokens (one per sequence), padded to a multiple
    of ``block_m``. Head-space: ``kvh`` KV heads of ``g_pad`` (8-padded)
    query heads each, ``hpad = kvh * g_pad * hd`` flattened q columns —
    the entrypoint zero-pads the flattened projections so padded head rows
    contribute exactly zero. ``block_kv`` is the joint tuner's shared tile
    axis (``block_m`` is pinned: epilogue operands are blocked on it).
    """
    from repro.core.graph import Epilogue, GraphEdge, GraphNode, StreamGraph
    from repro.core.program import BlockIn
    from repro.kernels.ff_decode_attention.kernel import \
        build_program as attn_prog
    from repro.kernels.ff_decode_attention.ops import \
        decode_attention_workload
    from repro.kernels.ff_layer.kernel import build_matmul_program, \
        build_swiglu_program
    from repro.kernels.ff_matmul.ops import matmul_workload

    hpad = kvh * g_pad * hd
    half = hd // 2

    qprog = build_matmul_program(b, hpad, d_model, block_m=block_m,
                                 norm=True, eps=eps, dtype=dtype,
                                 depth=depth, streams=streams,
                                 name="ff_layer_qproj")
    attn = attn_prog(b, kvh, g_pad, s, hd, block_kv=block_kv, dtype=dtype,
                     depth=depth, streams=streams)
    oprog = build_matmul_program(b, d_model, hpad, block_m=block_m,
                                 dtype=dtype, depth=depth, streams=streams,
                                 name="ff_layer_oproj")
    gprog = build_swiglu_program(b, d_ff, d_model, block_m=block_m,
                                 norm=True, eps=eps, dtype=dtype,
                                 depth=depth, streams=streams)
    dprog = build_matmul_program(b, d_model, d_ff, block_m=block_m,
                                 dtype=dtype, depth=depth, streams=streams,
                                 name="ff_layer_down")

    def _rope_bias_ep(ctx, idx, value):
        # q = (rmsnorm(x) @ wq + bq) rotated by the per-row cos/sin tables
        # (rope over the trailing hd dim of each padded head), all in f32 —
        # mirrors L.rope numerics exactly; rope(0) = 0 keeps padded head
        # columns zero
        v = value.astype(jnp.float32) + ctx.ref("bq")[...].astype(jnp.float32)
        c = ctx.ref("cos")[...][:, None, :].astype(jnp.float32)
        s_ = ctx.ref("sin")[...][:, None, :].astype(jnp.float32)
        vh = v.reshape(v.shape[0], kvh * g_pad, hd)
        x1, x2 = vh[..., :half], vh[..., half:]
        vh = jnp.concatenate([x1 * c - x2 * s_, x1 * s_ + x2 * c], axis=-1)
        return vh.reshape(v.shape).astype(value.dtype)

    def _residual_ep(name):
        def ep(ctx, idx, value):
            return value + ctx.ref(name)[...].astype(value.dtype)
        return ep

    w_q, t_q = matmul_workload(b, hpad, d_model, (block_m, hpad, d_model),
                               dtype)
    w_a, t_a = decode_attention_workload(b, kvh * g_pad, kvh, s, hd,
                                         block_kv=block_kv, dtype=dtype)
    w_o, t_o = matmul_workload(b, d_model, hpad, (block_m, d_model, hpad),
                               dtype)
    w_d, t_d = matmul_workload(b, d_model, d_ff, (block_m, d_model, d_ff),
                               dtype)
    return StreamGraph(
        name="decode_layer",
        nodes=(
            GraphNode("qproj", qprog, workload=w_q, plan_tile=t_q,
                      epilogue=Epilogue(_rope_bias_ep, inputs=(
                          BlockIn("bq", (block_m, hpad), lambda g: (0, 0)),
                          BlockIn("cos", (block_m, half), lambda g: (g, 0)),
                          BlockIn("sin", (block_m, half), lambda g: (g, 0)),
                      ))),
            GraphNode("attn", attn, workload=w_a, plan_tile=t_a),
            GraphNode("oproj", oprog, workload=w_o, plan_tile=t_o,
                      epilogue=Epilogue(_residual_ep("res1"), inputs=(
                          BlockIn("res1", (block_m, d_model),
                                  lambda g: (g, 0), dtype=dtype),))),
            # gateup's workload is synthesized from its streams (exact:
            # one x row-block + both weight blocks per word)
            GraphNode("gateup", gprog),
            GraphNode("down", dprog, workload=w_d, plan_tile=t_d,
                      epilogue=Epilogue(_residual_ep("res"), inputs=(
                          BlockIn("res", (block_m, d_model),
                                  lambda g: (g, 0), dtype=dtype),))),
        ),
        edges=(
            # staged: attn's q is a block-delivered BlockIn operand
            GraphEdge("qproj", "attn", "q", reshape=(b, kvh, g_pad, hd)),
            # staged: (1,1,g_pad,hd) attention blocks vs (block_m, hpad)
            # row tiles — mismatched schedules
            GraphEdge("attn", "oproj", "a", reshape=(b, hpad)),
            # fused chain: oproj -> gateup -> down, one pallas_call
            GraphEdge("oproj", "gateup", "x"),
            # multi-consumer: the post-attention hidden state also feeds
            # the final residual epilogue — ring-served from the chain's
            # intermediate VMEM ring, no HBM materialization
            GraphEdge("oproj", "down", "res"),
            GraphEdge("gateup", "down", "a"),
        ),
    )


def _decode_layer_inputs(key):
    """Operands in CompiledGraph.arg_names order: (qproj.a, qproj.b,
    qproj.nw, qproj.bq, qproj.cos, qproj.sin, attn.lengths, attn.k,
    attn.v, oproj.b, oproj.res1, gateup.wg, gateup.wu, gateup.nw,
    down.b). Norm weights and the q bias arrive broadcast to ``block_m``
    rows (ring-promotable blocks need 8-aligned sublanes)."""
    b, d, kvh, g_pad, hd, f, s = 16, 64, 1, 8, 16, 128, 128
    hpad, half, bm = kvh * g_pad * hd, hd // 2, 8
    ks = [jax.random.fold_in(key, i) for i in range(12)]
    x = 0.3 * jax.random.normal(key, (b, d), jnp.float32)
    wq = jax.random.normal(ks[1], (d, hpad), jnp.float32) / math.sqrt(d)
    nw1 = jnp.broadcast_to(
        1.0 + 0.1 * jax.random.normal(ks[2], (d,), jnp.float32), (bm, d))
    bq = jnp.broadcast_to(
        0.1 * jax.random.normal(ks[3], (hpad,), jnp.float32), (bm, hpad))
    lengths = jax.random.randint(ks[4], (b,), 1, s + 1, dtype=jnp.int32)
    ang = (lengths - 1).astype(jnp.float32)[:, None] \
        * (1e4 ** (-jnp.arange(half, dtype=jnp.float32) / half))
    k = 0.3 * jax.random.normal(ks[5], (b, kvh, s, hd), jnp.float32)
    v = jax.random.normal(ks[6], (b, kvh, s, hd), jnp.float32)
    wo = jax.random.normal(ks[7], (hpad, d), jnp.float32) / math.sqrt(hpad)
    wg = jax.random.normal(ks[8], (d, f), jnp.float32) / math.sqrt(d)
    wu = jax.random.normal(ks[9], (d, f), jnp.float32) / math.sqrt(d)
    nw2 = jnp.broadcast_to(
        1.0 + 0.1 * jax.random.normal(ks[10], (d,), jnp.float32), (bm, d))
    wo2 = jax.random.normal(ks[11], (f, d), jnp.float32) / math.sqrt(f)
    return (x, wq, nw1, bq, jnp.cos(ang), jnp.sin(ang), lengths, k, v,
            wo, x, wg, wu, nw2, wo2)


def _decode_layer_ref(x, wq, nw1, bq, cos, sin, lengths, k, v, wo, res1,
                      wg, wu, nw2, wo2, eps: float = 1e-6):
    """Pure-XLA decode layer at the graph's operand layout (flattened
    zero-padded projections, broadcast norm rows, precomputed rope
    tables). Mirrors the kernel convention that a fully-masked row
    (length 0) attends to nothing and outputs zeros."""
    b, d = x.shape
    _, kvh, s, hd = k.shape
    hpad, half = wq.shape[1], hd // 2
    dt = x.dtype
    xn = rmsnorm(x, nw1[0], eps)
    q = jnp.dot(xn, wq, preferred_element_type=jnp.float32).astype(dt)
    q = q.astype(jnp.float32) + bq[0].astype(jnp.float32)
    qh = q.reshape(b, hpad // hd, hd)
    c = cos[:, None, :].astype(jnp.float32)
    s_ = sin[:, None, :].astype(jnp.float32)
    x1, x2 = qh[..., :half], qh[..., half:]
    qh = jnp.concatenate([x1 * c - x2 * s_, x1 * s_ + x2 * c], axis=-1)
    q4 = qh.reshape(b, kvh, hpad // (kvh * hd), hd).astype(dt)
    scores = jnp.einsum("bkgd,bksd->bkgs", q4.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    valid = jnp.arange(s)[None, None, None, :] \
        < lengths[:, None, None, None]
    scores = jnp.where(valid, scores, -1e30)
    attn = jnp.einsum("bkgs,bksd->bkgd", jax.nn.softmax(scores, axis=-1),
                      v.astype(jnp.float32))
    attn = jnp.where(lengths[:, None, None, None] > 0, attn, 0.0)
    a = attn.astype(dt).reshape(b, hpad)
    h = jnp.dot(a, wo, preferred_element_type=jnp.float32).astype(dt) + res1
    hn = rmsnorm(h, nw2[0], eps)
    g32 = jnp.dot(hn, wg, preferred_element_type=jnp.float32)
    u32 = jnp.dot(hn, wu, preferred_element_type=jnp.float32)
    m = (jax.nn.silu(g32) * u32).astype(dt)
    return jnp.dot(m, wo2, preferred_element_type=jnp.float32).astype(dt) + h


@functools.lru_cache(maxsize=8)
def _unfused_decode_layer_fn(b, d, kvh, g_pad, hd, d_ff, s, dtype):
    """The chained-ops baseline: the same five planned kernels as the
    graph (identical per-node depth/streams sizing, via a one-time staged
    compile), but each node is its own jitted dispatch — intermediates
    cross the dispatch boundary instead of staying device-resident inside
    one program. Compiled once per shape so the bench measures execution,
    not per-call re-tracing."""
    from repro.core.graph import compile_graph

    g = build_decode_layer_graph(b=b, d_model=d, kvh=kvh, g_pad=g_pad,
                                 hd=hd, d_ff=d_ff, s=s, dtype=dtype)
    cg = compile_graph(g, prefer="staged")
    run = {u.out_node: jax.jit(u.fn) for u in cg.units}
    hpad = kvh * g_pad * hd

    def fn(x, wq, nw1, bq, cos, sin, lengths, k, v, wo, res1, wg, wu,
           nw2, wo2):
        q = run["qproj"](x, wq, nw1, bq, cos, sin)
        a = run["attn"](lengths, q.reshape(b, kvh, -1, hd), k, v)
        h = run["oproj"](a.reshape(b, hpad), wo, res1)
        m = run["gateup"](h, wg, wu, nw2)
        return run["down"](m, wo2, h)

    return fn


def _decode_layer_unfused(x, wq, nw1, bq, cos, sin, lengths, k, v, wo,
                          res1, wg, wu, nw2, wo2):
    """The same five node programs as five separate pallas_calls — every
    intermediate round-trips HBM (the BENCH_graph whole-layer baseline).
    Same lowering and sizing, no graph: the comparison isolates the
    fusion."""
    b, d = x.shape
    _, kvh, s, hd = k.shape
    hpad = wq.shape[1]
    fn = _unfused_decode_layer_fn(b, d, kvh, hpad // (kvh * hd), hd,
                                  wg.shape[1], s, jnp.dtype(x.dtype))
    return fn(x, wq, nw1, bq, cos, sin, lengths, k, v, wo, res1, wg, wu,
              nw2, wo2)


def decode_layer(x, nw1, wq, bq, positions, k_cache, v_cache, lengths,
                 wo, nw2, wg, wu, wo2, *, rope_theta: float = 10000.0,
                 eps: float = 1e-6, block_kv: Optional[int] = None,
                 policy=None) -> jnp.ndarray:
    """One transformer decode step (post cache-update) through the
    whole-layer ``decode_layer`` StreamGraph, at the caller's shapes.

    x: [B, D] current-token hidden states; nw1/nw2: [D] RMSNorm weights;
    wq: [D, H*hd] (bq: [H*hd] or None); positions: [B] rope positions of
    the current token; k_cache/v_cache: [B, KVH, S, hd] post-update;
    lengths: [B] live prefix length *including* the current token;
    wo: [H*hd, D]; wg/wu: [D, F]; wo2: [F, D]. Returns [B, D] =
    ``x + attn(...) @ wo + mlp(...)`` — the full pre-norm layer body.

    Marshals to the graph's padded operand layout (rows to ``block_m``,
    query-head group to ``g_pad``, cache length to ``block_kv``; the
    zero-padded flattened projections make every padded lane contribute
    exactly zero), resolves the joint plan, and records the call site for
    the plan-service sweep — mirroring ``attention_proj``.
    """
    from repro.core import autotune
    from repro.core import graph as graphlib
    from repro.core.program import current_policy

    policy = current_policy() if policy is None else policy
    dt = x.dtype
    b, d_model = x.shape
    _, kvh, s_len, hd = k_cache.shape
    half = hd // 2
    n_q = wq.shape[1] // hd
    group = max(n_q // kvh, 1)
    g_pad = max(8, -(-group // 8) * 8)
    hpad = kvh * g_pad * hd
    d_ff = wg.shape[1]
    block_m = 8
    bkv = int(block_kv or 128)
    bp = -(-b // block_m) * block_m
    spad = -(-s_len // bkv) * bkv

    def pad_rows(a):
        if a.shape[0] == bp:
            return a
        return jnp.pad(a, ((0, bp - b),) + ((0, 0),) * (a.ndim - 1))

    def pad_seq(c):
        c = c.astype(dt)
        if c.shape[2] != spad:
            c = jnp.pad(c, ((0, 0), (0, 0), (0, spad - s_len), (0, 0)))
        return pad_rows(c)

    # zero-pad the flattened projections over the padded head group:
    # padded q columns are 0 (rope keeps them 0), padded attention rows
    # are killed by zero wo rows
    wq4 = wq.reshape(d_model, kvh, group, hd)
    wqf = jnp.zeros((d_model, kvh, g_pad, hd), wq.dtype) \
        .at[:, :, :group].set(wq4).reshape(d_model, hpad)
    bqv = jnp.zeros((n_q * hd,), dt) if bq is None else bq
    bqf = jnp.zeros((kvh, g_pad, hd), bqv.dtype) \
        .at[:, :group].set(bqv.reshape(kvh, group, hd)).reshape(hpad)
    wo4 = wo.reshape(kvh, group, hd, d_model)
    wof = jnp.zeros((kvh, g_pad, hd, d_model), wo.dtype) \
        .at[:, :group].set(wo4).reshape(hpad, d_model)
    freqs = rope_theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs
    xp = pad_rows(x)
    ops = (xp, wqf.astype(dt),
           jnp.broadcast_to(nw1.astype(jnp.float32)[None],
                            (block_m, d_model)),
           jnp.broadcast_to(bqf.astype(jnp.float32)[None],
                            (block_m, hpad)),
           pad_rows(jnp.cos(ang)), pad_rows(jnp.sin(ang)),
           pad_rows(lengths.astype(jnp.int32)),
           pad_seq(k_cache), pad_seq(v_cache), wof.astype(dt), xp,
           wg.astype(dt), wu.astype(dt),
           jnp.broadcast_to(nw2.astype(jnp.float32)[None],
                            (block_m, d_model)),
           wo2.astype(dt))
    if policy.mode == "ref":
        return _decode_layer_ref(*ops, eps=eps)[:b]

    def build(depth=2, streams=1, **tk):
        return build_decode_layer_graph(
            b=bp, d_model=d_model, kvh=kvh, g_pad=g_pad, hd=hd, d_ff=d_ff,
            s=spad, eps=eps, dtype=dt, depth=depth, streams=streams,
            block_kv=tk.pop("block_kv", bkv), **tk)

    g0 = build()
    wl, tile = graphlib.graph_workload(g0)
    sig = graphlib.graph_signature(g0)

    def runner(tk, depth, streams):
        cg = graphlib.compile_graph(
            build(depth=depth, streams=streams, **dict(tk)),
            policy=policy.replace(mode="ff", depth=depth, streams=streams))
        return lambda: cg(*ops)

    choice = autotune.resolve_graph(
        "decode_layer", policy, workload=wl, tile=tile, dtype=dt,
        signature=sig,
        workload_fn=lambda tk: graphlib.graph_workload(build(**dict(tk))),
        runner=None if autotune.has_tracers(*ops) else runner,
        site={"b": b, "d_model": d_model, "h": n_q, "kvh": kvh, "hd": hd,
              "d_ff": d_ff, "s": s_len},
        site_dynamic=("b", "s"),
        tile_options=({"block_kv": 64},))
    # compiled fresh per call (trace-scoped closures must not be reused)
    mode = "ff" if policy.mode == "autotune" else policy.mode
    cg = graphlib.compile_graph(
        build(depth=choice.depth, streams=choice.streams,
              **dict(choice.tile_kwargs)),
        policy=policy.replace(mode=mode, depth=choice.depth,
                              streams=choice.streams))
    return cg(*ops)[:b]


def _decode_layer_sweep_inputs(key, site):
    """Rebuild decode_layer operands at a recorded call-site shape
    (plan sweep)."""
    b, d = int(site["b"]), int(site["d_model"])
    h, kvh, hd = int(site["h"]), int(site["kvh"]), int(site["hd"])
    f, s = int(site["d_ff"]), int(site["s"])
    dt = jnp.dtype(site.get("dtype", "float32"))
    ks = [jax.random.fold_in(key, i) for i in range(12)]
    x = 0.3 * jax.random.normal(key, (b, d), dt)
    nw1 = 1.0 + 0.1 * jax.random.normal(ks[1], (d,), dt)
    wq = jax.random.normal(ks[2], (d, h * hd), dt) / math.sqrt(d)
    bq = 0.1 * jax.random.normal(ks[3], (h * hd,), dt)
    lengths = jax.random.randint(ks[4], (b,), 1, s + 1, dtype=jnp.int32)
    positions = lengths - 1
    k = 0.3 * jax.random.normal(ks[5], (b, kvh, s, hd), dt)
    v = jax.random.normal(ks[6], (b, kvh, s, hd), dt)
    wo = jax.random.normal(ks[7], (h * hd, d), dt) / math.sqrt(h * hd)
    nw2 = 1.0 + 0.1 * jax.random.normal(ks[8], (d,), dt)
    wg = jax.random.normal(ks[9], (d, f), dt) / math.sqrt(d)
    wu = jax.random.normal(ks[10], (d, f), dt) / math.sqrt(d)
    wo2 = jax.random.normal(ks[11], (f, d), dt) / math.sqrt(f)
    return (x, nw1, wq, bq, positions, k, v, lengths, wo, nw2, wg, wu,
            wo2), {}


def _register_decode_layer_graph():
    from repro.kernels.registry import register_graph

    register_graph(
        name="decode_layer",
        build=build_decode_layer_graph,
        make_inputs=_decode_layer_inputs,
        ref=_decode_layer_ref,
        unfused=_decode_layer_unfused,
        tile_options=({"block_kv": 64},),
        tol=5e-4,
        doc="whole transformer decode layer: q-projection (+RMSNorm "
            "prologue, +bias/RoPE epilogue) -> decode attention -> "
            "out-projection (+residual) -> SwiGLU gate/up -> "
            "down-projection (+residual); oproj->gateup->down fuse into "
            "one chain kernel with the residual ring-served in VMEM",
        # plan-service sweep: resolve at call-site shapes through the real
        # entrypoint, not run_graph's fixed smoke point
        op=decode_layer,
        sweep_inputs=_decode_layer_sweep_inputs,
    )


_register_decode_layer_graph()
