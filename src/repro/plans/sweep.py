"""Offline sweep: tune a PlanDB from a recorded TrafficProfile.

Replaces fixed-benchmark-shape tuning with traffic-driven tuning: buckets
are ranked by **observed frequency x modeled cost** (count times the
roofline seconds of the bucket's heaviest workload — the buckets that
dominate real wall time tune first) and measured until the time budget
runs out. For each bucket the sweep

1. rebuilds the *serving* policy (``mode="autotune"`` with the recorded
   stream_options/interpret/pins, the recorded hardware model, and the
   recorded mesh topology — so the computed keys match what serving
   lookups will ask for);
2. synthesizes concrete operands at the bucketed shape via the kernel's
   ``KernelSpec.sweep_inputs`` builder and runs the op once under a
   scratch plan cache, which drives the real measured autotuner;
3. writes the tuned record into the PlanDB under **every exact plan key**
   observed in the bucket — serving lookups stay exact-match, bucketing
   only decides where the measurement happens.

Graph call sites (``graph:*``) and planner-origin records carry no shape
dict and are skipped with a logged reason — the sweep never silently
drops coverage.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.meshspec import MeshSpec
from repro.core.pipeline_model import ARRIA_CX, TPU_V5E, HardwareModel, \
    Workload
from repro.plans.plandb import PlanDB
from repro.plans.profile import ProfileEntry, TrafficProfile
from repro.plans.registry import plan_namespace

# recorded hw name -> analytic model (plan keys embed hw.name, so the
# sweep must rebuild the exact model the traffic planned against)
HW_BY_NAME: Dict[str, HardwareModel] = {
    TPU_V5E.name: TPU_V5E,
    ARRIA_CX.name: ARRIA_CX,
}


def modeled_cost_s(entry: ProfileEntry) -> float:
    """Roofline seconds of the bucket's heaviest observed workload — the
    cost half of the frequency x cost priority. A deliberately simple
    max(bytes/bw, flops/peak) bound: ranking needs ordering, not
    accuracy."""
    hw = HW_BY_NAME.get(entry.hw)
    worst = 0.0
    for var in entry.variants.values():
        w = var["workload"]
        loaded = float(w["n_words"]) * float(w["word_bytes"])
        flops = float(w["n_words"]) * float(w["flops_per_word"])
        if hw is None:
            worst = max(worst, loaded)     # bytes as a unitless proxy
        else:
            worst = max(worst, loaded / hw.hbm_bw, flops / hw.flops)
    return worst


def entry_priority(entry: ProfileEntry) -> float:
    return entry.count * modeled_cost_s(entry)


def _rebuild_policy(entry: ProfileEntry):
    """The serving-equivalent search policy for one bucket. mode is forced
    to "autotune" (profiles recorded under mode="ff" are swept for the
    measured path); everything that shapes the plan key — pins,
    stream_options, interpret, hw, mesh — comes from the recording."""
    from repro.core.program import PipePolicy

    hw = HW_BY_NAME.get(entry.hw)
    if hw is None:
        raise KeyError(f"unknown hardware model {entry.hw!r} "
                       f"(register it in repro.plans.sweep.HW_BY_NAME)")
    pol = entry.policy
    mesh = MeshSpec(axes=tuple(entry.mesh_axes)) if entry.mesh_axes else None
    return PipePolicy(
        mode="autotune",
        depth=pol["depth"] if isinstance(pol["depth"], int) else "auto",
        streams=pol["streams"] if isinstance(pol["streams"], int) else "auto",
        stream_options=tuple(int(s) for s in pol["stream_options"]),
        interpret=bool(pol["interpret"]), hw=hw, mesh=mesh)


@dataclasses.dataclass
class SweepResult:
    db: PlanDB
    namespace: str
    tuned_buckets: int = 0
    keys_written: int = 0
    skipped: List[str] = dataclasses.field(default_factory=list)
    wall_s: float = 0.0

    def to_payload(self) -> dict:
        return {"namespace": self.namespace,
                "tuned_buckets": self.tuned_buckets,
                "keys_written": self.keys_written,
                "skipped": self.skipped, "wall_s": self.wall_s,
                "db": self.db.stats()}


def sweep_profile(profile: TrafficProfile, *,
                  db: Optional[PlanDB] = None,
                  namespace: Optional[str] = None,
                  budget_s: Optional[float] = None,
                  scratch_cache: Optional[str] = None,
                  warmup: int = 1, iters: int = 2,
                  top_k: Optional[int] = None,
                  seed: int = 0,
                  log=print) -> SweepResult:
    """Tune every sweepable bucket of ``profile`` (priority order) into
    ``db`` under ``budget_s`` total wall seconds.

    ``scratch_cache`` is the throwaway per-host plan-cache path the
    measured autotuner persists through during the sweep (default: a
    path derived from the namespace under /tmp is *not* chosen for you —
    pass one; tests and the CLI use a tempdir). ``top_k`` caps the
    measured candidates per bucket (None keeps the tuner default; 2 =
    analytic reference + best predicted, the cheap smoke setting).
    Returns a :class:`SweepResult`; ``result.db`` holds the merged
    records.
    """
    from repro.kernels import registry as kernel_registry

    ns = namespace or plan_namespace()
    result = SweepResult(db=db if db is not None else PlanDB(),
                         namespace=ns)
    t0 = time.monotonic()

    order = sorted(
        profile.entries.items(),
        key=lambda kv: (-entry_priority(kv[1]), kv[0]))

    for i, (bkey, entry) in enumerate(order):
        spent = time.monotonic() - t0
        if budget_s is not None and spent >= budget_s:
            result.skipped.append(
                f"{entry.op}: sweep budget {budget_s}s exhausted "
                f"({len(order) - result.tuned_buckets - len(result.skipped)}"
                f" buckets left)")
            break
        # fair-share the remaining budget across the remaining buckets so
        # a deep search on one bucket can't starve the tail out of their
        # (always-measured) analytic-reference candidate
        budget_left = None if budget_s is None else \
            (budget_s - spent) / (len(order) - i)
        reason = _sweep_bucket(
            entry, result, kernel_registry,
            budget_left=budget_left,
            scratch_cache=scratch_cache, warmup=warmup, iters=iters,
            top_k=top_k, seed=seed)
        if reason is None:
            result.tuned_buckets += 1
            log(f"# sweep: tuned {entry.op} bucket "
                f"(count={entry.count}, variants={len(entry.variants)})")
        else:
            result.skipped.append(f"{entry.op}: {reason}")
    result.wall_s = time.monotonic() - t0
    return result


def _sweep_bucket(entry: ProfileEntry, result: SweepResult, kernel_registry,
                  *, budget_left: Optional[float], scratch_cache,
                  warmup: int, iters: int, top_k: Optional[int],
                  seed: int) -> Optional[str]:
    """Tune one bucket; returns None on success or a skip reason."""
    if entry.op.startswith("graph:"):
        try:
            gspec = kernel_registry.get_graph(entry.op[len("graph:"):])
        except KeyError:
            return "not a registered graph"
        if gspec.op is None or gspec.sweep_inputs is None:
            return "graph declares no sweep entrypoint/inputs builder"
        op_fn, sweep_inputs = gspec.op, gspec.sweep_inputs
    else:
        try:
            spec = kernel_registry.get_kernel(entry.op)
        except KeyError:
            return "not a registry kernel (legacy planner call site)"
        if spec.sweep_inputs is None:
            return "kernel declares no sweep_inputs builder"
        op_fn, sweep_inputs = spec.op, spec.sweep_inputs
    if entry.site is None:
        return "no recorded shape dict (planner-origin record)"

    try:
        policy = _rebuild_policy(entry)
    except KeyError as e:
        return str(e)

    # builders see the recorded operand dtype alongside the shape dict
    site = dict(entry.site, dtype=entry.dtype)
    try:
        args, kw = sweep_inputs(jax.random.key(seed), site)
    except Exception as e:   # noqa: BLE001 — report, don't abort the sweep
        return f"sweep_inputs failed at {entry.site}: " \
               f"{type(e).__name__}: {e}"

    cfg: Dict[str, Any] = {"warmup": warmup, "iters": iters,
                           "budget_s": budget_left}
    if top_k is not None:
        cfg["top_k"] = top_k
    if scratch_cache:
        cfg["cache_path"] = scratch_cache
    try:
        with autotune.tuning_config(**cfg):
            jax.block_until_ready(op_fn(*args, **kw, policy=policy))
    except Exception as e:   # noqa: BLE001
        return f"measurement failed: {type(e).__name__}: {e}"

    record = autotune.last_record(entry.op)
    if record is None:
        return "tuner produced no record (analytic fallback at the bucket)"

    # one DB record per *exact* observed key: serving lookups are
    # exact-match, the bucket only chose the measurement point
    mesh = MeshSpec(axes=tuple(entry.mesh_axes))
    constraints = autotune._policy_constraints(policy, entry.extra_key)
    tuned_at = time.time()
    for var in entry.variants.values():
        w = Workload(**var["workload"])
        key = autotune.plan_key(entry.op, w, entry.dtype, policy.hw,
                                constraints, mesh=mesh)
        result.db.put(result.namespace, key, record, tuned_at=tuned_at)
        result.keys_written += 1
    return None
