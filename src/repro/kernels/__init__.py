"""repro.kernels — Pallas TPU kernels implementing the feed-forward (DAE)
design model, one subpackage per hot spot:

  ff_matmul            DAE blocked matmul (regular streams)
  ff_attention         flash attention prefill, GQA, KV ring pipes
  ff_decode_attention  flash-decode vs. long KV caches
  ff_chunk_scan        gated linear-attention scan (Mamba2 / RWKV6)
  ff_gather            irregular row gather (embedding / MoE dispatch)

Each subpackage: kernel.py (pl.pallas_call + BlockSpec + explicit ring-pipe
DMAs), ops.py (jit wrapper + exact tile-schedule cost model), ref.py
(pure-jnp oracle). Kernels validate under interpret=True on CPU; real-TPU
lowering is the target.
"""

from repro.kernels.dae import cdiv, pad_to

__all__ = ["cdiv", "pad_to"]
