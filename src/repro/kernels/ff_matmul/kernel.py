"""Feed-forward (DAE) blocked matmul: C = A @ B, as a StreamProgram.

The paper's transformation, applied to the canonical MXU workload:

* producer stages = the A and B tile streams (two ring-pipe edges), issued
  ``depth-1`` words ahead; ``streams`` splits each tile copy into parallel
  sub-DMAs (multi-producer M2C2 analogue);
* consumer       = MXU dot over the landed tiles, accumulating in VMEM f32;
* ``depth=1`` degenerates to synchronous copy-then-compute — the "single
  work-item" baseline used by the Table-2 benchmark.

Word schedule: 1-D grid over (mi, ni, ki) with k innermost; the output block
(mi, ni) is revisited for nK consecutive steps and written on the last.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pipe import Pipe
from repro.core.program import ScratchSpec, Stream, StreamProgram, \
    compile_program


def build_program(m: int, n: int, k: int, *,
                  block: Tuple[int, int, int] = (128, 128, 128),
                  dtype=jnp.float32, b_dtype=None, out_dtype=None,
                  depth: int = 2, streams: int = 1) -> StreamProgram:
    """Declare the matmul stream program at one (block-aligned) shape.
    ``dtype`` sizes the A pipe, ``b_dtype`` (default ``dtype``) the B pipe —
    each operand streams through a ring of its own element type."""
    bm, bn, bk = block
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, ((m, n, k), block)
    nm, nn, nk = m // bm, n // bn, k // bk
    b_dtype = b_dtype or dtype
    out_dtype = out_dtype or dtype

    def a_slicer(ctx, word):
        w_ki = word % nk
        w_mi = word // (nk * nn)
        return ctx.ref("a").at[pl.ds(w_mi * bm, bm), pl.ds(w_ki * bk, bk)]

    def b_slicer(ctx, word):
        w_ki = word % nk
        w_ni = (word // nk) % nn
        return ctx.ref("b").at[pl.ds(w_ki * bk, bk), pl.ds(w_ni * bn, bn)]

    def consumer(ctx):
        ki = ctx.g % nk
        acc = ctx.scratch("acc")

        @pl.when(ki == 0)
        def _():
            acc[...] = jnp.zeros_like(acc)

        acc[...] += jnp.dot(ctx.word("a")[...], ctx.word("b")[...],
                            preferred_element_type=jnp.float32)

        @pl.when(ki == nk - 1)
        def _():
            ctx.out[...] = acc[...].astype(out_dtype)

    return StreamProgram(
        name="ff_matmul",
        n_words=nm * nn * nk,
        inputs=(
            # index declares each stream's block schedule (the address
            # stream as pure int arithmetic) so the graph fuser can match
            # an upstream producer's output schedule against it
            Stream("a", Pipe(tile=(bm, bk), dtype=dtype, depth=depth,
                             streams=streams), a_slicer,
                   index=lambda w: (w // (nk * nn), w % nk)),
            Stream("b", Pipe(tile=(bk, bn), dtype=b_dtype, depth=depth,
                             streams=streams), b_slicer,
                   index=lambda w: (w % nk, (w // nk) % nn)),
        ),
        consumer=consumer,
        out_shape=(m, n),
        out_dtype=out_dtype,
        out_block=(bm, bn),
        out_index_map=lambda g: (g // (nn * nk), (g // nk) % nn),
        scratch=(ScratchSpec("acc", (bm, bn), jnp.float32),),
    )


@functools.partial(
    jax.jit,
    static_argnames=("block", "depth", "streams", "out_dtype", "interpret"))
def matmul_ff(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block: Tuple[int, int, int] = (128, 128, 128),
    depth: int = 2,
    streams: int = 1,
    out_dtype=None,
    interpret: bool = True,
) -> jnp.ndarray:
    """DAE-pipelined matmul. Shapes must be multiples of ``block`` (use
    ops.matmul for auto-padding)."""
    (m, k), (k2, n) = a.shape, b.shape
    assert k == k2, (a.shape, b.shape)
    program = build_program(m, n, k, block=block, dtype=a.dtype,
                            b_dtype=b.dtype, out_dtype=out_dtype, depth=depth,
                            streams=streams)
    return compile_program(program, interpret=interpret)(a, b)
