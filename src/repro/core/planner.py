"""Roofline-driven pipe planner.

The paper leaves (depth, #producers, #consumers) to the programmer, guided
by profiler output, and reports two empirical rules: depth barely matters
once latency is hidden, and >2x2 streams saturate the memory system. The
planner encodes exactly that reasoning on top of the analytic model, so the
framework can size pipes automatically per kernel call site.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax.numpy as jnp

from repro import obs
from repro.core import profiling
from repro.core.meshspec import MeshSpec, SINGLE_DEVICE, resolve_mesh
from repro.core.pipe import DEFAULT_VMEM_BUDGET_BYTES, Pipe, \
    required_depth, vmem_budget_ok
from repro.core.pipeline_model import (
    HardwareModel,
    TPU_V5E,
    Workload,
    estimate_feedforward,
)


class PlanError(RuntimeError):
    """No feasible (depth, streams) candidate under the VMEM budget.

    Raised (never asserted: asserts vanish under ``python -O``) with the
    full search context attached, so autotune/bench callers can report the
    search space instead of a bare failure:

    Attributes:
      workload: the :class:`~repro.core.pipeline_model.Workload` planned for.
      vmem_budget_bytes: the budget every candidate was checked against.
      rejected: one human-readable line per rejected candidate.
    """

    def __init__(self, workload: Workload, vmem_budget_bytes: int,
                 rejected: Sequence[str]):
        self.workload = workload
        self.vmem_budget_bytes = vmem_budget_bytes
        self.rejected = tuple(rejected)
        lines = "; ".join(self.rejected) or "(no candidates generated)"
        super().__init__(
            f"no feasible pipe under the {vmem_budget_bytes}-byte VMEM "
            f"budget for workload {workload}; rejected: {lines}")


@dataclasses.dataclass(frozen=True)
class Plan:
    pipe: Pipe
    consumers: int
    predicted_s: float
    predicted_bw: float
    rationale: str
    skipped: Tuple[str, ...] = ()    # rejected candidates, one line each
    # what the plan was sized against: the (local, per-shard) workload and
    # the mesh topology the call site ran under — introspectable via
    # last_plan() so sharded tests can assert local-shape planning
    workload: Optional[Workload] = None
    mesh: MeshSpec = SINGLE_DEVICE


def plan_pipe(
    w: Workload,
    tile: Tuple[int, ...],
    dtype,
    hw: HardwareModel = TPU_V5E,
    stream_options: Sequence[int] = (1, 2, 4),
    depth_cap: int = 17,     # (cap-1) outstanding = burst-LSU parity

    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
) -> Plan:
    """Pick (depth, streams) minimizing modeled time under the VMEM budget.

    Ties break toward fewer streams and shallower pipes (the paper's
    "limit the number of channels" guidance).
    """
    base_pipe = Pipe(tile=tile, dtype=dtype, depth=2, streams=1)
    service = w.word_bytes / hw.stream_bandwidth(1, w.regular)
    depth = required_depth(hw.dma_latency_s, service, cap=depth_cap)

    best: Plan | None = None
    skipped = []
    for streams in stream_options:
        if tile[0] % streams != 0:
            skipped.append(
                f"streams={streams}: tile[0]={tile[0]} not divisible")
            continue
        pipe = base_pipe.with_depth(depth).with_streams(streams)
        if not vmem_budget_ok([pipe], vmem_budget_bytes):
            skipped.append(
                f"streams={streams} depth={depth}: ring vmem "
                f"{pipe.vmem_bytes}B > budget {vmem_budget_bytes}B")
            continue
        est = estimate_feedforward(w, hw, pipe)
        cand = Plan(
            pipe=pipe,
            consumers=streams,
            predicted_s=est.total_s,
            predicted_bw=est.achieved_bw,
            workload=w,
            rationale=(
                f"depth={depth} hides dma latency "
                f"({hw.dma_latency_s*1e9:.0f}ns over {service*1e9:.0f}ns/word); "
                f"streams={streams} bottleneck={est.bottleneck}"),
        )
        # require a >2% modeled win to take on more streams (channel-count
        # frugality, per the paper)
        if best is None or cand.predicted_s < best.predicted_s * 0.98:
            best = cand
    if best is None:
        raise PlanError(w, vmem_budget_bytes, skipped)
    if skipped:
        best = dataclasses.replace(
            best, skipped=tuple(skipped),
            rationale=best.rationale + f"; skipped: {'; '.join(skipped)}")
    return best


# -- call-site auto-sizing (depth="auto" / streams="auto") --------------------
#
# Every kernel's public op wrapper routes through here: the op builds its
# Workload from the call-site shapes and the planner returns the (depth,
# streams) the analytic model picks. Plans are memoized: the key is
# (op, workload, tile, dtype, hw, mesh, knobs) — workload and tile are pure
# functions of (op, shape, dtype), so this is the per-(op, shape, dtype, hw,
# mesh) plan cache with no risk of shape aliasing, and plans sized under one
# mesh topology are never served to call sites running under another.
#
# The cache is a hand-rolled insertion-ordered dict (not functools.lru_cache)
# so the resilience layer can *selectively* invalidate: an elastic remesh
# drops exactly the entries keyed by meshes that no longer exist
# (invalidate_mesh_plans) instead of nuking plans that are still valid.


class _CacheInfo(NamedTuple):
    hits: int
    misses: int
    maxsize: int
    currsize: int


_PLAN_MAXSIZE = 1024
_PLANS: "dict[tuple, Plan]" = {}    # insertion-ordered: FIFO eviction
_PLAN_HITS = 0
_PLAN_MISSES = 0


def _plan_cached(op: str, w: Workload, tile: Tuple[int, ...],
                 dtype_name: str, hw: HardwareModel,
                 stream_options: Tuple[int, ...], depth_cap: int,
                 vmem_budget_bytes: int, mesh: MeshSpec) -> Plan:
    global _PLAN_HITS, _PLAN_MISSES
    key = (op, w, tile, dtype_name, hw, stream_options, depth_cap,
           vmem_budget_bytes, mesh)
    plan = _PLANS.get(key)
    if plan is not None:
        _PLAN_HITS += 1
        return plan
    _PLAN_MISSES += 1
    plan = plan_pipe(w, tile, jnp.dtype(dtype_name), hw,
                     stream_options=stream_options, depth_cap=depth_cap,
                     vmem_budget_bytes=vmem_budget_bytes)
    plan = dataclasses.replace(plan, mesh=mesh)
    if len(_PLANS) >= _PLAN_MAXSIZE:
        _PLANS.pop(next(iter(_PLANS)))
    _PLANS[key] = plan
    return plan


def invalidate_mesh_plans(keep: MeshSpec, *,
                          keep_single: bool = True) -> int:
    """Drop every cached plan keyed by a mesh other than ``keep``.

    The elastic-recovery hook: after a remesh the surviving topology is
    ``keep`` — plans sized under the lost topology must never be served
    again, while plans for the surviving mesh (and, by default, the
    topology-independent :data:`~repro.core.meshspec.SINGLE_DEVICE`
    entries) stay warm. ``last_plan`` entries for dropped meshes are
    cleared too. Returns the number of plans dropped.
    """
    kept_meshes = {keep} | ({SINGLE_DEVICE} if keep_single else set())
    stale = [k for k, p in _PLANS.items() if p.mesh not in kept_meshes]
    for k in stale:
        del _PLANS[k]
    for op in [op for op, p in _LAST_PLAN.items()
               if p.mesh not in kept_meshes]:
        del _LAST_PLAN[op]
    return len(stale)


_LAST_PLAN: "dict[str, Plan]" = {}   # op -> most recent plan resolved


def last_plan(op: str) -> Optional[Plan]:
    """The most recent plan resolved for ``op`` (introspection hook: its
    ``workload``/``mesh`` record what the call site was actually sized
    against — the sharded-stream tests assert local-shape planning here)."""
    return _LAST_PLAN.get(op)


def planned_pipe(
    op: str,
    w: Workload,
    tile: Tuple[int, ...],
    dtype,
    hw: HardwareModel = TPU_V5E,
    stream_options: Sequence[int] = (1, 2, 4),
    depth_cap: int = 17,
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
    mesh: MeshSpec = SINGLE_DEVICE,
) -> Plan:
    """Memoized :func:`plan_pipe` for one kernel call site."""
    pre_misses = _PLAN_MISSES
    with obs.span("plan_pipe", op=op, mesh=mesh.token) as sp:
        plan = _plan_cached(op, w, tuple(tile), jnp.dtype(dtype).name, hw,
                            tuple(stream_options), depth_cap,
                            vmem_budget_bytes, mesh)
        sp.set(depth=plan.pipe.depth, streams=plan.pipe.streams,
               predicted_s=plan.predicted_s,
               cached=_PLAN_MISSES == pre_misses)
    _LAST_PLAN[op] = plan
    return plan


def resolve_auto(
    op: str,
    depth: Union[int, str],
    streams: Union[int, str],
    *,
    workload: Workload,
    tile: Tuple[int, ...],
    dtype,
    hw: HardwareModel = TPU_V5E,
    stream_options: Sequence[int] = (1, 2, 4),
    mesh: MeshSpec = SINGLE_DEVICE,
) -> Tuple[int, int]:
    """Resolve ``depth="auto"`` / ``streams="auto"`` to planned integers.

    Explicit integers pass through untouched (the paper's programmer-chosen
    sizing stays available); the planner only runs when at least one of the
    two is ``"auto"``, and its Plan is served from the per-(op, shape,
    dtype, hw, mesh) cache on repeat call sites. ``"measured"`` is accepted
    as a synonym for ``"auto"`` here: it is the analytic *fallback* for call
    sites the autotuner (:mod:`repro.core.autotune`) cannot measure (traced
    arguments, no runner) — measured resolution itself never reaches this
    function.
    """
    for label, val in (("depth", depth), ("streams", streams)):
        if isinstance(val, str) and val not in ("auto", "measured"):
            raise ValueError(
                f"{label} must be an int or 'auto'/'measured', got {val!r}")
    depth = "auto" if depth == "measured" else depth
    streams = "auto" if streams == "measured" else streams
    if depth != "auto" and streams != "auto":
        return int(depth), int(streams)
    plan = planned_pipe(op, workload, tile, dtype, hw,
                        stream_options=stream_options, mesh=mesh)
    d = plan.pipe.depth if depth == "auto" else int(depth)
    s = plan.pipe.streams if streams == "auto" else int(streams)
    return d, s


def resolve_policy(
    op: str,
    policy,
    *,
    workload: Workload,
    tile: Tuple[int, ...],
    dtype,
    mesh: Optional[MeshSpec] = None,
) -> Tuple[int, int]:
    """Planner entry for :class:`repro.core.program.PipePolicy` call sites.

    Duck-typed over anything exposing ``mode`` / ``depth`` / ``streams`` /
    ``hw`` / ``stream_options`` (and optionally ``mesh``): resolves "auto"
    fields against the policy's hardware model and mesh topology (so plans
    are cache-keyed by policy *and* topology, not just shape) and applies
    the mode semantics — ``baseline`` forces the synchronous depth=1 pipe
    after planning, exactly like the legacy per-kernel keyword plumbing
    did. When the policy carries no explicit mesh, the ambient
    :class:`~repro.runtime.sharding.ShardingContext` is consulted — a call
    site running inside ``use_sharding`` plans under that topology without
    any keyword plumbing.
    """
    if mesh is None:
        mesh = resolve_mesh(getattr(policy, "mesh", None))
    if profiling.recording():
        # planner-origin traffic record: suppressed when the call came
        # through autotune.resolve_call (which already recorded it)
        profiling.emit_planner(op=op, policy=policy, workload=workload,
                               tile=tile, dtype=jnp.dtype(dtype).name,
                               mesh=mesh)
    with obs.span("resolve_policy", op=op, mode=policy.mode,
                  mesh=mesh.token) as sp:
        depth, streams = resolve_auto(
            op, policy.depth, policy.streams, workload=workload, tile=tile,
            dtype=dtype, hw=policy.hw,
            stream_options=tuple(policy.stream_options), mesh=mesh)
        if policy.mode == "baseline":
            depth = 1
        sp.set(depth=depth, streams=streams)
    return depth, streams


# -- multi-kernel graphs (repro.core.graph) ----------------------------------
#
# A fused graph runs several stream programs inside one pallas_call, so the
# single-kernel VMEM budget must be *split* across the fused stages: each
# node plans its pipes against its share, and the fuser re-checks the
# combined footprint of a fused pair (producer rings + the in-VMEM
# intermediate ring + consumer rings + scratch) before committing to fusion.


def split_graph_budget(names: Sequence[str],
                       vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
                       ) -> "dict[str, int]":
    """Split the VMEM budget evenly across a graph's nodes.

    Even split is deliberate: the budget bounds the *worst case* where every
    adjacent edge fuses and all stages cohabit one kernel. A node that plans
    under its share is guaranteed composable into any fused segment.
    """
    if not names:
        return {}
    share = vmem_budget_bytes // len(names)
    return {n: share for n in names}


def check_fused_vmem(edge: str, parts: "dict[str, int]",
                     vmem_budget_bytes: int) -> Tuple[bool, str]:
    """Check one fused pair's combined VMEM footprint against its budget.

    ``parts`` itemizes the footprint (producer rings, intermediate ring,
    consumer rings, scratch). Returns (feasible, rationale-line); the
    caller turns an infeasible *requested* fusion into a :class:`PlanError`
    with this line in ``rejected`` and an auto fusion into a staged
    fallback with the line as the edge rationale.
    """
    del edge    # callers prefix the edge label when surfacing the line
    total = sum(parts.values())
    detail = " + ".join(f"{k}={v}B" for k, v in parts.items())
    if total <= vmem_budget_bytes:
        return True, (f"fused vmem {total}B ({detail}) fits the "
                      f"{vmem_budget_bytes}B fused-stage budget")
    return False, (f"fused vmem {total}B ({detail}) exceeds the "
                   f"{vmem_budget_bytes}B fused-stage budget")


def plan_cache_info() -> _CacheInfo:
    """Hit/miss stats of the planner's plan cache (CacheInfo-shaped)."""
    return _CacheInfo(_PLAN_HITS, _PLAN_MISSES, _PLAN_MAXSIZE, len(_PLANS))


def plan_cache_clear() -> None:
    global _PLAN_HITS, _PLAN_MISSES
    _PLANS.clear()
    _PLAN_HITS = 0
    _PLAN_MISSES = 0
    _LAST_PLAN.clear()
