from repro.kernels.ff_attention.ops import attention, attention_cost
from repro.kernels.ff_attention.ref import attention_ref

__all__ = ["attention", "attention_cost", "attention_ref"]
