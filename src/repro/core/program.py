"""Declarative stream programs: kernels as producer→pipe→consumer graphs.

The paper restructures a monolithic kernel into a *memory kernel* and a
*compute kernel* joined by a pipe; MKPipe (arXiv 2002.01614) argues the
decomposition pays off most when the multi-kernel program is a first-class
object the compiler can schedule. This module is that surface for the repo:
a kernel is *declared* as

  * producer stages — :class:`Stream` edges (regular block copies or
    irregular per-row gathers), each naming its HBM operand, pipe word
    shape, and address stream (``slicer``);
  * passive operands — :class:`BlockIn` (Pallas-blocked inputs such as the
    q tile) and :class:`ScalarIn` (scalar-prefetched index/length vectors);
  * a consumer compute body — ``consumer(ctx)`` reading landed pipe words
    via ``ctx.word(name)`` and folding them into scratch carries / the
    output block;

and :func:`compile_program` lowers the graph through the shared
:class:`~repro.core.emitter.RingPipe` / ``GatherRingPipe`` emitter into one
``pallas_call``: it owns the ring scratch, binds slicers, and emits the
acquire → consume → release word schedule. No kernel hand-rolls ring-buffer
plumbing; a new workload is a ~50-line declaration.

Sizing and mode selection are carried by one frozen :class:`PipePolicy`
(``mode`` / ``depth`` / ``streams`` / ``interpret`` / ``hw``), threaded
through the roofline planner (:func:`repro.core.planner.resolve_policy`)
instead of five copies of keyword plumbing. Session defaults are set with
the :func:`policy` context manager::

    with repro.policy(mode="baseline"):      # A/B the paper's strawman
        y = repro.ops.attention(q, k, v)
    with repro.policy(hw=ARRIA_CX):          # plan pipes for the paper's board
        y = repro.ops.matmul(a, b)

Old per-kernel keyword signatures (``mode=``/``depth=``/``streams=``/
``interpret=``) keep working through :func:`resolve_call_policy`, which
folds them into a PipePolicy and warns once per op.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
import warnings
from typing import Any, Callable, Dict, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs
from repro.core import planner
from repro.core.emitter import GatherRingPipe, RingPipe, acquire, release
from repro.core.meshspec import MeshSpec, localize_workload, resolve_sharding
from repro.core.pipe import Pipe
from repro.core.pipeline_model import TPU_V5E, HardwareModel, Workload

# ---------------------------------------------------------------------------
# PipePolicy: one frozen knob bundle for every kernel call site
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PipePolicy:
    """How to size and run the pipes of one kernel call.

    Attributes:
      mode: "ff" (DAE pipeline), "baseline" (synchronous depth=1 strawman),
        "ref" (pure-jnp oracle), "autotune" (pipelined like "ff" but the
        (tile, depth, streams) configuration is *measured* per call site by
        :mod:`repro.core.autotune` and served from the persistent plan
        cache), or a kernel-specific extra mode.
      depth: ring slots — int, "auto" (roofline-planned per call site), or
        "measured" (empirically tuned at the kernel's default tile).
      streams: producer DMAs per word — int, "auto", or "measured".
      interpret: run the Pallas kernel in interpret mode (CPU container).
      hw: hardware model the planner sizes against (TPU_V5E / ARRIA_CX);
        also part of the tuned-plan cache key.
      stream_options: candidate stream counts the planner/tuner may pick
        from.
      mesh: the mesh topology this policy's call sites run under
        (:class:`~repro.core.meshspec.MeshSpec`) — part of every plan and
        tuned-plan cache key, so plans sized for one topology never leak
        to another. ``None`` (the default) picks up the ambient
        :class:`~repro.runtime.sharding.ShardingContext` at resolve time;
        :func:`repro.runtime.streams.mesh_policy` tags a policy explicitly.
    """

    mode: str = "ff"
    depth: Union[int, str] = "auto"
    streams: Union[int, str] = "auto"
    interpret: bool = True
    hw: HardwareModel = TPU_V5E
    stream_options: Tuple[int, ...] = (1, 2, 4)
    mesh: Optional[MeshSpec] = None

    def __post_init__(self):
        if not isinstance(self.mode, str):
            raise TypeError(f"mode must be a str, got {self.mode!r}")
        if self.mesh is not None and not isinstance(self.mesh, MeshSpec):
            raise TypeError(
                f"mesh must be a MeshSpec or None, got {self.mesh!r}")
        for label, val in (("depth", self.depth), ("streams", self.streams)):
            if isinstance(val, str):
                if val not in ("auto", "measured"):
                    raise ValueError(f"{label} must be an int, 'auto', or "
                                     f"'measured', got {val!r}")
            elif int(val) < 1:
                raise ValueError(f"{label} must be >= 1, got {val!r}")

    def replace(self, **fields) -> "PipePolicy":
        return dataclasses.replace(self, **fields)

    def resolve(self, op: str, *, workload, tile, dtype) -> Tuple[int, int]:
        """Resolve this policy's (depth, streams) for one call site."""
        return planner.resolve_policy(op, self, workload=workload, tile=tile,
                                      dtype=dtype)


class _PolicyStack(threading.local):
    def __init__(self):
        self.stack = [PipePolicy()]


_policies = _PolicyStack()


def current_policy() -> PipePolicy:
    """The session's active policy (innermost :func:`policy` context)."""
    return _policies.stack[-1]


@contextlib.contextmanager
def policy(base: Optional[PipePolicy] = None, **fields):
    """Set session pipe-policy defaults without touching call sites.

    ``policy(mode="baseline")`` overrides just that field of the current
    policy; ``policy(some_policy)`` installs it wholesale (plus any field
    overrides). Nests and restores on exit; thread-local.

    Trace-time semantics: ops read the session policy when they are
    *traced*. The built-in kernel entrypoints re-resolve it on every call,
    but if you wrap an op in your own ``jax.jit``, a cached trace will NOT
    see a later policy change (the policy is not part of the jit cache
    key). Inside user jits, pass ``policy=PipePolicy(...)`` explicitly —
    it is hashable and works as a static argument — or enter the context
    before the first traced call.
    """
    pol = current_policy() if base is None else base
    if fields:
        pol = dataclasses.replace(pol, **fields)
    _policies.stack.append(pol)
    try:
        yield pol
    finally:
        _policies.stack.pop()


# -- deprecation shim: legacy keyword plumbing -> PipePolicy -----------------

_LEGACY_KWARGS = ("mode", "depth", "streams", "interpret")
_warned_ops = set()


def resolve_call_policy(op: str, call_policy: Optional[PipePolicy] = None,
                        **legacy) -> PipePolicy:
    """Fold one call's (policy=, legacy kwargs) into the effective policy.

    ``policy=`` overrides the session :func:`policy` context wholesale;
    legacy kwargs override individual fields of the session policy and warn
    once per op (the pre-StreamProgram keyword plumbing is deprecated).
    Mixing ``policy=`` with legacy kwargs in one call is ambiguous and
    raises TypeError.
    """
    given = {k: v for k, v in legacy.items() if v is not None}
    unknown = set(given) - set(_LEGACY_KWARGS)
    if unknown:
        raise TypeError(f"{op}: unknown policy kwargs {sorted(unknown)}")
    base = current_policy() if call_policy is None else call_policy
    if not given:
        return base
    if call_policy is not None:
        raise TypeError(
            f"{op}: pass either policy= or the deprecated "
            f"{sorted(given)} keywords, not both")
    if op not in _warned_ops:
        _warned_ops.add(op)
        warnings.warn(
            f"{op}: the {sorted(given)} keywords are deprecated; pass "
            f"policy=PipePolicy(...) or set session defaults with "
            f"`with repro.policy(...)`", DeprecationWarning, stacklevel=3)
    return dataclasses.replace(base, **given)


def make_entrypoint(op: str, apply_fn: Callable[..., Any],
                    modes: Tuple[str, ...] = ("ff", "baseline", "ref",
                                              "autotune"),
                    ) -> Callable[..., Any]:
    """Generate the public op wrapper from a policy-driven apply function.

    ``apply_fn(*arrays, policy: PipePolicy, **statics)`` implements the op;
    the generated entrypoint accepts the new ``policy=`` argument, the
    session policy context, and the deprecated per-kernel keywords
    (``mode``/``depth``/``streams``/``interpret``), all funneled through
    :func:`resolve_call_policy`. ``modes`` is the op's supported mode set —
    validated here, once, so apply functions never hand-roll the check.
    """

    @functools.wraps(apply_fn)
    def entrypoint(*args, policy=None, mode=None, depth=None, streams=None,
                   interpret=None, **kwargs):
        pol = resolve_call_policy(op, policy, mode=mode, depth=depth,
                                  streams=streams, interpret=interpret)
        if pol.mode not in modes:
            raise ValueError(
                f"{op}: unknown mode {pol.mode!r}; supported: {modes}")
        return apply_fn(*args, policy=pol, **kwargs)

    entrypoint.op_name = op
    entrypoint.__name__ = op
    entrypoint.__qualname__ = apply_fn.__qualname__.replace("_apply", op)
    return entrypoint


# ---------------------------------------------------------------------------
# The StreamProgram IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Stream:
    """A producer stage + pipe edge: operand ``name`` streams HBM→VMEM.

    ``slicer(ctx, word) -> hbm-ref-slice`` is the memory kernel's address
    stream (regular block copy); for ``gather=True`` it is a row slicer
    ``slicer(ctx, word, row)`` (irregular per-row gather — the row bundle is
    the stream decomposition). Slicers may depend only on the word index and
    input operands (typically scalar-prefetched indices), never on consumer
    state — the feed-forward restriction, enforced structurally: slicers
    receive a :class:`ProducerCtx` that exposes ``ref()`` only, no scratch
    or output.

    ``index`` optionally *declares* the stream's block schedule for the
    graph fuser (:mod:`repro.core.graph`): ``index(word) -> block-index
    tuple`` names which tile of the operand word ``word`` consumes, in the
    operand's own ``tile`` blocking. It must be a pure function of the word
    index (valid on Python ints for legality analysis and on traced ints
    inside the kernel) and must agree with ``slicer`` — the slicer of a
    declared stream is ``ref.at[index(word) * tile]``. Streams whose
    addresses are data-dependent (gathers) cannot declare one; an edge into
    such a stream always lowers staged.
    """

    name: str
    spec: Pipe
    slicer: Callable[..., Any]
    gather: bool = False
    index: Optional[Callable[..., Tuple[int, ...]]] = None


@dataclasses.dataclass(frozen=True)
class BlockIn:
    """A Pallas-blocked (non-streamed) input operand.

    ``dtype`` declares the operand element type. Plain ``compile_program``
    lowering never needs it (Pallas blocks carry the operand's own dtype),
    but the fused graph lowering (:mod:`repro.core.graph`) promotes producer
    BlockIns to ring-pipe streams, and a ring buffer must be sized at trace
    time — so the declaration carries the dtype.
    """

    name: str
    block: Tuple[int, ...]
    index_map: Callable[..., Any]
    dtype: Any = jnp.float32


@dataclasses.dataclass(frozen=True)
class ScalarIn:
    """A scalar-prefetched input (index/length vectors the slicers read)."""

    name: str


@dataclasses.dataclass(frozen=True)
class ScratchSpec:
    """One VMEM scratch carry owned by the consumer (accumulators etc.)."""

    name: str
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32


InputSpec = Union[Stream, BlockIn, ScalarIn]


class ScheduleOpaqueError(ValueError):
    """A block schedule could not be evaluated statically.

    Raised by :meth:`StreamProgram.out_schedule` /
    :meth:`StreamProgram.stream_schedule` when the requested schedule is
    data-dependent (an index map that reads scalar-prefetch operands, or a
    stream with no declared ``index``). The graph fuser treats this as
    "not fusible along this edge" and falls back to staged lowering — it is
    a rationale, never a hard failure.
    """


class _OpaqueScalar:
    """Stand-in for a scalar-prefetch ref during static schedule evaluation:
    any attempt to *read* it proves the schedule is data-dependent."""

    def _opaque(self, *_, **__):
        raise ScheduleOpaqueError(
            "schedule depends on a scalar-prefetch operand (data-dependent)")

    __getitem__ = __getattr__ = __index__ = __int__ = _opaque
    __add__ = __radd__ = __mul__ = __rmul__ = _opaque
    __floordiv__ = __rfloordiv__ = __mod__ = __rmod__ = _opaque


class ProducerCtx:
    """What a Stream slicer sees: the input refs, nothing else.

    The producer has no access to scratch carries or the output block, so
    a slicer *cannot* depend on consumer state — the paper's feed-forward
    restriction falls out of the type.
    """

    __slots__ = ("_refs",)

    def __init__(self, refs):
        self._refs = refs

    def ref(self, name: str):
        """Raw ref of input ``name`` (HBM for streams, block/scalar else)."""
        return self._refs[name]


class ProgramCtx(ProducerCtx):
    """What the consumer body (and slicers) see inside the kernel.

    Attributes:
      g: current word index (grid step).
      n_words: total pipe words.
      out: output block ref.
    """

    __slots__ = ("g", "n_words", "out", "_pipes", "_scratch")

    def __init__(self, g, n_words, refs, pipes, out, scratch):
        super().__init__(refs)
        self.g = g
        self.n_words = n_words
        self.out = out
        self._pipes = pipes
        self._scratch = scratch

    def word(self, name: str):
        """VMEM ref of stream ``name``'s landed word ``g`` (pipe read end)."""
        return self._pipes[name].slot(self.g)

    def scratch(self, name: str):
        return self._scratch[name]


@dataclasses.dataclass(frozen=True)
class StreamProgram:
    """A kernel declared as producer stages → pipes → consumer body.

    Attributes:
      name: op name (planner / registry key).
      n_words: trip count of the word schedule (the 1-D grid).
      inputs: call-ordered operand specs; ScalarIn entries must lead (the
        Pallas scalar-prefetch convention). Block/out index maps receive
        ``(g, *scalar_refs)`` when ScalarIn operands exist, else ``(g,)``.
      consumer: ``f(ctx: ProgramCtx) -> None`` — the compute kernel. All
        arithmetic, DLCD carries, and output stores live here.
      out_shape / out_dtype / out_block / out_index_map: the output block
        mapping.
      scratch: consumer-owned VMEM carries (ring scratch is implicit —
        compile_program appends each stage's buffer + semaphores).
    """

    name: str
    n_words: int
    inputs: Tuple[InputSpec, ...]
    consumer: Callable[[ProgramCtx], None]
    out_shape: Tuple[int, ...]
    out_dtype: Any
    out_block: Tuple[int, ...]
    out_index_map: Callable[..., Any]
    scratch: Tuple[ScratchSpec, ...] = ()

    def __post_init__(self):
        names = [i.name for i in self.inputs] + [s.name for s in self.scratch]
        if len(set(names)) != len(names):
            raise ValueError(f"{self.name}: duplicate operand/scratch names "
                             f"in {names}")
        seen_tensor = False
        for i in self.inputs:
            if isinstance(i, ScalarIn):
                if seen_tensor:
                    raise ValueError(
                        f"{self.name}: ScalarIn operands must precede tensor "
                        f"operands (Pallas scalar-prefetch convention)")
            else:
                seen_tensor = True
        if not self.streams:
            raise ValueError(f"{self.name}: a StreamProgram needs at least "
                             f"one Stream edge")
        if self.n_words < 1:
            raise ValueError(f"{self.name}: n_words must be >= 1")

    @property
    def streams(self) -> Tuple[Stream, ...]:
        return tuple(i for i in self.inputs if isinstance(i, Stream))

    @property
    def num_scalar_prefetch(self) -> int:
        return sum(isinstance(i, ScalarIn) for i in self.inputs)

    @property
    def vmem_bytes(self) -> int:
        """Ring-buffer VMEM of all pipe edges (the BRAM analogue)."""
        return sum(s.spec.vmem_bytes for s in self.streams)

    def stream(self, name: str) -> Stream:
        for s in self.streams:
            if s.name == name:
                return s
        raise KeyError(f"{self.name}: no stream {name!r}; streams: "
                       f"{[s.name for s in self.streams]}")

    # -- static block schedules (the graph fuser's legality surface) --------

    def out_schedule(self) -> Tuple[Tuple[int, ...], ...]:
        """The output block schedule: ``out_index_map`` evaluated per word.

        Returns one block-index tuple per grid word — the write schedule the
        graph fuser matches against a downstream consumer's stream schedule
        (:mod:`repro.core.graph`). Raises :class:`ScheduleOpaqueError` when
        the map reads scalar-prefetch operands (data-dependent output
        placement): such a program cannot be a fused producer.
        """
        dummies = (_OpaqueScalar(),) * self.num_scalar_prefetch
        sched = []
        for g in range(self.n_words):
            try:
                idx = self.out_index_map(g, *dummies)
                sched.append(tuple(int(i) for i in idx))
            except ScheduleOpaqueError:
                raise
            except Exception as e:   # noqa: BLE001 — map not int-evaluable
                raise ScheduleOpaqueError(
                    f"{self.name}: out_index_map is not statically "
                    f"evaluable at word {g}: {type(e).__name__}: {e}") from e
        return tuple(sched)

    def stream_schedule(self, name: str) -> Tuple[Tuple[int, ...], ...]:
        """Stream ``name``'s declared block schedule, one tuple per word.

        Requires the stream to declare :attr:`Stream.index`; raises
        :class:`ScheduleOpaqueError` otherwise (irregular/gather streams) —
        the fuser's staged-fallback signal.
        """
        st = self.stream(name)
        if st.index is None:
            raise ScheduleOpaqueError(
                f"{self.name}: stream {name!r} declares no block schedule "
                f"(Stream.index); its addresses are data-dependent")
        try:
            return tuple(tuple(int(i) for i in st.index(g))
                         for g in range(self.n_words))
        except ScheduleOpaqueError:
            raise
        except Exception as e:   # noqa: BLE001
            raise ScheduleOpaqueError(
                f"{self.name}: stream {name!r} index is not statically "
                f"evaluable: {type(e).__name__}: {e}") from e


# ---------------------------------------------------------------------------
# Lowering: StreamProgram -> one pallas_call
# ---------------------------------------------------------------------------


def program_workload(program: StreamProgram) -> Workload:
    """Synthesize a conservative analytic Workload from a program's streams
    (n_words, per-word load/store bytes, regularity) — the planner input
    for programs whose kernel did not declare a workload builder."""
    import numpy as np

    store = (float(np.prod(program.out_shape))
             * jnp.dtype(program.out_dtype).itemsize) / program.n_words
    return Workload(
        n_words=program.n_words,
        word_bytes=float(sum(s.spec.word_bytes for s in program.streams)),
        flops_per_word=0.0,
        regular=not any(s.gather for s in program.streams),
        store_bytes_per_word=store,
    )


def _clamped_streams(tile0: int, streams: int) -> int:
    """Largest power-of-two-reduced stream count dividing the tile's
    leading dim (the planner's global choice refined per stream)."""
    s = max(1, int(streams))
    while s > 1 and tile0 % s:
        s //= 2
    return max(1, s)


def _traced_compile(fn):
    """Wrap the program lowering in an obs span (no-op when tracing is
    off) so compile time and ring structure land in the trace."""
    @functools.wraps(fn)
    def wrapper(program, **kw):
        with obs.span("compile_program", program=program.name,
                      n_words=program.n_words,
                      streams=len(program.streams)):
            return fn(program, **kw)
    return wrapper


@_traced_compile
def compile_program(program: StreamProgram, *,
                    interpret: Optional[bool] = None,
                    pipe_overrides: Optional[Mapping[str, Pipe]] = None,
                    policy: Optional[PipePolicy] = None, sharding=None):
    """Lower a :class:`StreamProgram` into one ``pallas_call``.

    Returns a callable taking the program's operands in ``inputs`` order.
    The lowering instantiates one :class:`RingPipe` (or ``GatherRingPipe``)
    per Stream edge, appends the ring scratch it owns after the consumer's
    scratch, and wraps the consumer body in the emitter's word schedule::

        acquire(g, n_words, pipes)   # prologue fill + block on word g
        consumer(ctx)                # compute kernel
        release(g, n_words, pipes)   # refill consumed slots

    ``depth == 1`` pipes degenerate to the synchronous copy-then-compute
    baseline, so mode="baseline" reuses this exact path.

    ``pipe_overrides`` re-sizes named Stream edges at compile time: each
    entry replaces that stream's :class:`Pipe` spec with one of a
    different ``depth``/``streams`` without re-declaring the program —
    useful for sweeping ring sizes over a hand-built program (the
    built-in kernels instead rebuild through ``build_program(depth=,
    streams=)``). The word geometry is fixed by the declaration's
    slicers, so an override must keep ``tile`` and ``dtype`` unchanged —
    a different *tile* candidate is a different program, built through
    the kernel's ``build_program(...)`` / the registry's
    ``program(tile=...)`` hook.

    ``policy`` (optional) asks compile_program to *plan* the pipes
    instead: every regular stream is re-sized to the planner's (depth,
    streams) for the program's synthesized workload under the policy
    (gather streams keep their declared stream count — their row bundle
    is part of the word geometry), and ``policy.interpret`` supplies the
    interpret flag unless ``interpret=`` is passed explicitly.
    ``sharding`` localizes that planning to the mesh: pass a
    :class:`~repro.runtime.sharding.ShardingContext` (or a bare
    :class:`~repro.core.meshspec.MeshSpec`), or leave ``None`` to pick up
    the ambient context — the planner then sizes against the per-shard
    local word schedule, not the global one, and the plan is cache-keyed
    by the mesh topology. Mutually exclusive with explicit
    ``pipe_overrides``.
    """
    if policy is not None:
        if pipe_overrides is not None:
            raise TypeError(f"{program.name}: pass either policy= or "
                            f"pipe_overrides=, not both")
        sh = sharding if sharding is not None else policy.mesh
        mesh, shards = resolve_sharding(sh)
        w = localize_workload(program_workload(program), shards)
        tile = tuple(program.streams[0].spec.tile)
        depth, streams = planner.resolve_policy(
            program.name, policy, workload=w, tile=tile,
            dtype=program.streams[0].spec.dtype, mesh=mesh)
        pipe_overrides = {
            st.name: dataclasses.replace(
                st.spec, depth=depth,
                streams=(st.spec.streams if st.gather else
                         _clamped_streams(st.spec.tile[0], streams)))
            for st in program.streams
        }
        if interpret is None:
            interpret = policy.interpret
    interpret = True if interpret is None else interpret
    scalar_ins = [i for i in program.inputs if isinstance(i, ScalarIn)]
    tensor_ins = [i for i in program.inputs if not isinstance(i, ScalarIn)]
    specs: Dict[str, Pipe] = {s.name: s.spec for s in program.streams}
    for name, pipe in (pipe_overrides or {}).items():
        if name not in specs:
            raise KeyError(f"{program.name}: pipe override for unknown "
                           f"stream {name!r}; streams: {sorted(specs)}")
        old = specs[name]
        if tuple(pipe.tile) != tuple(old.tile) or \
                jnp.dtype(pipe.dtype) != jnp.dtype(old.dtype):
            raise ValueError(
                f"{program.name}: pipe override for {name!r} must keep "
                f"tile/dtype ({old.tile}, {jnp.dtype(old.dtype).name}); "
                f"rebuild the program for a different tile")
        specs[name] = pipe
    rings: Dict[str, RingPipe] = {
        s.name: (GatherRingPipe if s.gather else RingPipe)(specs[s.name])
        for s in program.streams
    }

    def kernel(*refs):
        it = iter(refs)
        named = {i.name: next(it) for i in scalar_ins}
        named.update({i.name: next(it) for i in tensor_ins})
        out = next(it)
        scratch = {s.name: next(it) for s in program.scratch}

        g = pl.program_id(0)
        ctx = ProgramCtx(g, program.n_words, named, {}, out, scratch)
        pctx = ProducerCtx(named)    # slicers never see scratch/out
        pipes = []
        for i in tensor_ins:
            if not isinstance(i, Stream):
                continue
            buf, sems = next(it), next(it)
            if i.gather:
                bound = rings[i.name].bind(
                    buf, sems, lambda word, r, s=i: s.slicer(pctx, word, r))
            else:
                bound = rings[i.name].bind(
                    buf, sems, lambda word, s=i: s.slicer(pctx, word))
            ctx._pipes[i.name] = bound
            pipes.append(bound)

        acquire(g, program.n_words, pipes)
        program.consumer(ctx)
        release(g, program.n_words, pipes)

    in_specs = [
        pl.BlockSpec(memory_space=pl.ANY) if isinstance(i, Stream)
        else pl.BlockSpec(i.block, i.index_map)
        for i in tensor_ins
    ]
    scratch_shapes = [pltpu.VMEM(s.shape, s.dtype) for s in program.scratch]
    for i in tensor_ins:
        if isinstance(i, Stream):
            scratch_shapes.extend(rings[i.name].scratch_shapes)
    out_spec = pl.BlockSpec(program.out_block, program.out_index_map)
    out_shape = jax.ShapeDtypeStruct(program.out_shape, program.out_dtype)

    if scalar_ins:
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=len(scalar_ins),
                grid=(program.n_words,),
                in_specs=in_specs,
                out_specs=out_spec,
                scratch_shapes=scratch_shapes,
            ),
            out_shape=out_shape,
            interpret=interpret,
        )
    return pl.pallas_call(
        kernel,
        grid=(program.n_words,),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )
