"""Serving driver: paged-KV continuous batching vs. padded lockstep.

Two schedulers over the same Poisson request trace:

  * **lockstep** (the baseline this PR replaces): FIFO static batches —
    wait until ``n_slots`` requests have arrived, right-pad prompts into
    one prefill, then decode the whole batch in lockstep over a dense
    right-padded KV cache ``[L, B, S_max, KVH, hd]``. Rows retire at
    EOS / their token budget (and stop emitting), but their cache stays
    allocated and the batch keeps stepping until its *slowest* row
    finishes — the head-of-line blocking and ``B x S_max`` padding waste
    the paged path removes.
  * **paged** (continuous batching): requests are admitted the moment a
    decode slot and enough KV blocks are free, prefill is interleaved
    with decode (per-request, bucketed to power-of-2 prompt lengths so
    traces stay few), every step retires finished slots and recycles
    their blocks (:class:`~repro.runtime.paged_kv.PagedKVCache`). Decode
    attention reads KV through the block table as the fused
    ``paged_decode_attention`` StreamGraph (gather producer →
    online-softmax consumer).

Both replay the same trace on a virtual clock advanced by measured step
wall-times (discrete-event replay: no sleeping, real compute costs), and
both decode greedily with identical math — with ``--impl ff`` the dense
path's KV tile is pinned to the page size (``cfg.decode_block_kv``), so
paged decode is *bitwise-identical* to the contiguous path and the two
schedulers emit token-for-token equal sequences.

Example (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1_5_0p5b --smoke \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ARCH_IDS, get_config, smoke_config
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.runtime import sharding as shlib
from repro.runtime.paged_kv import OutOfBlocks, PagedKVCache


def pad_cache_to(cache, s_from: int, s_max: int, seq_dims):
    """Right-pad the declared sequence axes of a cache pytree.

    ``seq_dims`` names the sequence axis: an int applied to every leaf, or
    a pytree matching ``cache`` whose leaves are an axis index or None
    (None = leaf has no sequence axis, left untouched). Only the declared
    axis is padded — a head/layer dim that happens to equal ``s_from`` is
    never touched.
    """
    if seq_dims is None:
        raise TypeError("pad_cache_to requires seq_dims (an int axis or a "
                        "per-leaf pytree of axes); padding by shape match "
                        "corrupts non-sequence dims that equal s_from")
    if s_from == s_max:
        return cache

    def pad(x, axis):
        if axis is None:
            return x
        if x.shape[axis] != s_from:
            raise ValueError(
                f"cache leaf {x.shape} has {x.shape[axis]} at declared seq "
                f"axis {axis}, expected {s_from}")
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, s_max - s_from)
        return jnp.pad(x, pads)

    if isinstance(seq_dims, int):
        return jax.tree.map(lambda x: pad(x, seq_dims), cache)
    return jax.tree.map(pad, cache, seq_dims)


# ---------------------------------------------------------------------------
# Load generator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float          # seconds on the trace clock
    prompt: np.ndarray      # [len] int32
    max_new: int


def make_requests(n: int, *, prompt_len: int, max_new: int, rate: float,
                  vocab: int, seed: int = 0) -> List[Request]:
    """Poisson arrivals (rate req/s), prompt lengths uniform in
    [4, prompt_len], per-request token budgets uniform in
    [max(1, max_new//2), max_new] (the mixed-length traffic that makes
    lockstep's straggler barrier visible)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n) if rate > 0 else np.zeros(n)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, prompt_len + 1))
        prompt = rng.integers(1, vocab, size=plen).astype(np.int32)
        budget = int(rng.integers(max(1, max_new // 2), max_new + 1))
        reqs.append(Request(i, float(arrivals[i]), prompt, budget))
    return reqs


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _summarize(emits: Dict[int, List[float]], requests: List[Request],
               util_samples: List[float], prefill_s: float, decode_s: float,
               steps: int) -> Dict[str, object]:
    """Per-token latency (first token measured from arrival, later tokens
    from the previous emit), throughput over the whole trace."""
    lat = []
    t_end = 0.0
    total = 0
    for r in requests:
        prev = r.arrival
        for t in emits.get(r.rid, []):
            lat.append(t - prev)
            prev = t
            t_end = max(t_end, t)
            total += 1
    lat_ms = np.array(sorted(lat)) * 1e3
    return {
        "tokens": total,
        "tokens_per_s": total / max(t_end, 1e-9),
        "p50_ms": float(np.percentile(lat_ms, 50)) if total else None,
        "p99_ms": float(np.percentile(lat_ms, 99)) if total else None,
        "kv_util": float(np.mean(util_samples)) if util_samples else None,
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "decode_steps": steps,
    }


# ---------------------------------------------------------------------------
# Scheduler 1: padded lockstep (the baseline)
# ---------------------------------------------------------------------------


def run_lockstep(model, params, cfg, requests: List[Request], *,
                 n_slots: int, page: int, eos_id: Optional[int],
                 policy) -> Dict[str, object]:
    """Static FIFO batches over a dense right-padded cache."""
    prefill = jax.jit(steps_lib.make_prefill_step(model, policy=policy))
    decode = jax.jit(steps_lib.make_decode_step(model, policy=policy))
    p_max = _bucket(max(len(r.prompt) for r in requests))
    total_max = max(len(r.prompt) + r.max_new for r in requests)
    s_max = max(-(-total_max // page) * page, -(-p_max // page) * page)

    # warm the two traces outside the clock
    wtoks = jnp.zeros((n_slots, p_max), jnp.int32)
    _, wcache = prefill(params, {"tokens": wtoks})
    wcache = pad_cache_to(wcache, p_max, s_max, 2)
    jax.block_until_ready(decode(
        params, {"token": jnp.zeros((n_slots,), jnp.int32),
                 "lengths": jnp.zeros((n_slots,), jnp.int32)}, wcache))

    clock = 0.0
    prefill_s = decode_s = 0.0
    steps = 0
    emits: Dict[int, List[float]] = {}
    utils: List[float] = []
    # live telemetry: one enabled check per run, then per-token histogram
    # observes of exactly the quantity _summarize computes post hoc (first
    # token from arrival, later tokens from the previous emit)
    telemetry = obs.enabled()
    hist = (obs.histogram("serve_token_latency_seconds",
                          "per-token emit latency (live)",
                          scheduler="lockstep") if telemetry else None)
    prev_emit: Dict[int, float] = {}
    queue = deque(sorted(requests, key=lambda r: r.arrival))
    while queue:
        batch = [queue.popleft() for _ in range(min(n_slots, len(queue)))]
        # static batching: the batch launches when its LAST request arrives
        clock = max(clock, max(r.arrival for r in batch))
        toks = np.zeros((n_slots, p_max), np.int32)
        lens = np.zeros((n_slots,), np.int32)
        for i, r in enumerate(batch):
            toks[i, :len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)

        t0 = time.perf_counter()
        with obs.span("serve_prefill", scheduler="lockstep",
                      batch=len(batch)):
            _, cache = prefill(params, {"tokens": jnp.asarray(toks)})
            cache = pad_cache_to(cache, p_max, s_max, 2)
            jax.block_until_ready(cache)
        dt = time.perf_counter() - t0
        clock += dt
        prefill_s += dt

        # re-feed each row's last prompt token at position len-1: the cache
        # write is idempotent (same k/v), and the step's logits are exactly
        # the model's next-token prediction at the prompt end
        cur = jnp.asarray(toks[np.arange(n_slots), np.maximum(lens - 1, 0)])
        lengths = jnp.asarray(np.maximum(lens - 1, 0))
        produced = np.zeros(n_slots, np.int64)
        active = np.array([i < len(batch) for i in range(n_slots)])
        # lockstep's cost: the batch steps until its slowest row finishes
        while active.any():
            t0 = time.perf_counter()
            with obs.span("serve_decode_step", scheduler="lockstep"):
                nxt, _, cache = decode(
                    params, {"token": cur, "lengths": lengths}, cache)
                nxt_np = np.asarray(nxt)
            dt = time.perf_counter() - t0
            clock += dt
            decode_s += dt
            steps += 1
            for i in np.nonzero(active)[0]:
                r = batch[i]
                tok = int(nxt_np[i])
                emits.setdefault(r.rid, []).append(clock)
                if telemetry:
                    hist.observe(clock - prev_emit.get(r.rid, r.arrival))
                    prev_emit[r.rid] = clock
                produced[i] += 1
                if tok == eos_id or produced[i] >= r.max_new:
                    active[i] = False      # retired; cache stays allocated
            cur = nxt
            lengths = lengths + 1
            live = sum(lens[i] + produced[i] for i in range(len(batch)))
            utils.append(live / (n_slots * s_max))
    return _summarize(emits, requests, utils, prefill_s, decode_s, steps)


# ---------------------------------------------------------------------------
# Scheduler 2: paged continuous batching
# ---------------------------------------------------------------------------


def run_continuous(model, params, cfg, requests: List[Request], *,
                   n_slots: int, page: int, eos_id: Optional[int],
                   policy, pool_blocks: Optional[int] = None
                   ) -> Dict[str, object]:
    """Continuous batching over a :class:`PagedKVCache`: admit on arrival
    into free slots, retire per step, recycle blocks."""
    prefill = jax.jit(steps_lib.make_prefill_step(model, policy=policy))
    decode = jax.jit(steps_lib.make_decode_step(model, policy=policy))
    n_pages_max = max(-(-(len(r.prompt) + r.max_new) // page)
                      for r in requests)
    if pool_blocks is None:
        pool_blocks = n_slots * n_pages_max
    # a single empty-pool admission must always fit, else admission stalls
    pool_blocks = max(pool_blocks, n_pages_max)

    def fresh_cache():
        return PagedKVCache(
            n_layers=cfg.n_layers, n_blocks=pool_blocks, page=page,
            kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, n_slots=n_slots,
            n_pages_max=n_pages_max, dtype=cfg.cdtype)

    buckets = sorted({_bucket(len(r.prompt)) for r in requests})

    # warm every trace (per-bucket prefill + admission scatter, decode)
    warm = fresh_cache()
    for i, pb in enumerate(buckets):
        _, wc = prefill(params, {"tokens": jnp.zeros((1, pb), jnp.int32)})
        warm.admit(i % n_slots, wc["k"][:, 0], wc["v"][:, 0], 4, 4)
        warm.retire(i % n_slots)
    jax.block_until_ready(decode(
        params, {"token": jnp.zeros((n_slots,), jnp.int32),
                 "lengths": jnp.zeros((n_slots,), jnp.int32)},
        warm.cache_view()))

    kv = fresh_cache()
    clock = 0.0
    prefill_s = decode_s = 0.0
    steps = 0
    emits: Dict[int, List[float]] = {}
    utils: List[float] = []
    utils_pool: List[float] = []
    telemetry = obs.enabled()
    hist = (obs.histogram("serve_token_latency_seconds",
                          "per-token emit latency (live)",
                          scheduler="paged") if telemetry else None)
    kv_gauge = (obs.gauge("serve_kv_utilization",
                          "paged KV pool utilization vs allocated blocks")
                if telemetry else None)
    prev_emit: Dict[int, float] = {}
    pending = deque(sorted(requests, key=lambda r: r.arrival))
    slot_req: List[Optional[Request]] = [None] * n_slots
    cur = np.zeros(n_slots, np.int32)
    produced = np.zeros(n_slots, np.int64)

    def active_mask():
        return np.array([r is not None for r in slot_req])

    while pending or active_mask().any():
        # admit arrived requests into free slots while blocks allow
        while pending and pending[0].arrival <= clock:
            free = [i for i, r in enumerate(slot_req) if r is None]
            if not free:
                break
            r = pending[0]
            need = -(-(len(r.prompt) + r.max_new) // page)
            if need > kv.allocator.n_free:
                break                       # wait for a retirement
            pending.popleft()
            slot = free[0]
            plen = len(r.prompt)
            pb = _bucket(plen)
            toks = np.zeros((1, pb), np.int32)
            toks[0, :plen] = r.prompt
            t0 = time.perf_counter()
            with obs.span("serve_admit", scheduler="paged", rid=r.rid,
                          slot=slot, prompt_len=plen):
                _, pc = prefill(params, {"tokens": jnp.asarray(toks)})
                kv.admit(slot, pc["k"][:, 0], pc["v"][:, 0], plen,
                         plen + r.max_new)
                jax.block_until_ready(kv.pool)
            dt = time.perf_counter() - t0
            clock += dt
            prefill_s += dt
            slot_req[slot] = r
            cur[slot] = int(r.prompt[-1])
            produced[slot] = 0
            # first decode step re-feeds the last prompt token at
            # position plen-1 (idempotent cache write, exact logits)
            kv.lengths[slot] = plen - 1

        act = active_mask()
        if not act.any():
            if pending:
                clock = max(clock, pending[0].arrival)
                continue
            break

        t0 = time.perf_counter()
        with obs.span("serve_decode_step", scheduler="paged"):
            nxt, _, new_caches = decode(
                params, {"token": jnp.asarray(cur),
                         "lengths": jnp.asarray(kv.lengths)},
                kv.cache_view())
            nxt_np = np.asarray(nxt)
        dt = time.perf_counter() - t0
        clock += dt
        decode_s += dt
        steps += 1
        kv.update_pool(new_caches["kv_pool"])
        kv.append(act.astype(np.int32))
        for slot in np.nonzero(act)[0]:
            r = slot_req[slot]
            tok = int(nxt_np[slot])
            emits.setdefault(r.rid, []).append(clock)
            if telemetry:
                hist.observe(clock - prev_emit.get(r.rid, r.arrival))
                prev_emit[r.rid] = clock
            produced[slot] += 1
            if tok == eos_id or produced[slot] >= r.max_new:
                with obs.span("serve_retire", scheduler="paged",
                              rid=r.rid, slot=int(slot)):
                    kv.retire(slot)         # blocks recycle immediately
                slot_req[slot] = None
            else:
                cur[slot] = tok
        u = kv.utilization()
        utils.append(u["util_vs_allocated"])
        utils_pool.append(u["util_vs_pool"])
        if telemetry:
            kv_gauge.set(u["util_vs_allocated"])
    out = _summarize(emits, requests, utils, prefill_s, decode_s, steps)
    out["kv_util_pool"] = (float(np.mean(utils_pool))
                           if utils_pool else None)
    out["pool_blocks"] = pool_blocks
    out["page"] = page
    return out


# ---------------------------------------------------------------------------
# Bitwise parity probe (paged vs. contiguous decode on identical state)
# ---------------------------------------------------------------------------


def decode_parity_probe(model, params, cfg, policy, *, page: int,
                        n_steps: int = 3, seed: int = 0) -> float:
    """Run ``n_steps`` greedy decode steps from the same prefill state
    through (a) the dense right-padded cache and (b) the paged pool, and
    return the max abs logits difference (0.0 = bitwise identical).

    Requires the model's dense ff path to be pinned to the page tile
    (``cfg.decode_block_kv == page``) for ff impls; xla impls match because
    both views present the same ``[B, n_pages*page]`` KV extent.
    """
    rng = np.random.default_rng(seed)
    b = 2
    lens = np.array([11, 24], np.int32)
    p_max = int(lens.max())
    toks = np.zeros((b, p_max), np.int32)
    for i in range(b):
        toks[i, :lens[i]] = rng.integers(1, cfg.vocab, size=lens[i])
    n_pages = -(-(p_max + n_steps) // page)
    s_max = n_pages * page

    prefill = jax.jit(steps_lib.make_prefill_step(model, policy=policy))
    decode = jax.jit(steps_lib.make_decode_step(model, policy=policy))

    _, dense = prefill(params, {"tokens": jnp.asarray(toks)})
    dense_cache = pad_cache_to(dense, p_max, s_max, 2)

    kv = PagedKVCache(
        n_layers=cfg.n_layers, n_blocks=b * n_pages + 2, page=page,
        kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, n_slots=b,
        n_pages_max=n_pages, dtype=cfg.cdtype)
    for i in range(b):
        kv.admit(i, dense["k"][:, i], dense["v"][:, i], int(lens[i]),
                 s_max)

    cur_d = jnp.asarray(toks[np.arange(b), lens - 1])
    cur_p = cur_d
    len_d = jnp.asarray(lens - 1)
    kv.lengths[:] = lens - 1
    max_diff = 0.0
    for _ in range(n_steps):
        nd, logits_d, dense_cache = decode(
            params, {"token": cur_d, "lengths": len_d}, dense_cache)
        np_, logits_p, new_caches = decode(
            params, {"token": cur_p, "lengths": jnp.asarray(kv.lengths)},
            kv.cache_view())
        kv.update_pool(new_caches["kv_pool"])
        kv.append(np.ones(b, np.int32))
        max_diff = max(max_diff, float(np.max(np.abs(
            np.asarray(logits_d) - np.asarray(logits_p)))))
        cur_d, cur_p = nd, np_
        len_d = len_d + 1
    return max_diff


# ---------------------------------------------------------------------------
# Benchmark entry (BENCH_serve.json)
# ---------------------------------------------------------------------------


def serve_bench(args) -> Dict[str, object]:
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("serve driver targets decoder-only archs")
    if args.impl != "cfg":
        cfg = cfg.replace(attn_impl=args.impl)
    if cfg.attn_impl == "ff":
        # pin the dense path's KV tile to the page so lockstep decode is
        # bitwise-identical to the paged stream graph
        cfg = cfg.replace(decode_block_kv=args.page)
    if getattr(args, "layer_graph", False):
        # route dense-cache decode steps through the whole-layer
        # decode_layer StreamGraph (one planned multi-kernel program per
        # layer; the paged scheduler keeps its gather-attention graph)
        cfg = cfg.replace(layer_graph=True)
    from repro.core.program import PipePolicy
    policy = PipePolicy(mode=args.policy_mode, interpret=True)
    from repro.models import build_model
    model = build_model(cfg)
    mesh = make_host_mesh()

    requests = make_requests(
        args.requests, prompt_len=args.prompt_len, max_new=args.max_new,
        rate=args.rate, vocab=cfg.vocab, seed=args.seed)

    # plan-service hooks: --plan-db points the autotune lookup chain at a
    # release PlanDB (pre-warmed here so the first resolution is a dict
    # hit, not file IO); --record-profile captures this run's traffic for
    # an offline sweep (see repro.plans)
    import contextlib

    from repro.core import autotune
    plan_service: Dict[str, object] = {}
    # --metrics-json opts into live telemetry: per-token latency
    # histograms and the kv gauge observe only while obs is enabled
    metrics_path = getattr(args, "metrics_json", None)
    trace_state = None
    if metrics_path and not obs.enabled():
        trace_state = obs.enable()      # in-memory ring, no JSONL sink
    with contextlib.ExitStack() as stack:
        if getattr(args, "plan_db", None):
            from repro.plans import plandb as plandb_lib
            stack.enter_context(autotune.tuning_config(plan_db=args.plan_db))
            plan_service["prewarm"] = plandb_lib.prewarm(args.plan_db)
            print(f"# plan-db {args.plan_db}: "
                  f"{plan_service['prewarm']['records_in_namespace']} "
                  f"records for namespace "
                  f"{plan_service['prewarm']['namespace']}")
        profile = None
        if getattr(args, "record_profile", None):
            from repro.plans import record_traffic
            profile = stack.enter_context(
                record_traffic(args.record_profile))

        with shlib.use_sharding(mesh,
                                overrides=dict(cfg.rule_overrides or {})):
            params = model.init(jax.random.key(0))
            lockstep = run_lockstep(
                model, params, cfg, requests, n_slots=args.slots,
                page=args.page, eos_id=args.eos_id, policy=policy)
            paged = run_continuous(
                model, params, cfg, requests, n_slots=args.slots,
                page=args.page, eos_id=args.eos_id, policy=policy,
                pool_blocks=args.pool_blocks)
            bitwise = decode_parity_probe(model, params, cfg, policy,
                                          page=args.page)
        if profile is not None:
            plan_service["recorded"] = {
                "path": args.record_profile,
                "buckets": len(profile),
                "observations": profile.total_count}
        if getattr(args, "plan_db", None) or profile is not None:
            plan_service["stats"] = autotune.plan_stats_snapshot()

    result = {
        "arch": args.arch,
        "mesh": dict(mesh.shape),
        "smoke": bool(args.smoke),
        "impl": cfg.attn_impl,
        "policy_mode": args.policy_mode,
        "requests": args.requests,
        "slots": args.slots,
        "page": args.page,
        "rate_req_per_s": args.rate,
        "prompt_len": args.prompt_len,
        "max_new": args.max_new,
        "lockstep": lockstep,
        "paged": paged,
        "speedup_tokens_per_s": (paged["tokens_per_s"]
                                 / max(lockstep["tokens_per_s"], 1e-9)),
        "p99_ratio": (lockstep["p99_ms"] / max(paged["p99_ms"], 1e-9)
                      if lockstep["p99_ms"] and paged["p99_ms"] else None),
        "bitwise_max_abs_diff": bitwise,
        "bitwise_identical": bitwise == 0.0,
        "token_count_parity": lockstep["tokens"] == paged["tokens"],
    }
    if plan_service:
        result["plan_service"] = plan_service
        if "recorded" in plan_service:
            rec = plan_service["recorded"]
            print(f"# recorded traffic profile: {rec['buckets']} buckets / "
                  f"{rec['observations']} observations -> {rec['path']}")
    if metrics_path:
        import json
        with open(metrics_path, "w") as f:
            json.dump(obs.metrics_snapshot(), f, indent=2, sort_keys=True)
        result["metrics_json"] = metrics_path
        print(f"# wrote live metrics snapshot -> {metrics_path}")
        if trace_state is not None:
            obs.restore(trace_state)
    return result


def add_serve_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1_5_0p5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page", type=int, default=16,
                    help="KV block (page) size in tokens; also pins the ff "
                         "dense path's block_kv for bitwise parity")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (batch rows) for both schedulers")
    ap.add_argument("--rate", type=float, default=10.0,
                    help="Poisson arrival rate, requests/s (0 = all at t=0)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="retire a slot when it emits this token")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="paged pool size in blocks (default: slots x "
                         "max pages per request)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--impl", choices=("ff", "xla", "cfg"), default="ff",
                    help="attention implementation: ff = repro.ops stream "
                         "kernels (default), xla = HLO reference, cfg = "
                         "whatever the arch config pins")
    ap.add_argument("--layer-graph", action="store_true",
                    help="fuse each dense-cache decode step into the "
                         "whole-layer decode_layer StreamGraph (QKV -> "
                         "attention -> out-proj -> MLP with residual/norm "
                         "epilogues, jointly planned)")
    ap.add_argument("--policy-mode", choices=("ff", "baseline", "autotune"),
                    default="ff",
                    help="session PipePolicy mode installed around the "
                         "prefill/decode step bodies (mesh-tagged)")
    ap.add_argument("--record-profile", default=None, metavar="PATH",
                    help="record every plan resolution into a "
                         "TrafficProfile JSON at PATH (the input of "
                         "`python -m repro.plans sweep`)")
    ap.add_argument("--plan-db", default=None, metavar="PATH",
                    help="release PlanDB consulted after the per-host plan "
                         "cache and before measuring (pre-warmed at "
                         "startup; overrides $REPRO_PLAN_DB)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="enable live telemetry (per-token latency "
                         "histograms, plan-source counters) and write "
                         "obs.metrics_snapshot() to PATH at exit")


def main(argv=None):
    ap = argparse.ArgumentParser()
    add_serve_args(ap)
    ap.add_argument("--json", default=None,
                    help="write the benchmark dict to this path")
    args = ap.parse_args(argv)
    result = serve_bench(args)
    ls, pg = result["lockstep"], result["paged"]
    print(f"impl={result['impl']} policy={args.policy_mode} "
          f"mesh={result['mesh']} "
          f"requests={args.requests} slots={args.slots} page={args.page}")
    for name, m in (("lockstep", ls), ("paged", pg)):
        print(f"{name:9s}: {m['tokens']} tokens, "
              f"{m['tokens_per_s']:.2f} tok/s, "
              f"p50 {m['p50_ms']:.0f} ms, p99 {m['p99_ms']:.0f} ms, "
              f"kv util {m['kv_util']:.2f}, "
              f"decode {m['decode_s']:.1f} s / {m['decode_steps']} steps")
    print(f"speedup x{result['speedup_tokens_per_s']:.2f} tok/s, "
          f"p99 x{result['p99_ratio']:.2f}, "
          f"bitwise diff {result['bitwise_max_abs_diff']:.1e}")
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return result


if __name__ == "__main__":
    main()
