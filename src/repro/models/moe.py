"""Mixture-of-experts FFN (grok-1, deepseek-v2-lite).

Capacity-based top-k routing with scatter dispatch / gather combine:
tokens are placed into a ``[E, C, d]`` dispatch buffer (expert-sharded under
the "expert" rule — EP over the model axis), experts run as one batched
einsum, and results gather back weighted by router probs. Overflow beyond
capacity ``C = ceil(T/E * k * capacity_factor)`` is dropped (standard
token-dropping MoE).

Paper mapping: the dispatch/combine *is* the irregular-gather microbenchmark
at system scale — under EP sharding XLA materializes it as all-to-alls, which
the roofline's collective term picks up (deepseek/grok are the most
collective-bound cells in EXPERIMENTS.md).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.runtime.sharding import constrain, current


def moe_ffn_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    s = {
        "router": L.ParamSpec((d, e), ("embed", None), scale=0.02),
        "w1": L.ParamSpec((e, d, 2 * f), ("expert", "embed", "mlp")),
        "w2": L.ParamSpec((e, f, d), ("expert", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * cfg.moe_d_ff
        s["shared"] = L.mlp_specs(d, fs, "swiglu")
    return s


def _dispatch_indices(gates: jnp.ndarray, top_k: int, capacity: int):
    """gates: [T, E] router probs. Returns (expert_idx [T,k], probs [T,k],
    slot [T,k], keep [T,k]) with capacity-ranked slots per expert."""
    t, e = gates.shape
    probs, idx = jax.lax.top_k(gates, top_k)                    # [T,k]
    probs = probs / (jnp.sum(probs, axis=-1, keepdims=True) + 1e-9)
    count = jnp.zeros((e,), jnp.int32)
    slots = []
    for k in range(top_k):
        oh = jax.nn.one_hot(idx[:, k], e, dtype=jnp.int32)       # [T,E]
        rank = jnp.cumsum(oh, axis=0) - 1                        # [T,E]
        r = jnp.take_along_axis(rank, idx[:, k:k + 1], axis=1)[:, 0]
        slots.append(r + count[idx[:, k]])
        count = count + jnp.sum(oh, axis=0)
    slot = jnp.stack(slots, axis=1)                              # [T,k]
    keep = slot < capacity
    return idx, probs, slot, keep


def _batch_shards() -> int:
    """How many ways the token (batch) dim is sharded under current rules."""
    ctx = current()
    if ctx is None:
        return 1
    target = ctx.rules.get("batch")
    if target is None:
        return 1
    tgt = (target,) if isinstance(target, str) else target
    n = 1
    for a in tgt:
        n *= ctx.axis_size(a)
    return n


def _local_dispatch_apply(cfg: ArchConfig, p, x
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Hierarchical dispatch (§Perf, 'MoE local dispatch'): slot ranks and
    capacity are computed *per data shard*, and the dispatch buffer's
    capacity dim is laid out [E, shards, C_local] with the shard dim aligned
    to the token sharding — the scatter/gather becomes shard-local and the
    only cross-device movement is the expert-parallel all-to-all, instead of
    the global-buffer all-gathers of the naive path."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    shards = _batch_shards()
    if t % shards:
        shards = 1
    tl = t // shards
    xf = x.reshape(t, d)

    gates = jax.nn.softmax(
        (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)), axis=-1)
    probs_k, idx = jax.lax.top_k(gates, k)
    probs_k = probs_k / (jnp.sum(probs_k, axis=-1, keepdims=True) + 1e-9)

    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0)
    aux = e * jnp.sum(me * ce)

    cap_l = int(tl // e * k * cfg.capacity_factor) + 1
    cap_l = -(-cap_l // 8) * 8
    idx_s = idx.reshape(shards, tl, k)
    count = jnp.zeros((shards, e), jnp.int32)
    slots = []
    for kk in range(k):
        oh = jax.nn.one_hot(idx_s[:, :, kk], e, dtype=jnp.int32)  # [D,tl,E]
        rank = jnp.cumsum(oh, axis=1) - 1
        r = jnp.take_along_axis(rank, idx_s[:, :, kk:kk + 1], axis=2)[..., 0]
        base = jnp.take_along_axis(count, idx_s[:, :, kk], axis=1)
        slots.append(r + base)
        count = count + jnp.sum(oh, axis=1)
    slot = jnp.stack(slots, axis=2)                               # [D,tl,k]
    keep = slot < cap_l

    # vmapped shard-local scatter: the buffer is *born* sharded on its
    # leading (data) dim, so the partitioner never materializes a global
    # buffer (the naive path all-gathers the whole [E,C,d] buffer — the
    # 181 GiB/layer pathology in the baseline grok HLO)
    flat_local = idx_s * cap_l + slot                             # [D,tl,k]
    contrib = xf.reshape(shards, tl, 1, d) * keep[..., None].astype(x.dtype)
    contrib = jnp.broadcast_to(contrib, (shards, tl, k, d))
    buf_s = jnp.zeros((shards, e * cap_l, d), x.dtype)
    buf_s = constrain(buf_s, ("batch", None, "embed"))
    buf_s = jax.vmap(
        lambda bb, ix, cc: bb.at[ix.reshape(-1)].add(
            cc.reshape(-1, d), mode="drop"))(buf_s, flat_local, contrib)
    buf = buf_s.reshape(shards, e, cap_l, d).transpose(1, 0, 2, 3) \
        .reshape(e, shards * cap_l, d)
    buf = constrain(buf, ("expert", "exp_cap", "embed"))

    dt = x.dtype
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(dt))
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    y = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(dt))
    y = constrain(y, ("expert", "exp_cap", "embed"))

    y_s = y.reshape(e, shards, cap_l, d).transpose(1, 0, 2, 3) \
        .reshape(shards, e * cap_l, d)
    y_s = constrain(y_s, ("batch", None, "embed"))
    picked = jax.vmap(lambda yy, ix: yy[ix.reshape(-1)])(
        y_s, flat_local).reshape(t, k, d)
    w = (probs_k.reshape(t, k) *
         keep.reshape(t, k).astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("tkd,tk->td", picked, w).reshape(b, s, d)
    if cfg.n_shared_experts:
        out = out + L.mlp_apply(p["shared"], x, "swiglu")
    return out, aux.astype(jnp.float32)


def moe_ffn_apply(cfg: ArchConfig, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B,S,D] -> (out [B,S,D], aux load-balance loss)."""
    if cfg.moe_local_dispatch:
        return _local_dispatch_apply(cfg, p, x)
    b, s, d = x.shape
    e, k, f = cfg.n_experts, cfg.top_k, cfg.moe_d_ff
    t = b * s
    xf = x.reshape(t, d)
    capacity = int(t // e * k * cfg.capacity_factor) + 1
    # round capacity so the buffer's capacity dim stays mesh-divisible
    gran = 2048 if t >= (1 << 17) else 8
    capacity = -(-capacity // gran) * gran

    gates = jax.nn.softmax(
        (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)), axis=-1)
    idx, probs, slot, keep = _dispatch_indices(gates, k, capacity)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(gates, axis=0)                                  # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0)
    aux = e * jnp.sum(me * ce)

    # scatter tokens into the expert-sharded dispatch buffer
    flat_idx = (idx * capacity + slot)                            # [T,k]
    buf = jnp.zeros((e * capacity, d), x.dtype)
    contrib = xf[:, None, :] * keep[:, :, None].astype(x.dtype)   # [T,k,D]
    buf = buf.at[flat_idx.reshape(-1)].add(
        contrib.reshape(t * k, d), mode="drop")
    # "exp_cap" shards the capacity dim when experts themselves cannot be
    # sharded (grok: 8 experts vs 16-way model axis)
    buf = constrain(buf.reshape(e, capacity, d), ("expert", "exp_cap", "embed"))

    # batched expert FFN (swiglu)
    dt = x.dtype
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(dt))
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    y = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(dt))
    y = constrain(y, ("expert", "exp_cap", "embed"))

    # gather/combine
    flat_y = y.reshape(e * capacity, d)
    picked = flat_y[flat_idx.reshape(-1)].reshape(t, k, d)
    w = (probs * keep.astype(jnp.float32)).astype(x.dtype)        # [T,k]
    out = jnp.einsum("tkd,tk->td", picked, w).reshape(b, s, d)

    if cfg.n_shared_experts:
        out = out + L.mlp_apply(p["shared"], x, "swiglu")
    return out, aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# StreamGraph workload: dispatch → expert-matmul → combine
# ---------------------------------------------------------------------------
#
# The kernel-level core of the MoE layer above, as a registered multi-kernel
# pipe graph (repro.core.graph): `dispatch` gathers the routed token rows
# (the paper's irregular-access pattern, ff_gather), `expert` is the regular
# expert FFN matmul over the dispatched buffer, `combine` gathers the expert
# outputs back into token order (the un-permute; routing-prob weighting
# stays in XLA where the layer applies it). The dispatch→expert edge is the
# showcase fusion: the gather's 8·streams-row bundles are exactly the
# matmul's A tiles, so the dispatched buffer never touches HBM — while
# expert→combine ends at an irregular gather stream (data-dependent
# addresses) and stages through HBM by construction, demonstrating the
# per-edge decision.


def build_moe_graph(*, t_tokens: int = 96, n_dispatch: int = 64,
                    d_model: int = 128, d_ff: int = 256, t_out: int = 64,
                    dtype=jnp.float32, depth: int = 2, streams: int = 1,
                    bn: int = 128):
    """Declare the MoE dispatch→expert-matmul→combine StreamGraph.

    ``n_dispatch`` (dispatched rows) and ``t_out`` (combined rows) must be
    multiples of the gather row bundle ``8 * streams``; the expert matmul's
    M tile is pinned to that bundle so the dispatch→expert edge is fusable
    by construction. ``bn`` is the expert matmul's N tile (the joint
    tuner's shared-tile axis).
    """
    from repro.core.graph import GraphEdge, GraphNode, StreamGraph
    from repro.kernels.ff_gather.kernel import _ROWS
    from repro.kernels.ff_gather.kernel import build_program as gather_prog
    from repro.kernels.ff_gather.ops import gather_workload
    from repro.kernels.ff_matmul.kernel import build_program as matmul_prog
    from repro.kernels.ff_matmul.ops import matmul_workload

    rpw = _ROWS * streams
    if n_dispatch % rpw or t_out % rpw:
        raise ValueError(f"n_dispatch={n_dispatch} / t_out={t_out} must be "
                         f"multiples of the {rpw}-row gather bundle")
    block = (rpw, min(bn, d_ff), d_model)
    dispatch = gather_prog(n_dispatch, d_model, dtype=dtype, depth=depth,
                           streams=streams)
    expert = matmul_prog(n_dispatch, d_ff, d_model, block=block, dtype=dtype,
                         depth=depth, streams=streams)
    combine = gather_prog(t_out, d_ff, dtype=dtype, depth=depth,
                          streams=streams)
    w_d, t_d = gather_workload(n_dispatch, d_model, dtype=dtype)
    w_e, t_e = matmul_workload(n_dispatch, d_ff, d_model, block, dtype)
    w_c, t_c = gather_workload(t_out, d_ff, dtype=dtype)
    return StreamGraph(
        name="moe_dispatch_ffn",
        nodes=(
            GraphNode("dispatch", dispatch, workload=w_d, plan_tile=t_d),
            GraphNode("expert", expert, workload=w_e, plan_tile=t_e),
            GraphNode("combine", combine, workload=w_c, plan_tile=t_c),
        ),
        edges=(
            GraphEdge("dispatch", "expert", "a"),
            GraphEdge("expert", "combine", "table"),
        ),
    )


def _moe_graph_inputs(key):
    """Operands in CompiledGraph.arg_names order:
    (dispatch.idx, dispatch.table, expert.b, combine.idx)."""
    # d_ff = 2 N tiles: the expert matmul re-reads each dispatched A tile
    # once per N tile, so the fused ring saves the re-streams too
    t, n, d, f, t_out = 96, 64, 128, 256, 64
    tokens = jax.random.normal(key, (t, d), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, t,
                             dtype=jnp.int32)
    w1 = jax.random.normal(jax.random.fold_in(key, 2), (d, f),
                           jnp.float32) / jnp.sqrt(d)
    comb = jax.random.randint(jax.random.fold_in(key, 3), (t_out,), 0, n,
                              dtype=jnp.int32)
    return (idx, tokens, w1, comb)


def _moe_graph_ref(idx, tokens, w1, comb):
    return (tokens[idx] @ w1)[comb]


def _moe_graph_unfused(idx, tokens, w1, comb):
    """The same computation as three separate repro.ops calls — every
    intermediate round-trips HBM (the BENCH_graph baseline). The expert
    matmul is pinned to the graph's 8-row tile so the comparison isolates
    the lowering (calls + HBM handoffs), not the tiling."""
    import repro

    h = repro.ops.gather(tokens, idx)
    y = repro.ops.matmul(h, w1, block=(8, 128, 128))
    return repro.ops.gather(y, comb)


def moe_dispatch_ffn(idx, tokens, w1, comb, *, policy=None) -> jnp.ndarray:
    """Dispatch→expert-matmul→combine through the fused StreamGraph, at the
    caller's shapes.

    idx: [n_dispatch] int32 rows into ``tokens``; tokens: [T, d_model];
    w1: [d_model, d_ff]; comb: [t_out] int32 rows into the expert output.
    Returns [t_out, d_ff] = ``(tokens[idx] @ w1)[comb]``.

    Unlike ``run_graph`` (fixed smoke shapes), this entrypoint resolves the
    joint graph plan at the call site's shapes and records the site for the
    plan-service sweep — mirroring ``paged_decode_attention``.
    """
    from repro.core import autotune
    from repro.core import graph as graphlib
    from repro.core.program import current_policy

    policy = current_policy() if policy is None else policy
    if policy.mode == "ref":
        return _moe_graph_ref(idx, tokens, w1, comb)
    n = idx.shape[0]
    t_tokens, d_model = tokens.shape
    d_ff = w1.shape[1]
    t_out = comb.shape[0]

    def build(depth=2, streams=1, **tk):
        return build_moe_graph(
            t_tokens=t_tokens, n_dispatch=n, d_model=d_model, d_ff=d_ff,
            t_out=t_out, dtype=tokens.dtype, depth=depth, streams=streams,
            **tk)

    g0 = build()
    w, tile = graphlib.graph_workload(g0)
    sig = graphlib.graph_signature(g0)

    def runner(tk, depth, streams):
        cg = graphlib.compile_graph(
            build(depth=depth, streams=streams, **dict(tk)),
            policy=policy.replace(mode="ff", depth=depth, streams=streams))
        return lambda: cg(idx, tokens, w1, comb)

    choice = autotune.resolve_graph(
        "moe_dispatch_ffn", policy, workload=w, tile=tile,
        dtype=tokens.dtype, signature=sig,
        workload_fn=lambda tk: graphlib.graph_workload(build(**dict(tk))),
        runner=None if autotune.has_tracers(idx, tokens, w1, comb)
        else runner,
        site={"t_tokens": t_tokens, "n_dispatch": n, "d_model": d_model,
              "d_ff": d_ff, "t_out": t_out},
        site_dynamic=("t_tokens", "n_dispatch", "t_out"),
        tile_options=({"bn": 64},))
    # compiled fresh per call (trace-scoped closures must not be reused)
    mode = "ff" if policy.mode == "autotune" else policy.mode
    cg = graphlib.compile_graph(
        build(depth=choice.depth, streams=choice.streams,
              **dict(choice.tile_kwargs)),
        policy=policy.replace(mode=mode, depth=choice.depth,
                              streams=choice.streams))
    return cg(idx, tokens, w1, comb)


def _moe_sweep_inputs(key, site):
    """Rebuild moe_dispatch_ffn operands at a recorded call-site shape
    (plan sweep)."""
    t = int(site.get("t_tokens", 96))
    n, d = int(site["n_dispatch"]), int(site["d_model"])
    f, t_out = int(site["d_ff"]), int(site["t_out"])
    dt = jnp.dtype(site.get("dtype", "float32"))
    tokens = jax.random.normal(key, (t, d), dt)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, t,
                             dtype=jnp.int32)
    w1 = jax.random.normal(jax.random.fold_in(key, 2), (d, f),
                           dt) / jnp.sqrt(d).astype(dt)
    comb = jax.random.randint(jax.random.fold_in(key, 3), (t_out,), 0, n,
                              dtype=jnp.int32)
    return (idx, tokens, w1, comb), {}


def _register_moe_graph():
    from repro.kernels.registry import register_graph

    register_graph(
        name="moe_dispatch_ffn",
        build=build_moe_graph,
        make_inputs=_moe_graph_inputs,
        ref=_moe_graph_ref,
        unfused=_moe_graph_unfused,
        tile_options=({"bn": 64},),
        tol=5e-4,
        doc="MoE dispatch (irregular gather) -> expert matmul -> combine; "
            "dispatch->expert fuses, expert->combine stages (gather edge)",
        # plan-service sweep: resolve at call-site shapes through the real
        # entrypoint, not run_graph's fixed smoke point
        op=moe_dispatch_ffn,
        sweep_inputs=_moe_sweep_inputs,
    )


_register_moe_graph()
