"""Shared decoupled-access/execute (DAE) machinery for the Pallas kernels.

Every ``ff_*`` kernel realizes the paper's memory-kernel/compute-kernel split
inside one Pallas program:

* the *memory kernel* is the set of ``start()`` calls issuing async HBM->VMEM
  copies up to ``depth-1`` words ahead of the consumer (the pipe's lookahead);
* the *pipe* is a VMEM ring buffer of ``depth`` slots with one DMA semaphore
  per (slot, stream);
* the *compute kernel* is the body that ``wait()``s on a slot and feeds the
  MXU/VPU from it.

``streams > 1`` implements the paper's multi-producer design (M2C2): each
word's copy is split into ``streams`` disjoint row ranges issued as separate
DMAs with separate semaphores — the TPU analogue of two memory kernels with
static index-parity load balancing.

The helpers are deliberately thin: kernels stay explicit about their word
schedule (what the paper calls the "feed-forward data path"), and the helpers
only own slot/semaphore bookkeeping.
"""

from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pipe import Pipe


def ring_scratch(pipe: Pipe):
    """Scratch shapes for one pipe: (ring VMEM buffer, DMA semaphore array)."""
    return (
        pltpu.VMEM(pipe.buffer_shape, pipe.dtype),
        pltpu.SemaphoreType.DMA((pipe.depth, pipe.streams)),
    )


class RingPipe:
    """In-kernel view of one pipe (ring buffer + semaphores).

    ``src_slicer(word) -> ref-slice`` names the HBM region of word ``word``
    — this is the *memory kernel*'s address stream, and by construction it
    can depend only on the word index (and scalar-prefetch values), never on
    consumer state: the feed-forward restriction, enforced structurally.
    """

    def __init__(self, buf, sems, pipe: Pipe,
                 src_slicer: Callable[[int], "pl.Ref"]):
        self.buf = buf
        self.sems = sems
        self.pipe = pipe
        self.src_slicer = src_slicer

    def _stream_rows(self, s: int) -> Tuple[int, int]:
        rows = self.pipe.tile[0] // self.pipe.streams
        return s * rows, rows

    def start(self, word) -> None:
        """Producer: issue the (possibly multi-stream) copy for ``word``."""
        slot = word % self.pipe.depth
        src = self.src_slicer(word)
        for s in range(self.pipe.streams):
            lo, rows = self._stream_rows(s)
            pltpu.make_async_copy(
                src.at[pl.ds(lo, rows)],
                self.buf.at[slot, pl.ds(lo, rows)],
                self.sems.at[slot, s],
            ).start()

    def wait(self, word) -> None:
        """Consumer: block until ``word``'s copy landed (paper: blocking read)."""
        slot = word % self.pipe.depth
        src = self.src_slicer(word)
        for s in range(self.pipe.streams):
            lo, rows = self._stream_rows(s)
            pltpu.make_async_copy(
                src.at[pl.ds(lo, rows)],
                self.buf.at[slot, pl.ds(lo, rows)],
                self.sems.at[slot, s],
            ).wait()

    def word_ref(self, word):
        """VMEM ref of the landed word (the pipe read endpoint)."""
        return self.buf.at[word % self.pipe.depth]


def dae_acquire(g, n_words: int, pipes: Sequence[RingPipe], depth: int):
    """DAE word schedule, acquire phase, at grid step ``g`` of ``n_words``.

    Warmup at g==0 fills the ring (lookahead of ``depth`` words), then blocks
    until word ``g`` has landed. Call :meth:`RingPipe.word_ref` for the slot,
    run the compute, then call :func:`dae_release` — releasing *before* the
    compute would let the refill DMA clobber the slot being consumed (the
    pipe's read endpoint is only freed once the consumer has read the word,
    exactly the paper's blocking-read semantics).

    With depth==1 this degenerates to synchronous copy-then-compute — the
    "single work-item baseline" mode used by the benchmark tables.
    """
    if depth == 1:
        for p in pipes:
            p.start(g)
            p.wait(g)
        return

    @pl.when(g == 0)
    def _():
        for d in range(depth):
            @pl.when(d < n_words)
            def _(d=d):
                for p in pipes:
                    p.start(d)

    for p in pipes:
        p.wait(g)


def dae_release(g, n_words: int, pipes: Sequence[RingPipe], depth: int):
    """DAE release phase: word ``g`` consumed; refill its slot with g+depth."""
    if depth == 1:
        return

    @pl.when(g + depth < n_words)
    def _():
        for p in pipes:
            p.start(g + depth)


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def pad_to(x: jnp.ndarray, multiple: int, axis: int) -> jnp.ndarray:
    """Zero-pad ``axis`` of x up to a multiple (TPU tile alignment)."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)
