"""Pure-jnp oracle for ff_matmul."""

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)
