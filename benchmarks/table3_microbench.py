"""Paper Table 3: auto-generated microbenchmarks — access-pattern
(regular/irregular) x divergence/DLCD — M2C2 vs single work-item baseline,
plus an interpret-mode correctness pass of the actual generated kernels
(ff_matmul for regular, ff_gather for irregular) against their oracles."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ARRIA_CX, Pipe, estimate_baseline, estimate_feedforward
from benchmarks.workloads import MICRO


def model_rows():
    out = []
    for name, b in MICRO.items():
        base = estimate_baseline(b.workload, ARRIA_CX)
        m2c2 = estimate_feedforward(b.workload, ARRIA_CX,
                                    Pipe(tile=(8, 128), depth=8, streams=2))
        out.append({
            "name": name,
            "us_per_call": m2c2.total_s * 1e6 / b.workload.n_words,
            "speedup": base.total_s / m2c2.total_s,
            "paper": b.paper_speedup,
            "bottleneck": m2c2.bottleneck,
        })
    return out


def kernel_validation():
    """Generated-kernel correctness (interpret mode) + wall time."""
    from repro.kernels.ff_matmul import matmul, matmul_ref
    from repro.kernels.ff_gather import gather, gather_ref
    k = jax.random.key(0)
    a = jax.random.normal(k, (256, 256))
    b = jax.random.normal(jax.random.fold_in(k, 1), (256, 256))
    t0 = time.time()
    out = matmul(a, b, mode="ff", depth=2, streams=2)
    t_reg = time.time() - t0
    ok_reg = bool(np.allclose(out, matmul_ref(a, b), atol=1e-4))
    tab = jax.random.normal(jax.random.fold_in(k, 2), (512, 128))
    idx = jax.random.randint(jax.random.fold_in(k, 3), (256,), 0, 512)
    t0 = time.time()
    g = gather(tab, idx, mode="ff", depth=4)
    t_irr = time.time() - t0
    ok_irr = bool(np.array_equal(np.asarray(g), np.asarray(gather_ref(tab, idx))))
    return ok_reg, ok_irr, t_reg, t_irr


def main():
    print("# Table 3 analogue: microbenchmarks (M2C2 vs baseline)")
    print("name,us_per_call,derived")
    for r in model_rows():
        print(f"table3/{r['name']},{r['us_per_call']:.3f},"
              f"m2c2={r['speedup']:.2f}x_paper={r['paper']:.2f}x")
    rs = {r["name"]: r for r in model_rows()}
    assert rs["M_AI10_R"]["speedup"] > rs["M_AI10_IR"]["speedup"], \
        "regular must gain more than irregular (paper Table 3)"
    assert rs["M_AI6_forif_R"]["speedup"] > rs["M_AI10_R"]["speedup"], \
        "divergent/DLCD kernels must gain more (paper Table 3)"
    ok_reg, ok_irr, t_reg, t_irr = kernel_validation()
    print(f"# generated-kernel validation: regular(ff_matmul)={ok_reg} "
          f"({t_reg*1e3:.0f} ms interp), irregular(ff_gather)={ok_irr} "
          f"({t_irr*1e3:.0f} ms interp)")
    assert ok_reg and ok_irr


if __name__ == "__main__":
    main()
