"""Chaos harness: fault injection against the resilience + plan stack.

Each scenario is an orchestrated subprocess experiment (the injected
fault kills, signals, or degrades a *real* training process built on the
StreamProgram/autotune stack) with a machine-checkable outcome:

* ``kill-restart`` — SIGKILL mid-run (uncatchable, between checkpoints).
  The restart runs with a **cold plan cache** and must (a) resume from
  the newest checkpoint, (b) pre-warm the tuned-plan chain from the
  checkpoint's plan snapshot — zero re-measurements, every call site a
  memory hit — and (c) finish with a final state bitwise identical to an
  uninterrupted control run.
* ``sigterm-drain`` — preemption notice landing exactly on a
  ``ckpt_every`` boundary: the supervisor drains the step, saves exactly
  once (no double checkpoint), exits 0; resuming completes bitwise
  identically to the control run.
* ``evict-remesh`` — a 2-pod job loses a pod. ``replace_host`` (the
  watchdog's "replace" action, end to end) must restore shard-exact
  state onto the survivable mesh, drop every stale-mesh plan, and serve
  the first post-remesh call site from the swept PlanDB for the *new*
  topology — never the 2-pod plan, and without re-measuring.
* ``slow-host`` — an injected straggler trips the MAD outlier model;
  the watchdog's "rebalance" action shrinks the slow host's data share
  via :class:`~repro.runtime.stragglers.BatchRebalancer` and re-plans
  its local pipes through ``shard_streams`` at the shrunk shard shape.

``run_scenarios`` drives all four and returns the metrics dict that
``benchmarks/run.py --chaos`` writes to ``BENCH_chaos.json`` (recovery
seconds, bitwise flags, plan-stat breakdowns), gating CI on ``ok``.

Workers run as ``python -m repro.runtime.chaos <scenario> ...`` so the
orchestrator controls their device topology (``XLA_FLAGS``) and plan
caches per process — the restart legitimately starts cold.
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time
from typing import Any, Dict, List, Optional

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))

# one matmul call site: (DIM, DIM) @ (DIM, DIM), a single 128^3 tile
DIM = 128

# generous wall bound for "restart -> first productive step" (includes
# process start + jax import + restore + prewarm; interpret-mode CPU)
RECOVERY_BOUND_S = 300.0


def _write_report(path: Optional[str], report: Dict[str, Any]) -> None:
    print("REPORT " + json.dumps(report, sort_keys=True), flush=True)
    if path:
        with open(path, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")


# ---------------------------------------------------------------------------
# Workers (run in subprocesses; heavy imports stay function-local)
# ---------------------------------------------------------------------------


def _worker_train(args) -> None:
    """Deterministic supervised loop on the autotuned matmul kernel.

    State evolves as ``w <- 0.99*w + 0.01*tanh(x_step @ w)`` with
    ``x_step`` derived from the step index — pure function of (step,
    state), so a killed-and-resumed run is bitwise identical to an
    uninterrupted one. ``--kill-at`` SIGKILLs after that step completes
    (before its boundary checkpoint); ``--sigterm-at`` delivers a real
    SIGTERM the supervisor must drain."""
    import hashlib

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.ops
    from repro.core import PipePolicy, autotune
    from repro.runtime.fault_tolerance import FTConfig, Supervisor

    t_start = time.perf_counter()
    pol = PipePolicy(mode="autotune", interpret=True)
    with autotune.tuning_config(cache_path=args.plan_cache, warmup=0,
                                iters=1, top_k=2):
        cfg = FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       keep_last=8)
        like = {"w": np.zeros((DIM, DIM), np.float32)}
        with Supervisor(cfg, like) as sup:
            t0 = time.perf_counter()
            state, start = sup.resume()
            resume_s = time.perf_counter() - t0
            autotune.plan_stats_clear()     # count post-resume resolutions

            def step_fn(state, step):
                x = jax.random.normal(jax.random.key(step), (DIM, DIM),
                                      jnp.float32)
                y = repro.ops.matmul(x, jnp.asarray(state["w"]), policy=pol)
                w = 0.99 * jnp.asarray(state["w"]) + 0.01 * jnp.tanh(y)
                return {"w": np.asarray(w)}

            progress = {"step": start, "first_step_s": None}

            def on_step(step, _state):
                if progress["first_step_s"] is None:
                    progress["first_step_s"] = time.perf_counter() - t_start
                progress["step"] = step
                print(f"step {step}", flush=True)
                if args.kill_at is not None and step == args.kill_at:
                    os.kill(os.getpid(), signal.SIGKILL)
                if args.sigterm_at is not None and step == args.sigterm_at:
                    os.kill(os.getpid(), signal.SIGTERM)

            state = sup.run(state, start, args.steps, step_fn,
                            on_step=on_step)
            report = {
                "scenario": "train",
                "resumed_from": start,
                "final_step": progress["step"],
                "preempted": sup.preempted,
                "save_count": sup.save_count,
                "prewarmed": sup.resume_prewarmed,
                "plan_stats": autotune.plan_stats_snapshot(),
                "resume_s": resume_s,
                "first_step_s": progress["first_step_s"],
                "total_s": time.perf_counter() - t_start,
                "state_sha256": hashlib.sha256(
                    np.ascontiguousarray(state["w"]).tobytes()).hexdigest(),
            }
    _write_report(args.report, report)


def _worker_remesh(args) -> None:
    """2-pod job loses a pod; replace_host must be plan-correct.

    A PlanDB is swept for the *surviving* topology up front (the release
    artifact a fleet would ship), the job tunes and checkpoints under
    the 2-pod mesh, then half the devices "fail". Asserts: shard-exact
    state on the new mesh, stale-mesh planner/autotune entries dropped,
    and the first post-remesh call site served from the PlanDB (not the
    stale plan, not a re-measurement)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.ops
    from repro.checkpoint import save
    from repro.core import PipePolicy, autotune, planner
    from repro.core.meshspec import MeshSpec
    from repro.plans import PlanDB
    from repro.plans.registry import plan_namespace
    from repro.runtime import sharding as shlib
    from repro.runtime.elastic import last_remesh, replace_host, \
        survivable_mesh

    base = args.dir
    host_cache = os.path.join(base, "host_cache.json")
    sweep_cache = os.path.join(base, "sweep_cache.json")
    db_path = os.path.join(base, "plandb.json")
    ckpt = os.path.join(base, "ckpt")

    old_spec = MeshSpec((("pod", 2), ("data", 2), ("model", 2)))
    new_spec = MeshSpec((("data", 2), ("model", 2)))
    a = jax.random.normal(jax.random.key(1), (DIM, DIM), jnp.float32)
    b = jax.random.normal(jax.random.key(2), (DIM, DIM), jnp.float32)

    def pol(spec):
        return PipePolicy(mode="autotune", interpret=True, mesh=spec)

    # offline sweep for the topology we will *fail over to* -> PlanDB
    with autotune.tuning_config(cache_path=sweep_cache, warmup=0, iters=1,
                                top_k=2):
        repro.ops.matmul(a, b, policy=pol(new_spec))
        db = PlanDB()
        ns = plan_namespace()
        for key, rec in autotune.load_plans(sweep_cache).items():
            db.put(ns, key, rec)
        db.save(db_path)
    autotune.tuned_cache_clear()

    with autotune.tuning_config(cache_path=host_cache, warmup=0, iters=1,
                                top_k=2, plan_db=db_path):
        # phase 1: healthy 2-pod job — tune + checkpoint
        old_mesh = survivable_mesh(jax.devices(), model_axis=2, pod_axis=2)
        params = {"w": np.asarray(jax.random.normal(
            jax.random.key(0), (2 * DIM, DIM), jnp.float32))}
        with shlib.use_sharding(old_mesh):
            save(ckpt, 3, params)
            repro.ops.matmul(a, b, policy=pol(old_spec))
        assert planner.last_plan("ff_matmul").mesh == old_spec

        # pod loss -> the watchdog's "replace" action, end to end
        autotune.plan_stats_clear()
        t_fail = time.perf_counter()
        like = {"w": jax.ShapeDtypeStruct((2 * DIM, DIM), jnp.float32)}
        axes = {"w": ("batch", None)}
        state, step, new_mesh = replace_host(
            ckpt, like, axes, jax.devices()[:4], model_axis=2,
            plan_db=db_path)
        rep = last_remesh()
        assert step == 3, step
        assert rep.mesh == new_spec, rep
        assert rep.planner_dropped >= 1, rep
        assert rep.autotune_dropped >= 1, rep
        assert rep.plan_db_records >= 1, rep
        np.testing.assert_array_equal(np.asarray(state["w"]), params["w"])

        # first call site under the new topology: swept plan, never the
        # stale 2-pod plan, no measurement
        with shlib.use_sharding(new_mesh):
            repro.ops.matmul(a, b, policy=pol(new_spec))
        recovery_s = time.perf_counter() - t_fail
        rec = autotune.last_record("ff_matmul")
        assert rec is not None and rec.get("mesh") == new_spec.token, rec
        assert rec.get("source") == "plandb", rec
        # the stale 2-pod plan is gone from the planner cache entirely
        stale = planner.last_plan("ff_matmul")
        assert stale is None or stale.mesh != old_spec, stale
        stats = autotune.plan_stats_snapshot()
        assert stats.get("plandb", 0) >= 1, stats
        assert stats.get("measured", 0) == 0, stats

    _write_report(args.report, {
        "scenario": "remesh",
        "ok": True,
        "old_mesh": old_spec.token,
        "new_mesh": rep.mesh.token,
        "planner_dropped": rep.planner_dropped,
        "autotune_dropped": rep.autotune_dropped,
        "plan_db_records": rep.plan_db_records,
        "post_remesh_source": rec.get("source"),
        "post_remesh_mesh": rec.get("mesh"),
        "post_remesh_stats": stats,
        "recovery_s": recovery_s,
    })


def _worker_slowhost(args) -> None:
    """Injected straggler -> MAD detection -> rebalance -> re-plan.

    Two hosts share a data batch; host h1 turns 2x slow with realistic
    per-step jitter (so the MAD path, not the degenerate slow_factor
    fallback, does the detecting). The watchdog's rebalance must shrink
    h1's share and the hook re-plans the local pipes through
    ``shard_streams`` — asserted via the planner's last_plan workload
    shrinking under the mesh-tagged key."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    import repro.ops
    from repro.core import planner
    from repro.runtime import sharding as shlib
    from repro.runtime.streams import shard_streams
    from repro.runtime.stragglers import (BatchRebalancer, StragglerConfig,
                                          StragglerWatchdog)

    mesh = Mesh(np.asarray(jax.devices()[:2]), ("data",))
    b = jax.random.normal(jax.random.key(2), (DIM, DIM), jnp.float32)

    def plan_local():
        # re-plan the local pipes at the current global share total:
        # shard_streams plans inside shard_map, i.e. at shard-local shape
        m_global = rb.total() * DIM
        a = jnp.zeros((m_global, DIM), jnp.float32)
        with shlib.use_sharding(mesh):
            f = shard_streams(repro.ops.matmul,
                              in_specs=(P("data"), P(None, None)),
                              out_specs=P("data"))
            f(a, b)
        plan = planner.last_plan("ff_matmul")
        return {"mesh": plan.mesh.token, "n_words": plan.workload.n_words}

    def replan(host, share):
        out = plan_local()
        out.update(host=host, share=share)
        return out

    rb = BatchRebalancer({"h0": 4, "h1": 4}, replan=replan)
    before = plan_local()
    cfg = StragglerConfig(window=16, tolerate=3, evict_after=64,
                          slow_factor=1.5, mad_factor=5.0)
    wd = StragglerWatchdog(cfg, hosts=["h0", "h1"], rebalancer=rb)

    slow_from, strikes_seen = 3, 0
    for i in range(10):
        jitter = 0.005 * ((i * 7) % 5 - 2)      # MAD > 0: realistic noise
        t0 = 1.0 + jitter
        t1 = 2.0 + jitter if i >= slow_from else t0
        acts = wd.observe_step({"h0": t0, "h1": t1})
        strikes_seen += int(acts.get("h1") != "none")
        wd.mitigate(acts)

    thr = wd._threshold()
    med = 1.0
    assert thr < cfg.slow_factor * med, (thr, "MAD path not taken")
    assert any(m["action"] == "rebalance" for m in wd.mitigations), \
        wd.mitigations
    after = rb.last_replan["h1"]
    assert rb.shares["h1"] < 4, rb.shares
    assert after["mesh"] == "data2", after
    assert after["n_words"] < before["n_words"], (before, after)

    _write_report(args.report, {
        "scenario": "slowhost",
        "ok": True,
        "threshold": thr,
        "mad_path": thr < cfg.slow_factor * med,
        "share_before": 4,
        "share_after": rb.shares["h1"],
        "n_words_before": before["n_words"],
        "n_words_after": after["n_words"],
        "replan_mesh": after["mesh"],
        "mitigations": wd.mitigations,
    })


# ---------------------------------------------------------------------------
# Orchestration (runs in the parent process; jax-free)
# ---------------------------------------------------------------------------


def _worker_env(n_dev: Optional[int] = None) -> Dict[str, str]:
    env = {**os.environ, "JAX_PLATFORMS": "cpu"}
    env["PYTHONPATH"] = _SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    if n_dev:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n_dev}"
    return env


def _run_worker(cmd_args: List[str], *, n_dev: Optional[int] = None,
                timeout: int = 600):
    cmd = [sys.executable, "-m", "repro.runtime.chaos"] + cmd_args
    t0 = time.perf_counter()
    r = subprocess.run(cmd, env=_worker_env(n_dev), capture_output=True,
                       text=True, timeout=timeout)
    return r, time.perf_counter() - t0


def _load_report(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


def _train_args(ckpt: str, cache: str, report: str, *, steps: int,
                ckpt_every: int, kill_at: Optional[int] = None,
                sigterm_at: Optional[int] = None) -> List[str]:
    out = ["train", "--ckpt-dir", ckpt, "--plan-cache", cache,
           "--report", report, "--steps", str(steps),
           "--ckpt-every", str(ckpt_every)]
    if kill_at is not None:
        out += ["--kill-at", str(kill_at)]
    if sigterm_at is not None:
        out += ["--sigterm-at", str(sigterm_at)]
    return out


def scenario_kill_restart(workdir: str, *, steps: int = 10, kill_at: int = 7,
                          ckpt_every: int = 3,
                          timeout: int = 600) -> Dict[str, Any]:
    """SIGKILL mid-run; cold-cache restart must be bitwise + pre-warmed."""
    base = os.path.join(workdir, "kill")
    os.makedirs(base, exist_ok=True)
    ckpt = os.path.join(base, "ckpt")
    reports = {k: os.path.join(base, f"report_{k}.json") for k in "abc"}

    rA, _ = _run_worker(_train_args(
        ckpt, os.path.join(base, "cache_a.json"), reports["a"],
        steps=steps, ckpt_every=ckpt_every, kill_at=kill_at),
        timeout=timeout)
    killed = rA.returncode == -signal.SIGKILL

    # restart with a COLD plan cache: the checkpoint snapshot is the only
    # warm source — measured must stay 0
    rB, wall_b = _run_worker(_train_args(
        ckpt, os.path.join(base, "cache_b.json"), reports["b"],
        steps=steps, ckpt_every=ckpt_every), timeout=timeout)
    # uninterrupted control run (own checkpoint dir + cache)
    rC, _ = _run_worker(_train_args(
        os.path.join(base, "ckpt_control"),
        os.path.join(base, "cache_c.json"), reports["c"],
        steps=steps, ckpt_every=ckpt_every), timeout=timeout)

    out: Dict[str, Any] = {"killed": killed, "kill_rc": rA.returncode,
                           "restart_rc": rB.returncode,
                           "control_rc": rC.returncode}
    if rB.returncode != 0 or rC.returncode != 0:
        out.update(ok=False, stderr=(rB.stderr + rC.stderr)[-2000:])
        return out
    rb, rc = _load_report(reports["b"]), _load_report(reports["c"])
    expect_resume = kill_at - (kill_at % ckpt_every)
    recovery_s = rb["first_step_s"]
    stats = rb["plan_stats"]
    out.update(
        ok=(killed
            and rb["resumed_from"] == expect_resume
            and rb["prewarmed"] >= 1
            and stats.get("measured", 0) == 0
            and stats.get("hits", 0) >= steps - expect_resume
            and rb["state_sha256"] == rc["state_sha256"]
            and recovery_s <= RECOVERY_BOUND_S),
        bitwise_identical=rb["state_sha256"] == rc["state_sha256"],
        resume_step=rb["resumed_from"], expect_resume=expect_resume,
        prewarmed=rb["prewarmed"], restart_plan_stats=stats,
        recovery_s=recovery_s, recovery_bound_s=RECOVERY_BOUND_S,
        restart_wall_s=wall_b)
    return out


def scenario_sigterm_drain(workdir: str, *, steps: int = 12,
                           sigterm_at: int = 6, ckpt_every: int = 3,
                           timeout: int = 600) -> Dict[str, Any]:
    """Preemption on a ckpt boundary: drain, save once, resume bitwise."""
    base = os.path.join(workdir, "sigterm")
    os.makedirs(base, exist_ok=True)
    ckpt = os.path.join(base, "ckpt")
    reports = {k: os.path.join(base, f"report_{k}.json") for k in "abc"}
    assert sigterm_at % ckpt_every == 0, \
        "scenario targets the boundary-coincident preemption"

    rA, _ = _run_worker(_train_args(
        ckpt, os.path.join(base, "cache_a.json"), reports["a"],
        steps=steps, ckpt_every=ckpt_every, sigterm_at=sigterm_at),
        timeout=timeout)
    rB, _ = _run_worker(_train_args(
        ckpt, os.path.join(base, "cache_b.json"), reports["b"],
        steps=steps, ckpt_every=ckpt_every), timeout=timeout)
    rC, _ = _run_worker(_train_args(
        os.path.join(base, "ckpt_control"),
        os.path.join(base, "cache_c.json"), reports["c"],
        steps=steps, ckpt_every=ckpt_every), timeout=timeout)

    out: Dict[str, Any] = {"drain_rc": rA.returncode,
                           "resume_rc": rB.returncode,
                           "control_rc": rC.returncode}
    if rA.returncode != 0 or rB.returncode != 0 or rC.returncode != 0:
        out.update(ok=False,
                   stderr=(rA.stderr + rB.stderr + rC.stderr)[-2000:])
        return out
    ra, rb, rc = (_load_report(reports[k]) for k in "abc")
    expected_saves = sigterm_at // ckpt_every   # drain save deduplicated
    out.update(
        ok=(ra["preempted"]
            and ra["final_step"] == sigterm_at
            and ra["save_count"] == expected_saves
            and rb["resumed_from"] == sigterm_at
            and rb["state_sha256"] == rc["state_sha256"]),
        preempted=ra["preempted"], drained_at=ra["final_step"],
        save_count=ra["save_count"], expected_saves=expected_saves,
        resume_step=rb["resumed_from"],
        bitwise_identical=rb["state_sha256"] == rc["state_sha256"])
    return out


def scenario_evict_remesh(workdir: str, *,
                          timeout: int = 600) -> Dict[str, Any]:
    """Pod loss: replace_host keeps plans correct for the new topology."""
    base = os.path.join(workdir, "remesh")
    os.makedirs(base, exist_ok=True)
    report = os.path.join(base, "report.json")
    r, wall = _run_worker(["remesh", "--dir", base, "--report", report],
                          n_dev=8, timeout=timeout)
    if r.returncode != 0:
        return {"ok": False, "rc": r.returncode, "stderr": r.stderr[-2000:]}
    out = _load_report(report)
    out["ok"] = bool(out.get("ok")) and out["recovery_s"] <= RECOVERY_BOUND_S
    out["recovery_bound_s"] = RECOVERY_BOUND_S
    out["wall_s"] = wall
    return out


def scenario_slow_host(workdir: str, *,
                       timeout: int = 600) -> Dict[str, Any]:
    """Straggler: MAD detection -> rebalance -> shrunk-shard re-plan."""
    base = os.path.join(workdir, "slowhost")
    os.makedirs(base, exist_ok=True)
    report = os.path.join(base, "report.json")
    r, wall = _run_worker(["slowhost", "--report", report], n_dev=2,
                          timeout=timeout)
    if r.returncode != 0:
        return {"ok": False, "rc": r.returncode, "stderr": r.stderr[-2000:]}
    out = _load_report(report)
    out["wall_s"] = wall
    return out


def run_scenarios(workdir: Optional[str] = None, *, smoke: bool = True,
                  timeout: int = 600) -> Dict[str, Any]:
    """Run the full chaos suite; the BENCH_chaos.json payload."""
    workdir = workdir or tempfile.mkdtemp(prefix="repro_chaos_")
    steps = 10 if smoke else 24
    t0 = time.perf_counter()
    scenarios = {
        "kill_restart": scenario_kill_restart(
            workdir, steps=steps, kill_at=7, ckpt_every=3, timeout=timeout),
        "sigterm_drain": scenario_sigterm_drain(
            workdir, steps=steps + 2, sigterm_at=6, ckpt_every=3,
            timeout=timeout),
        "evict_remesh": scenario_evict_remesh(workdir, timeout=timeout),
        "slow_host": scenario_slow_host(workdir, timeout=timeout),
    }
    return {"suite": "chaos", "smoke": smoke, "workdir": workdir,
            "wall_s": time.perf_counter() - t0,
            "scenarios": scenarios,
            "ok": all(s.get("ok") for s in scenarios.values())}


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    sub = parser.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("train", help="deterministic supervised worker")
    p.add_argument("--ckpt-dir", required=True)
    p.add_argument("--plan-cache", required=True)
    p.add_argument("--report", default="")
    p.add_argument("--steps", type=int, default=10)
    p.add_argument("--ckpt-every", type=int, default=3)
    p.add_argument("--kill-at", type=int, default=None)
    p.add_argument("--sigterm-at", type=int, default=None)

    p = sub.add_parser("remesh", help="pod-loss replace_host worker")
    p.add_argument("--dir", required=True)
    p.add_argument("--report", default="")

    p = sub.add_parser("slowhost", help="straggler rebalance worker")
    p.add_argument("--report", default="")

    p = sub.add_parser("suite", help="orchestrate all scenarios")
    p.add_argument("--workdir", default=None)
    p.add_argument("--full", action="store_true")
    p.add_argument("--json", default="")

    args = parser.parse_args(argv)
    if args.cmd == "train":
        _worker_train(args)
    elif args.cmd == "remesh":
        _worker_remesh(args)
    elif args.cmd == "slowhost":
        _worker_slowhost(args)
    else:
        result = run_scenarios(args.workdir, smoke=not args.full)
        _write_report(args.json, result)
        return 0 if result["ok"] else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
