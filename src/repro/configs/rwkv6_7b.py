"""rwkv6-7b [ssm] "Finch" — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]  32L d_model=4096 d_ff=14336 vocab=65536;
64 wkv heads of dim 64."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="rwkv6_7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,           # wkv heads (d_model / 64)
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    norm="layernorm",
    ssm_head_dim=64,
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    ssm_head_dim=16,
    compute_dtype="float32",
)


# §Perf-winning preset (EXPERIMENTS.md hillclimb C): tile-pair chunk scan +
# sequence-parallel residual. RF 0.025 -> 0.060; peak 80 -> 6.7 GiB/dev.
OPTIMIZED = CONFIG.replace(
    scan_impl="xla_tiled",
    rule_overrides={**(CONFIG.rule_overrides or {}), "seq_sp": "model"},
)
