"""Host data pipe: determinism, in-order delivery, back-pressure,
checkpointable state, multi-producer equivalence."""

import time

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import HostPipeline, SyntheticSpec, batch_at

SPEC = SyntheticSpec(vocab=100, seq_len=8, global_batch=2, seed=3)


def test_batches_are_pure_functions_of_step():
    a = batch_at(SPEC, 5)
    b = batch_at(SPEC, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_at(SPEC, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    b = batch_at(SPEC, 0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


@pytest.mark.parametrize("producers,depth", [(1, 1), (1, 4), (2, 2), (3, 5)])
def test_pipe_in_order_and_matches_direct(producers, depth):
    pipe = HostPipeline(lambda s: batch_at(SPEC, s), depth=depth,
                        producers=producers)
    try:
        for step in range(12):
            got = pipe.get()
            want = batch_at(SPEC, step)
            np.testing.assert_array_equal(got["tokens"], want["tokens"])
        assert pipe.state == 12
    finally:
        pipe.stop()


def test_pipe_resume_from_state():
    pipe = HostPipeline(lambda s: batch_at(SPEC, s), depth=2, producers=2)
    for _ in range(5):
        pipe.get()
    state = pipe.state
    pipe.stop()
    pipe2 = HostPipeline(lambda s: batch_at(SPEC, s), depth=2, producers=2,
                         start_step=state)
    try:
        got = pipe2.get()
        want = batch_at(SPEC, 5)
        np.testing.assert_array_equal(got["tokens"], want["tokens"])
    finally:
        pipe2.stop()


def test_pipe_backpressure_bounded():
    """Producers may not run ahead more than `depth` words."""
    calls = []
    def slow_consume_fn(s):
        calls.append(s)
        return batch_at(SPEC, s)
    pipe = HostPipeline(slow_consume_fn, depth=3, producers=1)
    try:
        time.sleep(0.5)
        assert max(calls) <= 3           # 0..2 in pipe, 3 may be in flight
        pipe.get()
        time.sleep(0.3)
        assert max(calls) <= 4
    finally:
        pipe.stop()


def test_modality_stubs():
    spec = SyntheticSpec(vocab=10, seq_len=4, global_batch=2, n_frames=5,
                         n_patches=3, d_model=8)
    b = batch_at(spec, 0)
    assert b["frames"].shape == (2, 5, 8)
    assert b["image_embeds"].shape == (2, 3, 8)


@given(st.integers(0, 1000), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_distinct_steps_distinct_batches(s1, s2):
    a = batch_at(SPEC, s1)
    b = batch_at(SPEC, s2)
    if s1 == s2:
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    else:
        assert not np.array_equal(a["tokens"], b["tokens"])
