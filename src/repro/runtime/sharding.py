"""Logical-axis sharding rules (MaxText-style) for the production meshes.

Model code names axes logically ("batch", "embed", "heads", "mlp", "vocab",
"expert", ...). A rule table maps logical names to mesh axes; the trainer /
dry-run installs a :class:`ShardingContext`, and model code calls
:func:`constrain` on activations. Without a context every call is a no-op,
so kernels/smoke tests run unchanged on one CPU device.

Default rules implement DP over ("pod","data") x TP/EP over "model":

  batch   -> (pod, data)     activations' global-batch dim
  embed   -> None            residual stream stays replicated across model
  heads   -> model           attention heads (TP)
  mlp     -> model           FFN hidden (TP)
  vocab   -> model           embedding/unembedding table + logits
  expert  -> model           MoE expert dim (EP), when divisible
  seq     -> None            (sequence parallelism opt-in: -> model)
  kv      -> None
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]

# Data parallel spans pod x data so that the same rules serve both meshes.
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": None,    # Megatron-style sequence parallelism for the residual
                       # stream / layer-boundary saves (hillclimb knob:
                       # -> "model"); attention/MLP internals re-shard by
                       # heads/mlp, XLA inserts the boundary collectives
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "kv": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "exp_cap": None,
    "ssm_heads": "model",
    "state": None,
    "layers": None,
    "frames": None,
    "patches": None,
}


@dataclasses.dataclass
class ShardingContext:
    mesh: Mesh
    rules: Rules

    def axis_size(self, name: str) -> int:
        return self.mesh.shape.get(name, 1)

    def data_shards(self) -> int:
        """How many ways the rules split the workload's batch dim: the
        product of the mesh axes ``"batch"`` maps to. This is the factor
        the stream planner divides a global word schedule by when deriving
        per-shard local workloads (core.meshspec.localize_workload)."""
        target = self.rules.get("batch")
        if target is None:
            return 1
        tgt = (target,) if isinstance(target, str) else target
        n = 1
        for a in tgt:
            n *= self.axis_size(a)
        return n

    def mesh_spec(self):
        """This context's topology as a hashable
        :class:`repro.core.meshspec.MeshSpec` (planner / plan-cache key)."""
        from repro.core.meshspec import MeshSpec
        return MeshSpec.from_mesh(self.mesh)


_LOCAL = threading.local()


def current() -> Optional[ShardingContext]:
    return getattr(_LOCAL, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Optional[Rules] = None,
                 overrides: Optional[Rules] = None):
    """Install mesh + logical rules for model code (and enter the mesh)."""
    rules = dict(DEFAULT_RULES if rules is None else rules)
    if overrides:
        rules.update(overrides)
    # prune rule targets not present in this mesh (e.g. "pod" on single-pod)
    axes = set(mesh.axis_names)

    def prune(target):
        if target is None:
            return None
        if isinstance(target, str):
            return target if target in axes else None
        kept = tuple(a for a in target if a in axes)
        return kept if kept else None

    ctx = ShardingContext(mesh=mesh, rules={k: prune(v) for k, v in rules.items()})
    prev = getattr(_LOCAL, "ctx", None)
    _LOCAL.ctx = ctx
    try:
        with mesh:
            yield ctx
    finally:
        _LOCAL.ctx = prev


def spec_for(logical_axes: Sequence[Optional[str]],
             ctx: Optional[ShardingContext] = None) -> P:
    """PartitionSpec for a tuple of logical axis names (None = replicated)."""
    ctx = ctx or current()
    if ctx is None:
        return P()
    parts = []
    used = set()
    for name in logical_axes:
        target = ctx.rules.get(name) if name is not None else None
        # a mesh axis may appear at most once in a spec
        if target is None:
            parts.append(None)
            continue
        tgt = (target,) if isinstance(target, str) else tuple(target)
        tgt = tuple(a for a in tgt if a not in used)
        if not tgt:
            parts.append(None)
        else:
            used.update(tgt)
            parts.append(tgt if len(tgt) > 1 else tgt[0])
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def sharding_for(logical_axes: Sequence[Optional[str]],
                 ctx: Optional[ShardingContext] = None) -> Optional[NamedSharding]:
    ctx = ctx or current()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, spec_for(logical_axes, ctx))


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """Annotate an activation with its logical sharding (no-op w/o context)."""
    ctx = current()
    if ctx is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{logical_axes} vs rank-{x.ndim} activation")
    return jax.lax.with_sharding_constraint(x, sharding_for(logical_axes, ctx))


def divisible(logical: str, size: int, ctx: Optional[ShardingContext] = None) -> bool:
    """Can axis ``logical`` of extent ``size`` be sharded under the rules?"""
    ctx = ctx or current()
    if ctx is None:
        return True
    target = ctx.rules.get(logical)
    if target is None:
        return True
    tgt = (target,) if isinstance(target, str) else target
    n = 1
    for a in tgt:
        n *= ctx.axis_size(a)
    return size % n == 0


def tree_shardings(axes_tree, ctx: Optional[ShardingContext] = None):
    """Map a pytree of logical-axes tuples to NamedShardings (or None)."""
    ctx = ctx or current()
    if ctx is None:
        return jax.tree.map(lambda _: None, axes_tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(lambda ax: sharding_for(ax, ctx), axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(a is None or isinstance(a, str) for a in x))
