from repro.kernels.ff_gather.ops import gather, gather_cost
from repro.kernels.ff_gather.ref import gather_ref

__all__ = ["gather", "gather_cost", "gather_ref"]
