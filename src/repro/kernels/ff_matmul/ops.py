"""Public op wrapper + cost model for ff_matmul."""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.pipe import Pipe
from repro.kernels.dae import cdiv, pad_to
from repro.kernels.ff_matmul.kernel import matmul_ff
from repro.kernels.ff_matmul.ref import matmul_ref


@dataclasses.dataclass(frozen=True)
class KernelCost:
    """Exact tile-schedule cost of one kernel call (used by the roofline:
    Pallas custom calls are opaque to XLA cost analysis, so each op reports
    its own deterministic FLOP/byte counts)."""

    flops: float
    hbm_bytes: float
    vmem_bytes: int


def matmul_cost(m: int, n: int, k: int,
                block: Tuple[int, int, int] = (128, 128, 128),
                dtype=jnp.float32, depth: int = 2, streams: int = 1) -> KernelCost:
    bm, bn, bk = block
    nm, nn, nk = cdiv(m, bm), cdiv(n, bn), cdiv(k, bk)
    itemsize = jnp.dtype(dtype).itemsize
    # A tile set is re-streamed once per ni; B once per mi; C written once.
    hbm = (nm * bm * nk * bk) * nn * itemsize \
        + (nk * bk * nn * bn) * nm * itemsize \
        + nm * bm * nn * bn * itemsize
    a_pipe = Pipe(tile=(bm, bk), dtype=dtype, depth=depth, streams=streams)
    b_pipe = Pipe(tile=(bk, bn), dtype=dtype, depth=depth, streams=streams)
    return KernelCost(
        flops=2.0 * m * n * k,
        hbm_bytes=float(hbm),
        vmem_bytes=a_pipe.vmem_bytes + b_pipe.vmem_bytes + bm * bn * 4,
    )


def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block: Tuple[int, int, int] = (128, 128, 128),
    depth: int = 2,
    streams: int = 1,
    mode: str = "ff",
    out_dtype=None,
    interpret: bool = True,
) -> jnp.ndarray:
    """C = A @ B with auto-padding to the block grid.

    mode="ff": DAE pipeline with the given pipe depth/streams.
    mode="baseline": synchronous copy-then-compute (depth=1) — the paper's
      single work-item strawman.
    mode="ref": pure-jnp oracle (XLA-visible; used in model graphs and as
      the correctness reference).
    """
    if mode == "ref":
        return matmul_ref(a, b, out_dtype)
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = block
    ap = pad_to(pad_to(a, bm, 0), bk, 1)
    bp = pad_to(pad_to(b, bk, 0), bn, 1)
    if mode == "baseline":
        depth = 1
    out = matmul_ff(ap, bp, block=block, depth=depth, streams=streams,
                    out_dtype=out_dtype, interpret=interpret)
    return out[:m, :n]
