import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (into experiments/dryrun/<cell>.json):

  * proof of compilability on the production mesh (16x16) and the 2-pod
    mesh (2x16x16) — sharding mismatches / unsupported collectives fail here;
  * ``memory_analysis()`` of the full scanned program (bytes per device);
  * ``cost_analysis()`` + HLO collective stats of *unrolled* L=1 and L=2
    variants, from which the roofline extrapolates exact per-layer terms
    (scan bodies are counted once by XLA's cost model — measured, see
    DESIGN.md — so the scanned program's numbers are not used for FLOPs).

Usage:
  python -m repro.launch.dryrun --arch qwen2_72b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-variants]
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.base import (ARCH_IDS, SHAPES, get_config,
                                shape_applicable)
from repro.launch import steps as steps_lib
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.runtime import sharding as shlib

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _mem_dict(m):
    return {
        "argument_bytes": m.argument_size_in_bytes,
        "output_bytes": m.output_size_in_bytes,
        "temp_bytes": m.temp_size_in_bytes,
        "alias_bytes": m.alias_size_in_bytes,
        "code_bytes": m.generated_code_size_in_bytes,
        "peak_bytes_est": (m.argument_size_in_bytes + m.output_size_in_bytes
                           + m.temp_size_in_bytes - m.alias_size_in_bytes),
    }


def _cost_dict(c):
    return {"flops": c.get("flops", 0.0),
            "bytes": c.get("bytes accessed", 0.0)}


def lower_cell(cfg, shape, mesh, overrides):
    """Lower the entry point for one cell; returns (lowered, model)."""
    with shlib.use_sharding(mesh, overrides=overrides) as ctx:
        model = build_model(cfg)
        sh = steps_lib.shardings_for_cell(model, shape, ctx,
                                          optimizer=cfg.optimizer)
        p_abs = model.abstract_params()
        batch_abs = model.input_specs(shape)
        if shape.kind == "train":
            train_step = steps_lib.make_train_step(model,
                                                   optimizer=cfg.optimizer)
            opt_init, _ = steps_lib.opt_init_and_update(cfg.optimizer)
            opt_abs = jax.eval_shape(opt_init, p_abs)
            fn = jax.jit(
                train_step,
                in_shardings=(sh["params"], sh["opt"], sh["batch"]),
                donate_argnums=(0, 1))
            lowered = fn.lower(p_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            fn = jax.jit(steps_lib.make_prefill_step(model),
                         in_shardings=(sh["params"], sh["batch"]))
            lowered = fn.lower(p_abs, batch_abs)
        else:
            cache_abs, _ = model.cache_spec(shape)
            fn = jax.jit(steps_lib.make_decode_step(model),
                         in_shardings=(sh["params"], sh["batch"],
                                       sh["cache"]),
                         donate_argnums=(2,))
            lowered = fn.lower(p_abs, batch_abs, cache_abs)
        return lowered, model


def _reduced_cfg(cfg, n_units: int):
    """Cost-extraction variant: n_units 'layer units', unrolled."""
    if cfg.family == "hybrid":
        k = cfg.attn_every_n
        return cfg.replace(n_layers=k * n_units, scan_layers=False)
    if cfg.family == "encdec":
        return cfg.replace(n_layers=n_units, n_enc_layers=n_units,
                           scan_layers=False)
    return cfg.replace(n_layers=n_units, scan_layers=False)


def n_layer_units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every_n
    return cfg.n_layers


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             skip_variants: bool = False, out_dir: str = OUT_DIR,
             cfg_patch=None, tag: str = "", mesh_axes=None) -> dict:
    """mesh_axes: optional ((name, size), ...) replacing the production mesh
    (same chip count) — used by §Perf mesh-refactoring iterations."""
    cfg = get_config(arch_id)
    if cfg_patch:
        cfg = cfg.replace(**cfg_patch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch_id}__{shape_name}__{mesh_name}{tag}"
    result = {"cell": cell, "arch": arch_id, "shape": shape_name,
              "mesh": mesh_name, "ok": False}

    ok, why = shape_applicable(cfg, shape)
    if not ok:
        result.update(skipped=True, reason=why, ok=True)
        _write(out_dir, cell, result)
        return result

    overrides = {**(cfg.rule_overrides or {}),
                 **(shape.rule_overrides or {})}
    if mesh_axes is not None:
        names = tuple(n for n, _ in mesh_axes)
        sizes = tuple(s for _, s in mesh_axes)
        mesh = jax.make_mesh(sizes, names)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        t0 = time.time()
        lowered, model = lower_cell(cfg, shape, mesh, overrides)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        result["memory"] = _mem_dict(compiled.memory_analysis())
        result["cost_scan_program"] = _cost_dict(compiled.cost_analysis())
        result["timings"] = {"lower_s": t1 - t0, "compile_s": t2 - t1}
        result["n_params"] = model.param_count()
        result["n_active_params"] = model.active_param_count()
        result["n_layer_units"] = n_layer_units(cfg)
        result["ok"] = True
        del lowered, compiled

        if not skip_variants:
            variants = {}
            for nl in (1, 2):
                cfgv = _reduced_cfg(cfg, nl)
                lv, _ = lower_cell(cfgv, shape, mesh, overrides)
                cv = lv.compile()
                variants[f"L{nl}"] = {
                    **_cost_dict(cv.cost_analysis()),
                    "collectives": collective_stats(cv.as_text()),
                }
                del lv, cv
            result["variants"] = variants
    except Exception as e:   # noqa: BLE001 — report per-cell failures
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    _write(out_dir, cell, result)
    return result


def _write(out_dir, cell, result):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{cell}.json"), "w") as f:
        json.dump(result, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-variants", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for a, s in cells:
        r = run_cell(a, s, multi_pod=args.multi_pod,
                     skip_variants=args.skip_variants, out_dir=args.out)
        status = ("SKIP" if r.get("skipped")
                  else "OK" if r["ok"] else "FAIL")
        n_fail += status == "FAIL"
        mem = r.get("memory", {}).get("peak_bytes_est", 0) / 2**30
        print(f"[{status:4s}] {r['cell']:60s} peak={mem:7.2f} GiB "
              f"{r.get('error', '')}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
