"""Pipeline parallelism over the ``pod`` axis: the paper's pipes at pod
scale.

GPipe-style schedule under shard_map: each pod holds a contiguous stage of
layers; activations flow stage->stage through ``ppermute`` (the inter-pod
pipe, one microbatch per word). With M microbatches and S stages the bubble
is (S-1)/(M+S-1) — the driver picks M >= 4*S.

The rotating-buffer schedule below runs all stages every tick: stage s
computes microbatch (t - s) while the permute moves last tick's outputs —
compute/comm overlap identical in shape to the kernel DAE schedule.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.runtime.collectives import axis_size


def pipeline_apply(stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                   stage_params: Any,
                   microbatches: jnp.ndarray,
                   axis_name: str) -> jnp.ndarray:
    """Run a GPipe pipeline under shard_map.

    stage_fn(params, x) -> x           one stage's forward
    stage_params                       this device's stage params (sharded)
    microbatches: [M, mb, ...]         this *pipeline's* input, replicated
                                       (stage 0 consumes them in order)
    Returns [M, mb, ...] final-stage outputs (valid on the last stage;
    replicated back by the caller if needed).
    """
    n_stage = axis_size(axis_name)
    stage = jax.lax.axis_index(axis_name)
    m = microbatches.shape[0]
    ticks = m + n_stage - 1
    perm = [(i, i + 1) for i in range(n_stage - 1)]       # stage s -> s+1

    buf = jnp.zeros_like(microbatches[0])
    outs = jnp.zeros_like(microbatches)

    def tick(t, carry):
        buf, outs = carry
        mb_idx = t - stage                                 # microbatch at this stage
        feed = jax.lax.dynamic_index_in_dim(
            microbatches, jnp.clip(t, 0, m - 1), keepdims=False)
        x_in = jnp.where(stage == 0, feed, buf)
        active = (mb_idx >= 0) & (mb_idx < m)
        y = stage_fn(stage_params, x_in)
        y = jnp.where(active, y, buf)
        # last stage banks its result; others forward through the pipe
        outs = jax.lax.cond(
            active & (stage == n_stage - 1),
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, y, jnp.clip(mb_idx, 0, m - 1), 0),
            lambda o: o, outs)
        buf = jax.lax.ppermute(y, axis_name, perm)
        return buf, outs

    _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
    return outs
