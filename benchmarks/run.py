"""Benchmark harness: one module per paper table/figure + the roofline
report. Prints ``name,us_per_call,derived`` CSV lines (detail lines are
'#'-prefixed)."""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (fig4_m2c2, kernel_bench, roofline_report,
                            table2_feedforward, table3_microbench)
    failures = []
    for mod in (table2_feedforward, fig4_m2c2, table3_microbench,
                kernel_bench, roofline_report):
        print(f"\n===== {mod.__name__} =====")
        try:
            mod.main()
        except Exception:   # noqa: BLE001 — report all benches
            failures.append(mod.__name__)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED benches: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("\nall benches ok")


if __name__ == "__main__":
    main()
