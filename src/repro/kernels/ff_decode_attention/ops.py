"""Public op wrapper + cost model for ff_decode_attention."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.emitter import cdiv
from repro.core.pipeline_model import Workload
from repro.core.program import PipePolicy, make_entrypoint
from repro.kernels.ff_decode_attention.kernel import build_program, \
    decode_attention_ff
from repro.kernels.ff_decode_attention.ref import decode_attention_ref
from repro.kernels.registry import KernelCost, register_kernel


def decode_attention_cost(b: int, h: int, kvh: int, s: int, d: int,
                          *, block_kv: int = 128, depth: int = 2,
                          dtype=jnp.bfloat16) -> KernelCost:
    itemsize = jnp.dtype(dtype).itemsize
    flops = 4.0 * b * h * s * d
    hbm = b * kvh * 2 * s * d * itemsize + 2 * b * h * d * itemsize
    g_pad = max(8, -(-(h // kvh) // 8) * 8)
    vmem = 2 * depth * block_kv * d * itemsize + g_pad * d * 4 * 3
    return KernelCost(flops=flops, hbm_bytes=float(hbm), vmem_bytes=vmem)


def decode_attention_workload(b: int, h: int, kvh: int, s: int, d: int,
                              *, block_kv: int = 128, dtype=jnp.bfloat16
                              ) -> Tuple[Workload, Tuple[int, int]]:
    """One word per (b, kvh, kj): a K and a V cache tile. The whole KV
    cache streams once — the paper's regular, DLCD-free favourable case."""
    itemsize = jnp.dtype(dtype).itemsize
    nkv = cdiv(s, block_kv)
    group = max(h // kvh, 1)
    w = Workload(
        n_words=b * kvh * nkv,
        word_bytes=float(2 * block_kv * d * itemsize),
        flops_per_word=4.0 * group * block_kv * d,
        regular=True,
    )
    return w, (block_kv, d)


def paged_decode_attention_workload(b: int, h: int, kvh: int, n_pages: int,
                                    page: int, d: int, *, dtype=jnp.bfloat16
                                    ) -> Tuple[Workload, Tuple[int, int]]:
    """One word per (b, kvh, page): a merged K+V page tile gathered through
    the block table. Same math as :func:`decode_attention_workload` at
    ``block_kv == page``, but the stream arrives via an irregular gather."""
    itemsize = jnp.dtype(dtype).itemsize
    group = max(h // kvh, 1)
    w = Workload(
        n_words=b * kvh * n_pages,
        word_bytes=float(2 * page * d * itemsize),
        flops_per_word=4.0 * group * page * d,
        regular=True,
    )
    return w, (2 * page, d)


# KV-cache tile candidates for mode="autotune" (the cache stream's word
# size; candidates not dividing the call site's S are skipped at measure)
_TILE_OPTIONS = (
    {"block_kv": 64},
    {"block_kv": 256},
    {"block_kv": 512},
)


def _apply(q, k, v, lengths=None, *, kv_heads: int = None,
           block_kv: int = 128, policy: PipePolicy):
    """Decode attention for one new token.

    q: [B, H, D]; k, v: [B, KVH, S, D]; lengths: [B] int32 (defaults to S).
    Returns [B, H, D]. The wrapper regroups q heads per KV head and pads the
    group to the 8-sublane granule.
    policy.mode="ff"|"autotune"(measured plan)|"baseline"|"ref".
    """
    del kv_heads    # accepted for legacy signature compatibility
    b, h, d = q.shape
    _, kvh, s, _ = k.shape
    assert h % kvh == 0
    group = h // kvh
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    if policy.mode == "ref":
        qg = q.reshape(b, kvh, group, d)
        return decode_attention_ref(qg, k, v, lengths).reshape(b, h, d)
    g_pad = -(-group // 8) * 8
    qg = q.reshape(b, kvh, group, d)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - group), (0, 0)))
    lens = lengths.astype(jnp.int32)

    def _run(bkv, depth, streams):
        if s % bkv != 0:
            raise ValueError(f"block_kv={bkv} does not divide S={s}")
        return decode_attention_ff(
            qg, k, v, lens, block_kv=bkv, depth=depth, streams=streams,
            interpret=policy.interpret)

    w, tile = decode_attention_workload(b, h, kvh, s, d, block_kv=block_kv,
                                        dtype=k.dtype)
    choice = autotune.resolve_call(
        "ff_decode_attention", policy, workload=w, tile=tile, dtype=k.dtype,
        workload_fn=lambda tk: decode_attention_workload(
            b, h, kvh, s, d, block_kv=tk.get("block_kv", block_kv),
            dtype=k.dtype),
        runner=None if autotune.has_tracers(q, k, v, lens) else
        lambda tk, dep, st: lambda: _run(
            tk.get("block_kv", block_kv), dep, st),
        tile_options=_TILE_OPTIONS,
        site={"b": b, "h": h, "kvh": kvh, "s": s, "d": d,
              "block_kv": block_kv},
        site_dynamic=("b", "s"))
    out = _run(choice.tile_kwargs.get("block_kv", block_kv), choice.depth,
               choice.streams)
    return out[:, :, :group, :].reshape(b, h, d)


decode_attention = make_entrypoint("ff_decode_attention", _apply)


def _make_inputs(key):
    q = jax.random.normal(key, (2, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, 128, 64),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 128, 64),
                          jnp.float32)
    lens = jnp.array([70, 128], jnp.int32)
    return (q, k, v, lens), {"block_kv": 64}


def _sweep_inputs(key, site):
    # rebuild concrete operands at a recorded call-site shape (plan sweep);
    # h snaps to a multiple of the recorded KV-head count
    kvh = int(site["kvh"])
    h = max(1, int(site["h"]) // kvh) * kvh
    b, s, d = int(site["b"]), int(site["s"]), int(site["d"])
    dt = jnp.dtype(site.get("dtype", "float32"))
    q = jax.random.normal(key, (b, h, d), dt)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kvh, s, d), dt)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, kvh, s, d), dt)
    lens = jnp.full((b,), s, jnp.int32)
    return (q, k, v, lens), {"block_kv": int(site.get("block_kv", 128))}


def _smoke_program(*, depth: int = 2, streams: int = 1, tile=None):
    # the smoke shape point of _make_inputs (group 2 -> g_pad 8)
    return build_program(2, 2, 8, 128, 64,
                         block_kv=(tile or {}).get("block_kv", 64),
                         dtype=jnp.float32, depth=depth, streams=streams)


register_kernel(
    name="ff_decode_attention",
    alias="decode_attention",
    op=decode_attention,
    ref=decode_attention_ref,
    cost=decode_attention_cost,
    workload=decode_attention_workload,
    program=_smoke_program,
    make_inputs=_make_inputs,
    bench_kwargs={"b": 8, "h": 64, "kvh": 8, "s": 32768, "d": 128,
                  "dtype": jnp.bfloat16},
    tile_options=_TILE_OPTIONS,
    regular=True,
    tol=2e-4,
    doc="flash-decode vs. long KV caches",
    shard_dims=(0, 0, 0, 0),     # request batch data-parallel
    shard_out_dim=0,
    sweep_inputs=_sweep_inputs,
)
