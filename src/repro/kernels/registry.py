"""Kernel registry: one KernelSpec per ff_* op, a single interface for the
benchmarks, the planner, and the tests (style: models/registry.py).

Each kernel subpackage registers itself at import time with
:func:`register_kernel`, declaring:

  op          public wrapper (accepts mode="ff"|"baseline"|"ref",
              depth=int|"auto", streams=int|"auto", interpret=...)
  ref         pure-jnp oracle
  cost        exact tile-schedule cost model -> KernelCost
  workload    Workload builder: call-site shapes -> (core.Workload, tile),
              the planner's input for depth/streams auto-sizing
  make_inputs tiny-input builder for smoke/equivalence runs

so adding a sixth kernel is its subpackage plus one ``register_kernel``
call — the benchmark harness, the ``--smoke`` mode, and the registry tests
all pick it up by enumeration, nothing else changes.

Registration is lazy: the five built-in subpackages are imported on first
lookup, so ``import repro.kernels.registry`` alone stays cheap and the
subpackages can import this module without a cycle.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class KernelCost:
    """Exact tile-schedule cost of one kernel call (used by the roofline:
    Pallas custom calls are opaque to XLA cost analysis, so each op reports
    its own deterministic FLOP/byte counts)."""

    flops: float
    hbm_bytes: float
    vmem_bytes: int


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered kernel. ``bench_kwargs`` is the shape point used by
    benchmarks/kernel_bench.py and must be accepted by both ``cost`` and
    ``workload``."""

    name: str
    op: Callable[..., Any]
    ref: Callable[..., Any]
    cost: Callable[..., KernelCost]
    workload: Callable[..., Tuple[Any, Tuple[int, ...]]]
    make_inputs: Callable[..., Tuple[tuple, dict]]
    bench_kwargs: Mapping[str, Any]
    regular: bool = True
    tol: float = 1e-4
    doc: str = ""


_REGISTRY: Dict[str, KernelSpec] = {}

# the five built-in subpackages; their ops.py modules self-register on import
_BUILTIN = (
    "repro.kernels.ff_matmul.ops",
    "repro.kernels.ff_attention.ops",
    "repro.kernels.ff_decode_attention.ops",
    "repro.kernels.ff_chunk_scan.ops",
    "repro.kernels.ff_gather.ops",
)


def register_kernel(**fields) -> KernelSpec:
    """Register one kernel (keyword form of KernelSpec). Re-registration
    under the same name replaces the entry (supports module reloads)."""
    spec = KernelSpec(**fields)
    _REGISTRY[spec.name] = spec
    return spec


def _ensure_loaded() -> None:
    for mod in _BUILTIN:
        importlib.import_module(mod)


def kernel_names() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def all_kernels() -> Tuple[KernelSpec, ...]:
    _ensure_loaded()
    return tuple(_REGISTRY[n] for n in sorted(_REGISTRY))


def get_kernel(name: str) -> KernelSpec:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def run_smoke(spec: KernelSpec, *, depth="auto", streams="auto", seed: int = 0,
              interpret: bool = True) -> Tuple[np.ndarray, np.ndarray, float]:
    """Run ``spec`` at its tiny smoke shapes against its oracle.

    Exercises the full planned path by default (depth/streams "auto" go
    through plan_pipe). Returns (out, ref, max_abs_err).
    """
    import jax

    args, kw = spec.make_inputs(jax.random.key(seed))
    out = np.float32(spec.op(*args, **kw, mode="ff", depth=depth,
                             streams=streams, interpret=interpret))
    ref = np.float32(spec.op(*args, **kw, mode="ref"))
    err = float(np.max(np.abs(out - ref))) if out.size else 0.0
    return out, ref, err
