"""Paper Figure 4: M2C2 (2 producers x 2 consumers) speedup over the FF
baseline + resource overhead; ``--sweep-streams`` shows the >2x2 saturation
the paper reports (no gains, extra VMEM)."""

from __future__ import annotations

from repro.core import ARRIA_CX, Pipe, estimate_feedforward
from benchmarks.workloads import BENCHES


def rows(streams_list=(1, 2, 4)):
    out = []
    for name, b in BENCHES.items():
        pipe1 = Pipe(tile=(8, 128), depth=8, streams=1)
        ff1 = estimate_feedforward(b.workload, ARRIA_CX, pipe1)
        row = {"name": name, "ff_ms": ff1.total_s * 1e3,
               "paper_m2c2": b.paper_m2c2, "vmem_1": ff1.vmem_bytes}
        for s in streams_list:
            if s == 1:
                continue
            pipe = Pipe(tile=(8, 128), depth=8, streams=s)
            ff = estimate_feedforward(b.workload, ARRIA_CX, pipe)
            row[f"x{s}"] = ff1.total_s / ff.total_s
            row[f"vmem_{s}"] = ff.vmem_bytes
        out.append(row)
    return out


def main(sweep_streams: bool = True):
    print("# Fig. 4 analogue: M2C2 speedup over the FF baseline")
    print("name,us_per_call,derived")
    detail = []
    xs = []
    for r in rows((1, 2, 4) if sweep_streams else (1, 2)):
        print(f"fig4/{r['name']},{r['ff_ms'] * 1e3:.3f},"
              f"m2c2={r['x2']:.2f}x_paper~{r['paper_m2c2']:.2f}x")
        xs.append(r["x2"])
        line = (f"  {r['name']:10s} m2c2={r['x2']:5.2f}x "
                f"(paper ~{r['paper_m2c2']:.2f}x) "
                f"vmem {r['vmem_1']}->{r['vmem_2']}B")
        if sweep_streams and "x4" in r:
            line += f"  m4c4={r['x4']:5.2f}x (saturation)"
        detail.append(line)
    for line in detail:
        print("#" + line)
    avg = sum(xs) / len(xs)
    print(f"# avg modeled M2C2 speedup: {avg:.2f}x (paper avg 1.39x); "
          f"VMEM overhead 2x pipes (paper: +31% logic / +26% BRAM)")


if __name__ == "__main__":
    main(sweep_streams=True)
