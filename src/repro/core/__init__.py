"""repro.core — the paper's contribution: feed-forward pipes for TPU.

Public API:
  Pipe                      on-chip FIFO spec (depth, streams, tile)
  RingPipe / GatherRingPipe the shared ring-pipe emitter runtime
  StreamSpec / run_reference  the producer/consumer stream-program contract
  check_no_mlcd             legality (true-MLCD) checker
  Workload / HardwareModel  analytic DAE pipeline model
  estimate_baseline / estimate_feedforward / speedup
  plan_pipe                 roofline-driven (depth, streams) planner
  planned_pipe / resolve_auto  cached per-call-site plan + "auto" resolution
  resolve_call / tuning_config  measured autotuner ((tile, depth, streams)
                            searched empirically, persistent plan cache)
  PipePolicy / policy       unified pipe policy + session-default context
  StreamProgram / compile_program  declarative producer→pipe→consumer graphs
                            lowered through the emitter into one pallas_call
  StreamGraph / compile_graph  multi-kernel pipe graphs: per-edge fused
                            (in-VMEM intermediate, single pallas_call) vs
                            staged (HBM handoff) lowering + estimate_graph
"""

from repro.core.emitter import (
    GatherRingPipe,
    RingPipe,
    acquire,
    cdiv,
    pad_to,
    release,
)
from repro.core.meshspec import (
    MeshSpec,
    SINGLE_DEVICE,
    ambient_mesh,
    localize_workload,
    resolve_mesh,
    resolve_sharding,
)
from repro.core.pipe import Pipe, required_depth, vmem_budget_ok
from repro.core.feedforward import (
    Footprint,
    StreamSpec,
    check_no_mlcd,
    reduction_stream,
    run_multistream_reference,
    run_reference,
    split_words_static,
)
from repro.core.pipeline_model import (
    ARRIA_CX,
    TPU_V5E,
    HardwareModel,
    PipelineEstimate,
    Workload,
    estimate_baseline,
    estimate_feedforward,
    speedup,
)
from repro.core.planner import (
    Plan,
    PlanError,
    invalidate_mesh_plans,
    last_plan,
    plan_cache_clear,
    plan_cache_info,
    plan_pipe,
    planned_pipe,
    resolve_auto,
    resolve_policy,
)
from repro.core.autotune import (
    PLAN_FORMAT_VERSION,
    TunedChoice,
    measure,
    resolve_call,
    resolve_graph,
    restore_snapshot,
    snapshot_plans,
    tuned_cache_clear,
    tuning_config,
)
from repro.core.graph import (
    CompiledGraph,
    GraphEdge,
    GraphNode,
    StreamGraph,
    check_fusion,
    compile_graph,
    graph_signature,
    graph_workload,
)
from repro.core.pipeline_model import (
    EdgeEstimate,
    GraphEstimate,
    GraphStage,
    estimate_graph,
)
from repro.core.program import (
    BlockIn,
    PipePolicy,
    ProgramCtx,
    ScalarIn,
    ScratchSpec,
    Stream,
    StreamProgram,
    compile_program,
    current_policy,
    make_entrypoint,
    policy,
    program_workload,
    resolve_call_policy,
)

__all__ = [
    "ARRIA_CX",
    "BlockIn",
    "CompiledGraph",
    "EdgeEstimate",
    "GraphEdge",
    "GraphEstimate",
    "GraphNode",
    "GraphStage",
    "PLAN_FORMAT_VERSION",
    "PlanError",
    "StreamGraph",
    "TunedChoice",
    "Footprint",
    "GatherRingPipe",
    "check_fusion",
    "compile_graph",
    "estimate_graph",
    "graph_signature",
    "graph_workload",
    "resolve_graph",
    "HardwareModel",
    "MeshSpec",
    "Pipe",
    "PipePolicy",
    "PipelineEstimate",
    "Plan",
    "ProgramCtx",
    "RingPipe",
    "ScalarIn",
    "ScratchSpec",
    "Stream",
    "StreamProgram",
    "SINGLE_DEVICE",
    "StreamSpec",
    "TPU_V5E",
    "Workload",
    "acquire",
    "ambient_mesh",
    "cdiv",
    "check_no_mlcd",
    "compile_program",
    "current_policy",
    "estimate_baseline",
    "estimate_feedforward",
    "invalidate_mesh_plans",
    "last_plan",
    "localize_workload",
    "make_entrypoint",
    "measure",
    "pad_to",
    "plan_cache_clear",
    "plan_cache_info",
    "plan_pipe",
    "planned_pipe",
    "policy",
    "program_workload",
    "reduction_stream",
    "release",
    "required_depth",
    "resolve_auto",
    "resolve_call",
    "resolve_call_policy",
    "resolve_mesh",
    "resolve_policy",
    "resolve_sharding",
    "restore_snapshot",
    "run_multistream_reference",
    "run_reference",
    "snapshot_plans",
    "speedup",
    "split_words_static",
    "tuned_cache_clear",
    "tuning_config",
    "vmem_budget_ok",
]
