"""Mamba2 (SSD) mixer + the zamba2 hybrid stack.

The SSD recurrence h_t = exp(A dt_t) h_{t-1} + dt_t B_t (x) x_t is the
paper's data loop-carried dependency (Fig. 3) in the flesh: the kernel path
(``ff_chunk_scan``) keeps the state in the consumer while x/B/C/dt stream
DLCD-free through pipes; the XLA path (``chunk_scan_xla``) uses the same
chunked math with a log-depth associative scan across chunk boundaries
(HLO-visible for the roofline).

zamba2: a stack of Mamba2 blocks with one *shared* full-attention
transformer block applied every ``attn_every_n`` layers (weights reused
across applications, each application with its own KV cache), per the
Zamba2 architecture. LoRA adapters on the shared block are omitted (noted
in DESIGN.md).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.ff_chunk_scan import chunk_scan, chunk_scan_xla
from repro.models import layers as L
from repro.runtime.sharding import constrain


def _dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_state, cfg.ssm_head_dim


def mamba_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    d_in, nh, n, hd = _dims(cfg)
    conv_dim = d_in + 2 * n
    return {
        "in_proj": L.ParamSpec((d, 2 * d_in + 2 * n + nh), ("embed", "mlp")),
        "conv_w": L.ParamSpec((cfg.conv_width, conv_dim), (None, "mlp"),
                              init="small"),
        "conv_b": L.ParamSpec((conv_dim,), ("mlp",), init="zeros"),
        "a_log": L.ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "dt_bias": L.ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "d_skip": L.ParamSpec((nh,), ("ssm_heads",), init="ones"),
        "norm_w": L.ParamSpec((d_in,), ("mlp",), init="ones"),
        "out_proj": L.ParamSpec((d_in, d), ("mlp", "embed")),
    }


def _split_proj(cfg: ArchConfig, zxbcdt):
    d_in, nh, n, hd = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * n]
    dt = zxbcdt[..., -nh:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, prev: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along time. xbc: [B,S,C]; w: [W,C].
    prev: [B,W-1,C] carried state (decode). Returns (y, new_prev)."""
    width = w.shape[0]
    if prev is None:
        prev = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    xx = jnp.concatenate([prev, xbc], axis=1)
    y = sum(xx[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
            for i in range(width))
    y = jax.nn.silu(y + b[None, None, :])
    new_prev = xx[:, -(width - 1):, :]
    return y, new_prev


def mamba_apply(cfg: ArchConfig, p, x, *, positions=None, cache=None,
                lengths=None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x: [B,S,D]. cache (decode): {"conv": [B,W-1,C], "h": [B*NH,N,HD]}."""
    b, s, d = x.shape
    d_in, nh, n, hd = _dims(cfg)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_prev = cache["conv"] if cache is not None else None
    xbc, conv_new = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), conv_prev)
    x_ssm = xbc[..., :d_in]
    b_ssm = xbc[..., d_in:d_in + n]
    c_ssm = xbc[..., d_in + n:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])             # [B,S,NH]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                  # [NH]
    log_w = dt * a[None, None, :]                                 # <= 0

    xs = x_ssm.reshape(b, s, nh, hd)
    v = (xs.astype(jnp.float32) * dt[..., None]).astype(x.dtype)

    def to_bh(t):                                                 # [B,S,*]->[B*NH,S,*]
        return jnp.broadcast_to(t[:, :, None, :], (b, s, nh, t.shape[-1])) \
            .transpose(0, 2, 1, 3).reshape(b * nh, s, t.shape[-1])

    q_bh = to_bh(c_ssm)
    k_bh = to_bh(b_ssm)
    v_bh = xs.transpose(0, 2, 1, 3).reshape(b * nh, s, hd)
    v_bh = (v_bh.astype(jnp.float32) *
            dt.transpose(0, 2, 1).reshape(b * nh, s, 1)).astype(x.dtype)
    lw_bh = jnp.broadcast_to(
        log_w.transpose(0, 2, 1).reshape(b * nh, s, 1), (b * nh, s, n))

    if cache is None:
        mode = cfg.scan_impl if cfg.scan_impl in ("xla", "xla_tiled", "ff") \
            else "xla"
        y = chunk_scan(q_bh, k_bh, v_bh, lw_bh, inclusive=True,
                       chunk=cfg.scan_chunk,
                       policy=L._session_scan_policy(mode))
        # final state for prefill->decode handoff:
        #   h_S = sum_s exp(cw_S - cw_s) k_s (x) v_s   (exponents <= 0)
        cw = jnp.cumsum(lw_bh.astype(jnp.float32), axis=1)        # [BH,S,N]
        k2 = k_bh.astype(jnp.float32) * jnp.exp(cw[:, -1:, :] - cw)
        h_final = jnp.einsum("bsn,bsp->bnp", k2, v_bh.astype(jnp.float32))
        new_cache = {"conv": conv_new, "h": h_final}
    else:
        # single-token recurrence
        h = cache["h"]                                            # [B*NH,N,HD]
        w1 = jnp.exp(lw_bh[:, 0, :])                              # [B*NH,N]
        kv = k_bh[:, 0, :, None] * v_bh[:, 0, None, :]            # [B*NH,N,HD]
        h = w1[:, :, None] * h + kv.astype(jnp.float32)
        y = jnp.einsum("bn,bnp->bp", q_bh[:, 0].astype(jnp.float32), h)
        y = y[:, None, :].astype(x.dtype)                         # [B*NH,1,HD]
        new_cache = {"conv": conv_new, "h": h}

    y = y.reshape(b, nh, s, hd).transpose(0, 2, 1, 3)             # [B,S,NH,HD]
    y = y + xs * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = L.rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    y = constrain(y, ("batch", "seq", "mlp"))
    out = y @ p["out_proj"].astype(x.dtype)
    return out, new_cache


def mamba_cache_spec(cfg: ArchConfig, batch: int):
    d_in, nh, n, hd = _dims(cfg)
    conv_dim = d_in + 2 * n
    spec = {
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, conv_dim),
                                     cfg.cdtype),
        "h": jax.ShapeDtypeStruct((batch * nh, n, hd), jnp.float32),
    }
    axes = {"conv": ("batch", None, "mlp"),
            "h": ("ssm_heads", "state", None)}
    return spec, axes
