"""PlanDB: the versioned, mergeable, release-shippable tuned-plan artifact.

Where ``~/.cache/repro/plans.json`` is one host's private cache, a PlanDB
is the *fleet* artifact: content-addressed tuned-plan records keyed by the
autotuner's exact ``plan_key`` and partitioned into hardware namespaces
(:mod:`repro.plans.registry`), so one file tuned on heterogeneous hosts
ships with a release and pre-warms every process.

Lookup chain position (see ``autotune.resolve_call``): in-memory -> per-host
disk cache (``REPRO_PLAN_CACHE``) -> **PlanDB** (``REPRO_PLAN_DB``) ->
measure -> analytic. The DB is read-only at serving time: freshly measured
plans go to the host cache and only enter a DB through an offline sweep or
an explicit merge.

Merge semantics (deterministic — merging the same files in any association
order yields the same artifact):

* disjoint keys/namespaces: union (foreign namespaces are preserved
  bitwise — merging never rewrites records it did not touch);
* same key, identical content hash: kept (refreshed ``tuned_at`` wins so
  re-tuning the same answer still advances the timestamp);
* same key, different content: the newer ``tuned_at`` wins; exact-tie
  timestamps break toward the lexicographically larger content hash, and
  every such conflict is reported in the :class:`MergeReport`.

Strictness is asymmetric by design: :meth:`PlanDB.load` and
:meth:`PlanDB.merge` *raise* (:class:`PlanDBError`) on corrupt files or
format mismatches — an artifact pipeline must never silently mix formats —
while the serving-side :func:`lookup`/:func:`prewarm` degrade to an empty
DB with a one-shot warning, because at runtime the DB is a cache tier, not
a source of failure.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import warnings
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.core.autotune import PLAN_FORMAT_VERSION
from repro.plans import registry as plan_registry

PLANDB_FORMAT_VERSION = 1

# record fields excluded from the content hash: provenance, not plan content
_VOLATILE_FIELDS = ("tuned_at", "content_hash")


class PlanDBError(ValueError):
    """Corrupt PlanDB file, or a format/plan-format mismatch."""


def content_hash(record: Mapping[str, Any]) -> str:
    """sha256 of the canonical-JSON record body (volatile provenance
    fields excluded) — two records with the same hash carry the same
    plan."""
    body = {k: v for k, v in record.items() if k not in _VOLATILE_FIELDS}
    return hashlib.sha256(
        json.dumps(body, sort_keys=True, default=list).encode()).hexdigest()


@dataclasses.dataclass
class MergeReport:
    added: int = 0        # keys only the other DB had
    replaced: int = 0     # same key, other's record won
    kept: int = 0         # same key, ours won (or identical content)
    conflicts: List[str] = dataclasses.field(default_factory=list)


class PlanDB:
    """In-memory PlanDB: ``namespaces[namespace][plan_key] -> record``."""

    def __init__(self, namespaces: Optional[Dict[str, Dict[str, dict]]] = None,
                 plan_format: int = PLAN_FORMAT_VERSION):
        self.plan_format = int(plan_format)
        self.namespaces: Dict[str, Dict[str, dict]] = \
            {ns: dict(recs) for ns, recs in (namespaces or {}).items()}

    # -- content ------------------------------------------------------------

    def put(self, namespace: str, key: str, record: Mapping[str, Any],
            tuned_at: Optional[float] = None) -> dict:
        """Stamp + store one tuned-plan record (a fresh dict; ``source`` —
        a lookup-time annotation, not plan content — is dropped)."""
        rec = {k: v for k, v in record.items() if k != "source"}
        rec["tuned_at"] = float(tuned_at if tuned_at is not None
                                else time.time())
        rec["content_hash"] = content_hash(rec)
        self.namespaces.setdefault(namespace, {})[key] = rec
        return rec

    def get(self, namespace: str, key: str) -> Optional[dict]:
        return self.namespaces.get(namespace, {}).get(key)

    def records(self, namespace: str) -> Dict[str, dict]:
        return dict(self.namespaces.get(namespace, {}))

    def stats(self) -> dict:
        return {"plan_format": self.plan_format,
                "namespaces": {ns: len(recs)
                               for ns, recs in sorted(self.namespaces.items())},
                "records": sum(len(r) for r in self.namespaces.values())}

    # -- merge --------------------------------------------------------------

    def merge(self, other: "PlanDB") -> MergeReport:
        """Fold ``other`` into this DB under the deterministic semantics in
        the module docstring. Raises :class:`PlanDBError` on plan-format
        mismatch: records keyed under different plan formats are not
        comparable, so the merge is refused rather than guessed at."""
        if other.plan_format != self.plan_format:
            raise PlanDBError(
                f"cannot merge PlanDB with plan format {other.plan_format} "
                f"into one with {self.plan_format}")
        report = MergeReport()
        for ns, theirs in other.namespaces.items():
            mine = self.namespaces.setdefault(ns, {})
            for key, rec_o in theirs.items():
                rec_m = mine.get(key)
                if rec_m is None:
                    mine[key] = dict(rec_o)
                    report.added += 1
                    continue
                h_m, h_o = rec_m.get("content_hash"), rec_o.get("content_hash")
                t_m = float(rec_m.get("tuned_at", 0.0))
                t_o = float(rec_o.get("tuned_at", 0.0))
                if h_m == h_o:
                    # same plan: keep ours, advance the timestamp
                    rec_m["tuned_at"] = max(t_m, t_o)
                    report.kept += 1
                    continue
                theirs_win = (t_o, str(h_o)) > (t_m, str(h_m))
                report.conflicts.append(
                    f"{ns}:{key[:96]}: {h_m and h_m[:12]} (t={t_m:.3f}) vs "
                    f"{h_o and h_o[:12]} (t={t_o:.3f}) -> "
                    f"{'theirs' if theirs_win else 'ours'}")
                if theirs_win:
                    mine[key] = dict(rec_o)
                    report.replaced += 1
                else:
                    report.kept += 1
        return report

    # -- (de)serialization --------------------------------------------------

    def to_payload(self) -> dict:
        return {"format": PLANDB_FORMAT_VERSION,
                "plan_format": self.plan_format,
                "namespaces": self.namespaces}

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(self.to_payload(), f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "PlanDB":
        """Strict load: raises :class:`PlanDBError` on unreadable/corrupt
        files or a PlanDB format mismatch (artifact tooling must fail
        loudly; the serving path uses :func:`lookup` instead)."""
        try:
            with open(path) as f:
                payload = json.load(f)
        except FileNotFoundError:
            raise
        except (OSError, ValueError) as e:
            raise PlanDBError(f"corrupt PlanDB {path}: {e}") from e
        if not isinstance(payload, dict) \
                or payload.get("format") != PLANDB_FORMAT_VERSION \
                or not isinstance(payload.get("namespaces"), dict):
            raise PlanDBError(
                f"{path}: PlanDB format {payload.get('format')!r} != "
                f"{PLANDB_FORMAT_VERSION}")
        return cls(namespaces=payload["namespaces"],
                   plan_format=int(payload.get("plan_format", -1)))


# ---------------------------------------------------------------------------
# Serving-side lookup (the autotune lookup-chain tier)
# ---------------------------------------------------------------------------

# path -> (namespaces dict or {}, usable) — parsed once per process, like
# autotune._DISK; cleared by clear_cache()
_CACHE: Dict[str, Tuple[Dict[str, Dict[str, dict]], bool]] = {}
_WARNED: set = set()


def clear_cache() -> None:
    """Drop the parsed-DB cache (tests; mirrors autotune.tuned_cache_clear)."""
    _CACHE.clear()
    _WARNED.clear()


def _load_for_serving(path: str) -> Dict[str, Dict[str, dict]]:
    cached = _CACHE.get(path)
    if cached is not None:
        return cached[0]
    try:
        db = PlanDB.load(path)
        if db.plan_format != PLAN_FORMAT_VERSION:
            raise PlanDBError(
                f"{path}: plan format {db.plan_format} != current "
                f"{PLAN_FORMAT_VERSION} (re-sweep the artifact)")
        namespaces, usable = db.namespaces, True
    except FileNotFoundError:
        namespaces, usable = {}, False
    except PlanDBError as e:
        if path not in _WARNED:
            _WARNED.add(path)
            warnings.warn(
                f"ignoring unusable PlanDB ({e}); lookups fall through to "
                f"measurement or the analytic planner", RuntimeWarning,
                stacklevel=3)
        namespaces, usable = {}, False
    _CACHE[path] = (namespaces, usable)
    return namespaces


def lookup(key: str, *, path: str,
           namespace: Optional[str] = None) -> Optional[dict]:
    """Serving-side record lookup: this process's namespace first, then
    :data:`~repro.plans.registry.DEFAULT_NAMESPACE`. Never raises — a
    missing/corrupt/mismatched DB reads as empty (warned once per path)."""
    namespaces = _load_for_serving(path)
    if not namespaces:
        return None
    ns = namespace or plan_registry.plan_namespace()
    for candidate in (ns, plan_registry.DEFAULT_NAMESPACE):
        rec = namespaces.get(candidate, {}).get(key)
        if rec is not None:
            return rec
    return None


def prewarm(path: str, namespace: Optional[str] = None) -> dict:
    """Parse the DB once at startup (so the first resolution is a dict
    lookup, not file IO) and report coverage for this process's
    namespace. Returns a stats dict; never raises."""
    t0 = time.perf_counter()
    namespaces = _load_for_serving(path)
    ns = namespace or plan_registry.plan_namespace()
    return {
        "path": path,
        "usable": bool(_CACHE.get(path, ({}, False))[1]),
        "namespace": ns,
        "records_in_namespace": len(namespaces.get(ns, {})),
        "records_in_default": len(
            namespaces.get(plan_registry.DEFAULT_NAMESPACE, {})),
        "namespaces": {n: len(r) for n, r in sorted(namespaces.items())},
        "prewarm_s": time.perf_counter() - t0,
    }
