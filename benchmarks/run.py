"""Benchmark harness: one module per paper table/figure + the roofline
report. Prints ``name,us_per_call,derived`` CSV lines (detail lines are
'#'-prefixed).

``--smoke`` skips the modeled tables and instead exercises every kernel in
the registry at tiny shapes with planner-sized pipes (interpret mode), so
the perf plumbing — registry enumeration, auto planning, the StreamProgram
compile path — cannot silently rot even where full benches are too slow.
It also writes ``BENCH_smoke.json`` (override with ``--json``): per-kernel
wall time, max error, and the modeled FF-vs-baseline speedup + planned
(depth, streams) at the registry bench shape point, so CI tracks the perf
trajectory run over run."""

from __future__ import annotations

import argparse
import json
import math
import sys
import time
import traceback


def smoke(json_path: str = "BENCH_smoke.json") -> None:
    import jax.numpy as jnp

    from repro.core import (TPU_V5E, estimate_baseline, estimate_feedforward,
                            plan_cache_info, planned_pipe)
    from repro.kernels.registry import all_kernels, run_smoke

    results = []
    failures = []
    print("# smoke: every registered kernel, tiny shapes, depth/streams=auto")
    for spec in all_kernels():
        t0 = time.time()
        try:
            _, _, err = run_smoke(spec)
            ok = err <= spec.tol
        except Exception:   # noqa: BLE001 — report all kernels
            traceback.print_exc()
            ok, err = False, float("nan")
        dt_ms = (time.time() - t0) * 1e3
        row = {
            "kernel": spec.name,
            "alias": spec.alias,
            "ok": bool(ok),
            # None (JSON null), not NaN: bare NaN tokens break RFC-8259
            # parsers of the CI-uploaded artifact
            "max_abs_err": float(err) if math.isfinite(err) else None,
            "tol": spec.tol,
            "smoke_wall_ms": round(dt_ms, 1),
            "model_ok": True,
        }
        try:
            # modeled trajectory numbers at the bench shape point
            kw = dict(spec.bench_kwargs)
            dtype = kw.get("dtype", jnp.float32)
            w, tile = spec.workload(**kw)
            plan = planned_pipe(spec.name, w, tile, dtype, TPU_V5E)
            base = estimate_baseline(w, TPU_V5E)
            ff = estimate_feedforward(w, TPU_V5E, plan.pipe)
            row.update({
                "est_speedup": round(base.total_s / ff.total_s, 3),
                "est_us_per_call": round(ff.total_s * 1e6, 1),
                "plan": {"depth": plan.pipe.depth,
                         "streams": plan.pipe.streams},
                "bottleneck": ff.bottleneck,
            })
        except Exception:   # noqa: BLE001 — still report the other kernels
            traceback.print_exc()
            row["model_ok"] = False    # modeling bug, not a kernel failure
            failures.append(f"{spec.name} (modeled metrics)")
        results.append(row)
        status = "ok" if ok else "FAIL"
        print(f"smoke/{spec.name},{dt_ms:.0f},err={err:.1e}_{status}")
        if not ok:
            failures.append(spec.name)
    cache = plan_cache_info()
    print(f"# plan cache: {cache}")
    if json_path:
        payload = {
            "suite": "smoke",
            "kernels": results,
            "plan_cache": {"hits": cache.hits, "misses": cache.misses},
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_path}")
    if failures:
        print(f"\nFAILED smoke kernels: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("smoke ok")


def full() -> None:
    from benchmarks import (fig4_m2c2, kernel_bench, roofline_report,
                            table2_feedforward, table3_microbench)
    failures = []
    for mod in (table2_feedforward, fig4_m2c2, table3_microbench,
                kernel_bench, roofline_report):
        print(f"\n===== {mod.__name__} =====")
        try:
            mod.main()
        except Exception:   # noqa: BLE001 — report all benches
            failures.append(mod.__name__)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED benches: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("\nall benches ok")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="run every registered kernel at tiny shapes "
                             "instead of the modeled benches")
    parser.add_argument("--json", default="BENCH_smoke.json",
                        help="path for the smoke-mode JSON report "
                             "('' disables; default %(default)s)")
    args = parser.parse_args()
    smoke(args.json) if args.smoke else full()


if __name__ == "__main__":
    main()
