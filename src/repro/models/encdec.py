"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/audio frontend is a STUB: ``input_specs``
provides precomputed frame embeddings [B, n_frames, d_model] (what the two
conv layers would emit). The encoder is a non-causal transformer over
frames; the decoder is a causal transformer with cross-attention whose K/V
are computed once from the encoder output and reused every decode step (a
pipe-resident stream in the ff path).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer
from repro.runtime.sharding import constrain


def specs(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    enc_layer = {
        "norm1": L.norm_specs(cfg.norm, d),
        "attn": transformer.attn_specs(cfg),
        "norm2": L.norm_specs(cfg.norm, d),
        "ffn": L.mlp_specs(d, cfg.d_ff, cfg.act),
    }
    dec_layer = {
        "norm1": L.norm_specs(cfg.norm, d),
        "self_attn": transformer.attn_specs(cfg),
        "norm_x": L.norm_specs(cfg.norm, d),
        "cross_attn": transformer.attn_specs(cfg),
        "norm2": L.norm_specs(cfg.norm, d),
        "ffn": L.mlp_specs(d, cfg.d_ff, cfg.act),
    }

    def stack(one, n):
        return jax.tree.map(
            lambda s: L.ParamSpec((n, *s.shape), ("layers", *s.axes),
                                  s.dtype, s.init, s.scale),
            one, is_leaf=L.is_spec)

    return {
        "enc_layers": stack(enc_layer, cfg.n_enc_layers),
        "enc_norm": L.norm_specs(cfg.norm, d),
        "dec_layers": stack(dec_layer, cfg.n_layers),
        "dec_norm": L.norm_specs(cfg.norm, d),
        "dec_pos": L.ParamSpec((4096 * 9, d), (None, "embed"), init="small"),
    }


def _mha(cfg, p, xq, xkv, *, causal, positions_q, positions_kv=None,
         cache=None, lengths=None):
    """Generic (self or cross) attention using transformer attn weights.
    RoPE is skipped (whisper uses absolute positions)."""
    dt = xq.dtype
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(dt))
    if cache is not None and "k" in cache and xkv is None:
        k, v = cache["k"], cache["v"]     # precomputed cross K/V
        new_cache = cache
        out = L.attention_xla(q, k, v, causal=False)
    else:
        k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(dt))
        if cache is not None:   # decode self-attn append
            k = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
                c, u, i, axis=0))(cache["k"], k, lengths)
            v = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
                c, u, i, axis=0))(cache["v"], v, lengths)
            out = L.decode_attention_op(q[:, 0], k, v, lengths + 1,
                                        impl="xla")[:, None]
            new_cache = {"k": k, "v": v}
            return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), \
                new_cache
        out = L.attention_xla(q, k, v, causal=causal)
        new_cache = {"k": k, "v": v}
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), new_cache


def encode(cfg: ArchConfig, params, frames):
    """frames: [B,F,D] stub embeddings -> encoder output [B,F,D]."""
    x = frames + L.sinusoidal_positions(frames.shape[1], cfg.d_model) \
        .astype(frames.dtype)[None]
    x = constrain(x, ("batch", "frames", "embed"))

    def body(xx, p):
        h = L.norm_apply(cfg.norm, xx, p["norm1"])
        a, _ = _mha(cfg, p["attn"], h, h, causal=False,
                    positions_q=None)
        xx = xx + a
        h = L.norm_apply(cfg.norm, xx, p["norm2"])
        xx = xx + L.mlp_apply(p["ffn"], h, cfg.act)
        return constrain(xx, ("batch", "frames", "embed")), None

    if cfg.remat != "none":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for i in range(cfg.n_enc_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["enc_layers"]))
    return L.norm_apply(cfg.norm, x, params["enc_norm"])


def decode_stack(cfg: ArchConfig, params, x, enc_out, *, positions,
                 caches=None, lengths=None, want_cache=False):
    """x: [B,S,D] token embeddings (+pos added by caller).
    caches (decode): {"self": stacked, "cross": stacked}. enc_out may be
    None when cross K/V are cached."""

    def layer(p, xx, self_cache, cross_cache):
        h = L.norm_apply(cfg.norm, xx, p["norm1"])
        a, new_self = _mha(cfg, p["self_attn"], h, h, causal=True,
                           positions_q=positions, cache=self_cache,
                           lengths=lengths)
        xx = xx + a
        h = L.norm_apply(cfg.norm, xx, p["norm_x"])
        a, new_cross = _mha(cfg, p["cross_attn"], h,
                            enc_out if cross_cache is None else None,
                            causal=False, positions_q=None, cache=cross_cache)
        xx = xx + a
        h = L.norm_apply(cfg.norm, xx, p["norm2"])
        xx = xx + L.mlp_apply(p["ffn"], h, cfg.act)
        xx = constrain(xx, ("batch", "seq", "embed"))
        return xx, new_self, new_cross

    if cfg.remat != "none":
        layer = jax.checkpoint(layer,
                               policy=jax.checkpoint_policies.nothing_saveable)

    if not cfg.scan_layers:
        outs = []
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["dec_layers"])
            sc = (jax.tree.map(lambda a: a[i], caches["self"])
                  if caches is not None else None)
            cc = (jax.tree.map(lambda a: a[i], caches["cross"])
                  if caches is not None else None)
            x, new_self, new_cross = layer(p, x, sc, cc)
            outs.append((new_self, new_cross))
        if want_cache or caches is not None:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
            new_caches = {"self": stacked[0], "cross": stacked[1]}
        else:
            new_caches = None
    elif caches is None:
        def body(xx, p):
            xx, new_self, new_cross = layer(p, xx, None, None)
            ys = (new_self, new_cross) if want_cache else None
            return xx, ys
        x, ys = jax.lax.scan(body, x, params["dec_layers"])
        new_caches = {"self": ys[0], "cross": ys[1]} if want_cache else None
    else:
        def body(xx, xs):
            p, sc, cc = xs
            xx, new_self, new_cross = layer(p, xx, sc, cc)
            return xx, (new_self, new_cross)
        x, ys = jax.lax.scan(
            body, x, (params["dec_layers"], caches["self"], caches["cross"]))
        new_caches = {"self": ys[0], "cross": ys[1]}
    x = L.norm_apply(cfg.norm, x, params["dec_norm"])
    return x, new_caches


def cache_spec(cfg: ArchConfig, batch: int, s_max: int):
    kv = (batch, s_max, cfg.n_kv_heads, cfg.hd)
    cross = (batch, cfg.n_frames, cfg.n_kv_heads, cfg.hd)
    ls = cfg.n_layers
    spec = {
        "self": {"k": jax.ShapeDtypeStruct((ls, *kv), cfg.cdtype),
                 "v": jax.ShapeDtypeStruct((ls, *kv), cfg.cdtype)},
        "cross": {"k": jax.ShapeDtypeStruct((ls, *cross), cfg.cdtype),
                  "v": jax.ShapeDtypeStruct((ls, *cross), cfg.cdtype)},
    }
    ax_kv = ("layers", "batch", "kv", "kv_heads", None)
    ax_cross = ("layers", "batch", "frames", "kv_heads", None)
    axes = {"self": {"k": ax_kv, "v": ax_kv},
            "cross": {"k": ax_cross, "v": ax_cross}}
    return spec, axes
