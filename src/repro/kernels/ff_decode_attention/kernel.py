"""Feed-forward decode attention: one new token vs. a long KV cache.

The decode step is the paper's favourable case par excellence: a huge,
perfectly *regular* stream (the KV cache) consumed by a tiny reduction with
a loop-carried softmax state. The cache stream is DLCD-free, so the memory
kernel prefetches KV tiles at full pipe depth while the consumer folds the
online softmax — the whole kernel runs at HBM bandwidth (roofline-memory
bound), which is exactly what the roofline table shows for decode cells.

Layout: q is [B, KVH, G, D] (G = padded query-head group per KV head, GQA),
cache k/v are [B, KVH, S, D], ``lengths[B]`` gives the live cache prefix.
Grid: 1-D over (b*kvh, kv_block), kv innermost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.emitter import RingPipe, acquire, release
from repro.core.pipe import Pipe

_NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_hbm, v_hbm, o_ref, m_sc, l_sc, acc,
            k_buf, k_sems, v_buf, v_sems,
            *, nkv: int, kvh: int, g_pad: int, bkv: int, d: int,
            scale: float, k_ring: RingPipe, v_ring: RingPipe, out_dtype):
    g = pl.program_id(0)
    n_words = pl.num_programs(0)
    kj = g % nkv
    bh = g // nkv
    b = bh // kvh
    length = len_ref[b]

    def kv_slice(hbm):
        def f(word):
            w_kj = word % nkv
            w_bh = word // nkv
            return hbm.at[w_bh // kvh, w_bh % kvh, pl.ds(w_kj * bkv, bkv), :]
        return f

    pipes = [k_ring.bind(k_buf, k_sems, kv_slice(k_hbm)),
             v_ring.bind(v_buf, v_sems, kv_slice(v_hbm))]
    acquire(g, n_words, pipes)

    @pl.when(kj == 0)
    def _():
        m_sc[...] = jnp.full_like(m_sc, _NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc[...] = jnp.zeros_like(acc)

    kv_start = kj * bkv

    @pl.when(kv_start < length)
    def _():
        q = q_ref[0, 0]                                # [g_pad, d]
        k = k_ring.slot(g)[...]                        # [bkv, d]
        v = v_ring.slot(g)[...]                        # [bkv, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [g_pad, bkv]
        cols = kv_start + jax.lax.broadcasted_iota(jnp.int32, (g_pad, bkv), 1)
        s = jnp.where(cols < length, s, _NEG_INF)
        m_prev = m_sc[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = jnp.broadcast_to(
            l_sc[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True), l_sc.shape)
        acc[...] = acc[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_sc[...] = jnp.broadcast_to(m_new, m_sc.shape)

    @pl.when(kj == nkv - 1)
    def _():
        l = l_sc[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc[...] / l).astype(out_dtype)

    release(g, n_words, pipes)


@functools.partial(
    jax.jit,
    static_argnames=("block_kv", "depth", "streams", "interpret"))
def decode_attention_ff(
    q: jnp.ndarray,           # [B, KVH, G_pad, D]
    k: jnp.ndarray,           # [B, KVH, S, D]
    v: jnp.ndarray,           # [B, KVH, S, D]
    lengths: jnp.ndarray,     # [B] int32
    *,
    block_kv: int = 128,
    depth: int = 2,
    streams: int = 1,
    interpret: bool = True,
) -> jnp.ndarray:
    b, kvh, g_pad, d = q.shape
    _, _, s, _ = k.shape
    assert s % block_kv == 0, (s, block_kv)
    nkv = s // block_kv
    scale = 1.0 / (d ** 0.5)

    k_ring = RingPipe(Pipe(tile=(block_kv, d), dtype=k.dtype, depth=depth,
                           streams=streams))
    v_ring = RingPipe(Pipe(tile=(block_kv, d), dtype=v.dtype, depth=depth,
                           streams=streams))

    kernel = functools.partial(
        _kernel, nkv=nkv, kvh=kvh, g_pad=g_pad, bkv=block_kv, d=d,
        scale=scale, k_ring=k_ring, v_ring=v_ring, out_dtype=q.dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b * kvh * nkv,),
            in_specs=[
                pl.BlockSpec((1, 1, g_pad, d),
                             lambda g, lens: ((g // nkv) // kvh,
                                              (g // nkv) % kvh, 0, 0)),
                pl.BlockSpec(memory_space=pl.ANY),
                pl.BlockSpec(memory_space=pl.ANY),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, g_pad, d),
                lambda g, lens: ((g // nkv) // kvh, (g // nkv) % kvh, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g_pad, 128), jnp.float32),
                pltpu.VMEM((g_pad, 128), jnp.float32),
                pltpu.VMEM((g_pad, d), jnp.float32),
                *k_ring.scratch_shapes,
                *v_ring.scratch_shapes,
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g_pad, d), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)
