"""Adafactor (factored second moment) — the memory-frugal option for the
largest archs (grok-1 314B does not fit AdamW fp32 state on one 256-chip
pod; see EXPERIMENTS.md §Dry-run)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr_peak: float = 1e-3
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    warmup_steps: int = 100
    total_steps: int = 10000


def _factored(shape) -> bool:
    return len(shape) >= 2


def init(params) -> Dict[str, Any]:
    def st(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(st, params,
                              is_leaf=lambda x: isinstance(x, jnp.ndarray)),
            "step": jnp.zeros((), jnp.int32)}


def update(cfg: AdafactorConfig, grads, state, params):
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    lr = cfg.lr_peak * jnp.minimum(1.0, sf / cfg.warmup_steps) * \
        jax.lax.rsqrt(jnp.maximum(sf, cfg.warmup_steps))
    beta = 1.0 - sf ** (-cfg.decay)

    def upd(p, g, v):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.eps
        if _factored(p.shape):
            vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = (vr[..., None] / jnp.mean(vr, axis=-1, keepdims=True)[..., None]) \
                * vc[..., None, :]
            u = g * jax.lax.rsqrt(denom + cfg.eps)
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": beta * v["v"] + (1 - beta) * g2}
            u = g * jax.lax.rsqrt(nv["v"] + cfg.eps)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        p32 = p.astype(jnp.float32) * (1 - cfg.weight_decay * lr) - lr * u
        return p32.astype(p.dtype), nv

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    outs = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_params, {"v": new_v, "step": step}, {"lr": lr}
