"""Fault tolerance: killed/failed training resumes bitwise-identically, and
the supervisor + straggler policies behave as specified."""

import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.runtime.fault_tolerance import FTConfig, Supervisor
from repro.runtime.stragglers import StragglerConfig, StragglerWatchdog

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _counter_step(state, step):
    return {"x": state["x"] + step + 1}


def test_supervisor_resume_after_injected_failure(tmp_path):
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=3,
                   handle_sigterm=False)
    sup = Supervisor(cfg, {"x": np.zeros((), np.int64)}, fail_at_step=7)
    state, start = sup.resume()
    with pytest.raises(RuntimeError, match="injected"):
        sup.run(state, start, 10, _counter_step)
    # new supervisor (a "restarted job") resumes from step 6 checkpoint
    sup2 = Supervisor(cfg, {"x": np.zeros((), np.int64)})
    state, start = sup2.resume()
    assert start == 6
    final = sup2.run(state, start, 10, _counter_step)
    assert int(final["x"]) == sum(range(1, 11))   # identical to no-failure run


def _run_train(ckpt_dir, steps, fail_at=None, timeout=600):
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "qwen1_5_0p5b", "--smoke", "--steps", str(steps), "--batch", "2",
           "--seq", "32", "--ckpt-dir", ckpt_dir, "--ckpt-every", "5",
           "--log-every", "1"]
    if fail_at is not None:
        cmd += ["--fail-at", str(fail_at)]
    env = {**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}
    return subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=timeout)


@pytest.mark.slow
def test_training_killed_and_resumed_is_identical(tmp_path):
    """Deliverable: node-failure recovery. Run A: crash at step 12; run B:
    resume to 20. Run C: uninterrupted 20 steps. Final params must match
    bitwise (stateless data pipeline + pure-function batches)."""
    d1 = str(tmp_path / "crash")
    r = _run_train(d1, 20, fail_at=12)
    assert r.returncode != 0 and "injected failure" in r.stderr
    r = _run_train(d1, 20)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "resumed from checkpoint at step 10" in r.stdout

    d2 = str(tmp_path / "clean")
    r = _run_train(d2, 20)
    assert r.returncode == 0, r.stderr[-2000:]

    from repro.checkpoint import latest_step
    assert latest_step(d1) == 20 and latest_step(d2) == 20
    za = np.load(os.path.join(d1, "step_00000020", "arrays.npz"))
    zb = np.load(os.path.join(d2, "step_00000020", "arrays.npz"))
    assert set(za.files) == set(zb.files)
    for k in za.files:
        np.testing.assert_array_equal(za[k], zb[k], err_msg=k)


def test_straggler_watchdog_policies():
    cfg = StragglerConfig(window=20, slow_factor=1.5, tolerate=3,
                          evict_after=6, hot_spares=1)
    hosts = [f"h{i}" for i in range(8)]
    wd = StragglerWatchdog(cfg, hosts)
    # warmup: uniform
    for _ in range(5):
        acts = wd.observe_step({h: 1.0 for h in hosts})
    assert all(a == "none" for a in acts.values())
    # h3 becomes persistently slow
    actions_seen = []
    for i in range(7):
        t = {h: 1.0 for h in hosts}
        t["h3"] = 2.5
        acts = wd.observe_step(t)
        actions_seen.append(acts["h3"])
    assert "rebalance" in actions_seen
    assert actions_seen[-1] == "replace"
    spare = wd.replace("h3")
    assert spare == "spare_0"
    assert "h3" in wd.evicted and "spare_0" in wd.hosts
    # transient blip never escalates
    wd2 = StragglerWatchdog(cfg, hosts)
    for i in range(10):
        t = {h: 1.0 for h in hosts}
        if i == 4:
            t["h1"] = 3.0
        acts = wd2.observe_step(t)
        assert acts["h1"] in ("none",) if i != 4 else True
    assert acts["h1"] == "none"
