"""Batched serving driver: continuous-batching decode loop.

Prefill and decode are separate jitted programs (the feed-forward model at
the serving level: prefill is the producer filling the KV-cache pipe, the
decode loop is the consumer). Requests arrive with different prompt
lengths; the scheduler right-pads prompts into a prefill batch, then decodes
in lockstep with per-row lengths, retiring rows at EOS / max-len.

The decode loop runs through ``repro.ops`` under the mesh by default
(``--impl ff``): the model's attention/decode-attention call sites hit the
tuned stream kernels, with the session :class:`~repro.core.program.
PipePolicy` installed mesh-tagged around the step bodies (``--policy-mode``
selects ff / baseline / autotune) — so pipe plans are keyed by the serving
mesh topology, never shared with single-device runs. ``--impl xla`` keeps
the HLO-visible reference path; ``--impl cfg`` defers to the arch config.

Example (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1_5_0p5b --smoke \
      --requests 6 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config, smoke_config
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.runtime import sharding as shlib

# decode caches are padded to a KV-block multiple so the ff decode kernel
# streams full tiles (rows past `lengths` are masked inside the kernel)
_KV_BLOCK = 128


def pad_cache_to(cache, s_from: int, s_max: int, seq_dims):
    """Right-pad every cache leaf whose dim ``seq_dims[path]`` is seq."""
    def pad(x):
        for axis in range(x.ndim):
            if x.shape[axis] == s_from and s_from != s_max:
                pads = [(0, 0)] * x.ndim
                pads[axis] = (0, s_max - s_from)
                return jnp.pad(x, pads)
        return x
    return jax.tree.map(pad, cache)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1_5_0p5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--impl", choices=("ff", "xla", "cfg"), default="ff",
                    help="attention implementation: ff = repro.ops stream "
                         "kernels (default), xla = HLO reference, cfg = "
                         "whatever the arch config pins")
    ap.add_argument("--policy-mode", choices=("ff", "baseline", "autotune"),
                    default="ff",
                    help="session PipePolicy mode installed around the "
                         "prefill/decode step bodies (mesh-tagged)")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("serve driver targets decoder-only archs; "
                         "see tests/test_serving.py for enc-dec decode")
    if args.impl != "cfg":
        cfg = cfg.replace(attn_impl=args.impl)
    from repro.core.program import PipePolicy
    policy = PipePolicy(mode=args.policy_mode, interpret=True)
    from repro.models import build_model
    model = build_model(cfg)
    mesh = make_host_mesh()

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab,
                            size=rng.integers(4, args.prompt_len + 1))
               for _ in range(args.requests)]
    b = len(prompts)
    s_max = args.prompt_len + args.max_new
    toks = np.zeros((b, args.prompt_len), np.int32)
    lens = np.array([len(p) for p in prompts], np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p       # right-padded prefill batch

    # cache length rounded to the KV block so the ff decode kernel streams
    # whole tiles; lengths mask the padded rows
    s_max = -(-s_max // _KV_BLOCK) * _KV_BLOCK

    with shlib.use_sharding(mesh, overrides=dict(cfg.rule_overrides or {})):
        params = model.init(jax.random.key(0))
        prefill = jax.jit(steps_lib.make_prefill_step(model, policy=policy))
        decode = jax.jit(steps_lib.make_decode_step(model, policy=policy))

        t0 = time.time()
        logits, cache = prefill(params, {"tokens": jnp.asarray(toks)})
        cache = pad_cache_to(cache, args.prompt_len, s_max, None)
        # NOTE: right-padding means padded rows' last-token logits come from
        # pad positions; real serving uses per-row gather — we re-score row
        # ends during the first decode steps, which is exact for generation.
        t_prefill = time.time() - t0

        out = [list(p) for p in prompts]
        cur = jnp.asarray(toks[np.arange(b), lens - 1])      # last real token
        lengths = jnp.asarray(lens)
        alive = np.ones(b, bool)
        t0 = time.time()
        steps = 0
        while alive.any() and steps < args.max_new + args.prompt_len:
            nxt, logits, cache = decode(
                params, {"token": cur, "lengths": lengths}, cache)
            nxt_np = np.asarray(nxt)
            for i in range(b):
                if alive[i] and len(out[i]) < len(prompts[i]) + args.max_new:
                    out[i].append(int(nxt_np[i]))
                elif alive[i]:
                    alive[i] = False
            cur = nxt
            lengths = lengths + 1
            steps += 1
        t_decode = time.time() - t0

    toks_out = sum(len(o) - len(p) for o, p in zip(out, prompts))
    print(f"impl={cfg.attn_impl} policy={args.policy_mode} "
          f"mesh={dict(mesh.shape)}")
    print(f"prefill {t_prefill*1e3:.0f} ms; decode {toks_out} tokens in "
          f"{t_decode*1e3:.0f} ms "
          f"({toks_out / max(t_decode, 1e-9):.1f} tok/s batched)")
    for i, o in enumerate(out[:4]):
        print(f"req{i}: prompt={o[:len(prompts[i])][:8]}... "
              f"gen={o[len(prompts[i]):][:8]}...")
    return out


if __name__ == "__main__":
    main()
