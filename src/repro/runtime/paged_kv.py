"""Paged KV cache + block-table decode attention for continuous batching.

Serving real mixed-length traffic is the paper's *irregular* access pattern
as a system: decode attention over a paged KV cache is an indirect,
block-table-addressed gather, not a contiguous scan. This module rebuilds
the serving cache around fixed-size KV blocks (pages):

  * :class:`BlockAllocator` / :class:`PagedKVCache` — a host-side free-list
    allocator over a device-resident block pool
    ``[L, n_blocks, 2, page, KVH, hd]`` (axis 2: k=0 / v=1), with
    per-request block tables. Admission reserves ``ceil(prompt+max_new /
    page)`` blocks; retirement recycles them, so KV memory scales with the
    *live* token count instead of ``B * S_max``.
  * :func:`gather_indices` — flattens a block table into the row-index
    stream an ``ff_gather`` producer walks: word ``w = (b*KVH + h)*n_pages
    + kj`` covers page ``kj``'s K rows then its V rows for one kv head.
  * ``paged_decode_attention`` StreamGraph — the registered two-node graph
    (block-table gather producer → online-softmax decode-attention
    consumer). The gather bundles ``2*page`` row DMAs per word, its
    ``(2*page, d)`` out blocks line up word-for-word with the consumer's
    kv pipe, and ``check_fusion`` legalizes the edge with wpb=1: the
    gathered pages stream through a VMEM ring and never round-trip HBM.
    Tuned jointly via :func:`repro.core.autotune.resolve_graph`.

The consumer's softmax math is identical to the contiguous
``ff_decode_attention`` kernel at ``block_kv == page`` (same tile order,
same f32 accumulation), so paged decode is *bitwise-equal* to the
contiguous-cache path — rows past ``length`` (zero fill or stale recycled
block contents) mask to ``-1e30`` and their ``exp`` underflows to exactly
0.0. ``tests/test_serving_paged.py`` asserts this.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune
from repro.core.program import current_policy


# ---------------------------------------------------------------------------
# Block-table -> gather-row indexing
# ---------------------------------------------------------------------------


def gather_indices(block_tables, *, page: int, kv_heads: int,
                   n_blocks: int) -> jnp.ndarray:
    """Row indices into the row-flattened pool ``[nb*2*page*KVH, hd]`` for
    one decode step.

    ``block_tables``: [B, n_pages] int32 (entries >= ``n_blocks`` are
    sentinels for unallocated pages; they clip to a real row and the
    consumer's length mask discards whatever they fetch). Returns the
    [B*KVH*n_pages*2*page] index stream in ``ff_gather`` word order:
    word ``(b*KVH + h)*n_pages + kj`` reads page ``kj``'s K rows
    (offsets 0..page-1) then its V rows.
    """
    bt = jnp.clip(jnp.asarray(block_tables, jnp.int32), 0, n_blocks - 1)
    which = jnp.arange(2, dtype=jnp.int32)
    off = jnp.arange(page, dtype=jnp.int32)
    heads = jnp.arange(kv_heads, dtype=jnp.int32)
    # [B, KVH, n_pages, 2, page]: row = ((blk*2 + which)*page + off)*KVH + h
    rows = ((bt[:, None, :, None, None] * 2
             + which[None, None, None, :, None]) * page
            + off[None, None, None, None, :]) * kv_heads \
        + heads[None, :, None, None, None]
    return rows.reshape(-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Pure-jnp oracle
# ---------------------------------------------------------------------------


def paged_decode_attention_ref(q, kv_pool, block_tables, lengths):
    """XLA oracle: dereference the block table densely, then masked softmax.
    q: [B, H, d]; kv_pool: [nb, 2, page, KVH, d]; block_tables: [B, n_pages];
    lengths: [B]. Returns [B, H, d] (zeros for length-0 rows)."""
    b, h, d = q.shape
    nb, _, page, kvh, _ = kv_pool.shape
    npg = block_tables.shape[-1]
    group = h // kvh
    bt = jnp.clip(jnp.asarray(block_tables, jnp.int32), 0, nb - 1)
    kv = kv_pool[bt]                     # [B, n_pages, 2, page, KVH, d]
    k = kv[:, :, 0].reshape(b, npg * page, kvh, d).transpose(0, 2, 1, 3)
    v = kv[:, :, 1].reshape(b, npg * page, kvh, d).transpose(0, 2, 1, 3)
    qg = q.reshape(b, kvh, group, d).astype(jnp.float32)
    s_ = jnp.einsum("bhgd,bhsd->bhgs", qg,
                    k.astype(jnp.float32)) * (1.0 / (d ** 0.5))
    cols = jnp.arange(npg * page)
    s_ = jnp.where(cols[None, None, None] < lengths[:, None, None, None],
                   s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    out = jnp.where(lengths[:, None, None, None] > 0, out, 0.0)
    return out.reshape(b, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# The two-node StreamGraph
# ---------------------------------------------------------------------------


def build_paged_decode_graph(*, b: int, kvh: int, g_pad: int, n_pages: int,
                             page: int, d: int, dtype=jnp.float32,
                             kv_dtype=None, depth: int = 2,
                             streams: int = 1):
    """Declare the paged-decode StreamGraph at one shape point: an
    ``ff_gather`` producer walking the block-table row stream feeding the
    paged online-softmax consumer through a fusable ``(2*page, d)`` edge.

    The gather's row bundle is pinned to ``2*page`` rows per word (one
    merged K+V page) so its out blocks coincide with the consumer's kv
    words — the geometry ``check_fusion`` needs for wpb=1.
    """
    from repro.core.graph import GraphEdge, GraphNode, StreamGraph
    from repro.kernels.ff_decode_attention.kernel import build_paged_program
    from repro.kernels.ff_decode_attention.ops import \
        paged_decode_attention_workload
    from repro.kernels.ff_gather.kernel import _ROWS
    from repro.kernels.ff_gather.kernel import build_program as gather_prog
    from repro.kernels.ff_gather.ops import gather_workload

    kv_dtype = kv_dtype or dtype
    assert (2 * page) % _ROWS == 0, (page, _ROWS)
    n_rows = b * kvh * n_pages * 2 * page
    gather = gather_prog(n_rows, d, dtype=kv_dtype, depth=depth,
                         streams=(2 * page) // _ROWS)
    attn = build_paged_program(b, kvh, g_pad, n_pages, page, d, dtype=dtype,
                               kv_dtype=kv_dtype, depth=depth,
                               streams=streams)
    w_g, t_g = gather_workload(n_rows, d, dtype=kv_dtype)
    w_a, t_a = paged_decode_attention_workload(
        b, kvh * g_pad, kvh, n_pages, page, d, dtype=kv_dtype)
    return StreamGraph(
        name="paged_decode_attention",
        nodes=(
            GraphNode("gather", gather, workload=w_g, plan_tile=t_g),
            GraphNode("attn", attn, workload=w_a, plan_tile=t_a),
        ),
        edges=(
            GraphEdge("gather", "attn", "kv"),
        ),
    )


def paged_decode_attention(q, kv_pool, block_tables, lengths, *,
                           policy=None) -> jnp.ndarray:
    """Decode attention for one new token through the block table.

    q: [B, H, d]; kv_pool: [n_blocks, 2, page, KVH, d] (one layer's pool);
    block_tables: [B, n_pages] int32; lengths: [B] int32 (0 = inactive
    slot). Returns [B, H, d].
    """
    policy = current_policy() if policy is None else policy
    b, h, d = q.shape
    nb, _, page, kvh, _ = kv_pool.shape
    n_pages = block_tables.shape[-1]
    assert h % kvh == 0, (h, kvh)
    group = h // kvh
    lens = lengths.astype(jnp.int32)
    if policy.mode == "ref":
        return paged_decode_attention_ref(q, kv_pool, block_tables, lens)
    g_pad = -(-group // 8) * 8
    qg = q.reshape(b, kvh, group, d)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - group), (0, 0)))
    idx = gather_indices(block_tables, page=page, kv_heads=kvh, n_blocks=nb)
    table = kv_pool.reshape(nb * 2 * page * kvh, d)

    def build(depth=2, streams=1):
        return build_paged_decode_graph(
            b=b, kvh=kvh, g_pad=g_pad, n_pages=n_pages, page=page, d=d,
            dtype=qg.dtype, kv_dtype=kv_pool.dtype, depth=depth,
            streams=streams)

    from repro.core import graph as graphlib
    g0 = build()
    w, tile = graphlib.graph_workload(g0)
    sig = graphlib.graph_signature(g0)

    def runner(tk, depth, streams):
        cg = graphlib.compile_graph(
            build(depth=depth, streams=streams),
            policy=policy.replace(mode="ff", depth=depth, streams=streams))
        return lambda: cg(idx, table, lens, qg)

    choice = autotune.resolve_graph(
        "paged_decode_attention", policy, workload=w, tile=tile,
        dtype=kv_pool.dtype, signature=sig,
        workload_fn=lambda tk: graphlib.graph_workload(build()),
        runner=None if autotune.has_tracers(q, kv_pool, block_tables, lens)
        else runner,
        site={"b": b, "h": h, "kvh": kvh, "n_pages": n_pages, "page": page,
              "d": d, "n_blocks": nb, "q_dtype": str(q.dtype)},
        site_dynamic=("b", "n_pages", "n_blocks"))
    # compiled fresh per call: the graph closure may capture trace-scoped
    # constants, so it must never be reused across jit traces (the outer
    # jitted decode step already amortizes the rebuild)
    mode = "ff" if policy.mode == "autotune" else policy.mode
    cg = graphlib.compile_graph(
        build(depth=choice.depth, streams=choice.streams),
        policy=policy.replace(mode=mode, depth=choice.depth,
                              streams=choice.streams))
    out = cg(idx, table, lens, qg)
    return out[:, :, :group, :].reshape(b, h, d)


# ---------------------------------------------------------------------------
# Device-side scatter helpers (prefill admission, per-step token append)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("page", "n_blocks"))
def scatter_prefill(pool, k, v, block_tables, lengths, *, page: int,
                    n_blocks: int):
    """Write prefill KV into the pool through the block tables.

    pool: [L, nb, 2, page, KVH, hd]; k, v: [L, B, S_p, KVH, hd];
    block_tables: [B, n_pages]; lengths: [B]. Positions past ``lengths``
    route to the sentinel block id ``n_blocks`` and drop.
    """
    s_p = k.shape[2]
    pos = jnp.arange(s_p)
    bt = jnp.asarray(block_tables, jnp.int32)
    blk = bt[:, jnp.clip(pos // page, 0, bt.shape[1] - 1)]     # [B, S_p]
    blk = jnp.where(pos[None] < lengths[:, None], blk, n_blocks)
    off = jnp.broadcast_to(pos % page, blk.shape)
    pool = pool.at[:, blk, 0, off].set(k, mode="drop")
    pool = pool.at[:, blk, 1, off].set(v, mode="drop")
    return pool


def scatter_token(pool_layer, block_tables, lengths, k_new, v_new,
                  n_blocks: int):
    """Append one token's K/V at position ``lengths`` (per row) into one
    layer's pool. pool_layer: [nb, 2, page, KVH, hd]; k_new, v_new:
    [B, KVH, hd]. Sentinel table entries (>= n_blocks) drop the write."""
    page = pool_layer.shape[2]
    b = k_new.shape[0]
    bt = jnp.asarray(block_tables, jnp.int32)
    blk = bt[jnp.arange(b), jnp.clip(lengths // page, 0, bt.shape[1] - 1)]
    off = lengths % page
    pool_layer = pool_layer.at[blk, 0, off].set(k_new, mode="drop")
    pool_layer = pool_layer.at[blk, 1, off].set(v_new, mode="drop")
    return pool_layer


# ---------------------------------------------------------------------------
# Host-side allocator + cache
# ---------------------------------------------------------------------------


class OutOfBlocks(RuntimeError):
    """Raised when an admission asks for more KV blocks than are free."""


class BlockAllocator:
    """LIFO free-list allocator over ``n_blocks`` page-sized KV blocks.

    LIFO recycling keeps the hot end of the pool dense: freshly retired
    blocks are reissued first, so the working set stays compact regardless
    of retirement order (external fragmentation is impossible — any
    ``k <= len(free)`` allocation succeeds; the only waste is *internal*:
    at most ``page - 1`` unused rows in each request's last block).
    """

    def __init__(self, n_blocks: int):
        self.n_blocks = int(n_blocks)
        self._free: List[int] = list(range(self.n_blocks - 1, -1, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> List[int]:
        """Pop ``n`` block ids, or raise :class:`OutOfBlocks` leaving the
        free list untouched (admission is all-or-nothing)."""
        if n > len(self._free):
            raise OutOfBlocks(
                f"need {n} KV blocks, {len(self._free)} free "
                f"(pool has {self.n_blocks})")
        ids = [self._free.pop() for _ in range(n)]
        return ids

    def free(self, ids) -> None:
        for i in ids:
            self._free.append(int(i))


class PagedKVCache:
    """Device-resident paged KV pool + host-side slot/block bookkeeping.

    The pool is one array ``[L, n_blocks, 2, page, KVH, hd]`` shared by all
    decode slots; each slot owns a block table (host list of block ids).
    ``device_state()`` materializes the per-layer view the model consumes:
    ``{"kv_pool": [L, nb, 2, page, KVH, hd], "block_tables": [L, B, n_pages],
    "lengths": unused-by-model}``. Unallocated table entries hold the
    sentinel id ``n_blocks`` (scatters drop, gathers clip + mask).
    """

    def __init__(self, *, n_layers: int, n_blocks: int, page: int,
                 kv_heads: int, head_dim: int, n_slots: int,
                 n_pages_max: int, dtype=jnp.float32):
        self.n_layers = n_layers
        self.n_blocks = n_blocks
        self.page = page
        self.kv_heads = kv_heads
        self.head_dim = head_dim
        self.n_slots = n_slots
        self.n_pages_max = n_pages_max
        self.pool = jnp.zeros(
            (n_layers, n_blocks, 2, page, kv_heads, head_dim), dtype)
        self.allocator = BlockAllocator(n_blocks)
        # host bookkeeping: per-slot block ids / lengths (sentinel-filled)
        self._tables = np.full((n_slots, n_pages_max), n_blocks, np.int32)
        self._owned: List[List[int]] = [[] for _ in range(n_slots)]
        self.lengths = np.zeros((n_slots,), np.int32)
        self._live_tokens = 0

    # -- admission / retirement ---------------------------------------------

    def admit(self, slot: int, k_seq, v_seq, length: int,
              reserve_tokens: int) -> None:
        """Claim ``ceil(reserve_tokens / page)`` blocks for ``slot`` and
        scatter the prompt KV (``k_seq``/``v_seq``: [L, S_p, KVH, hd],
        valid prefix ``length``). Raises :class:`OutOfBlocks` atomically
        (no partial allocation) when the pool cannot hold the reservation.
        """
        assert not self._owned[slot], f"slot {slot} already occupied"
        n_pages = -(-int(reserve_tokens) // self.page)
        if n_pages > self.n_pages_max:
            raise ValueError(
                f"reservation {reserve_tokens} tokens = {n_pages} pages "
                f"exceeds n_pages_max={self.n_pages_max}")
        ids = self.allocator.alloc(n_pages)
        self._owned[slot] = ids
        self._tables[slot, :] = self.n_blocks
        self._tables[slot, :n_pages] = ids
        self.lengths[slot] = length
        self._live_tokens += int(length)
        bt = jnp.asarray(self._tables[slot:slot + 1])
        lens = jnp.asarray([length], jnp.int32)
        self.pool = scatter_prefill(
            self.pool, k_seq[:, None], v_seq[:, None], bt, lens,
            page=self.page, n_blocks=self.n_blocks)

    def append(self, n_per_slot) -> None:
        """Host bookkeeping after a decode step appended tokens on device:
        bump lengths for the slots that wrote (device scatter already
        happened inside the jitted step)."""
        self.lengths = self.lengths + np.asarray(n_per_slot, np.int32)
        self._live_tokens += int(np.sum(n_per_slot))

    def retire(self, slot: int) -> None:
        """Free ``slot``'s blocks back to the pool."""
        self._live_tokens -= int(self.lengths[slot])
        self.allocator.free(self._owned[slot])
        self._owned[slot] = []
        self._tables[slot, :] = self.n_blocks
        self.lengths[slot] = 0

    # -- device views --------------------------------------------------------

    def device_tables(self) -> jnp.ndarray:
        """Block tables broadcast over layers: [L, n_slots, n_pages_max]
        (every layer shares one table — the pool's L axis separates them).
        """
        bt = jnp.asarray(self._tables)
        return jnp.broadcast_to(bt, (self.n_layers, *bt.shape))

    def cache_view(self) -> Dict[str, jnp.ndarray]:
        """The paged decode cache pytree ``attn_apply`` consumes (leading
        L axis on every leaf, matching the scanned layer stack)."""
        return {"kv_pool": self.pool, "block_tables": self.device_tables()}

    def update_pool(self, new_pool) -> None:
        self.pool = new_pool

    # -- metrics -------------------------------------------------------------

    def utilization(self) -> Dict[str, float]:
        """KV-memory utilization: live tokens vs. allocated block capacity
        vs. whole-pool capacity."""
        alloc_blocks = self.n_blocks - self.allocator.n_free
        alloc_tokens = alloc_blocks * self.page
        pool_tokens = self.n_blocks * self.page
        return {
            "live_tokens": float(self._live_tokens),
            "allocated_tokens": float(alloc_tokens),
            "pool_tokens": float(pool_tokens),
            "util_vs_allocated": (self._live_tokens / alloc_tokens
                                  if alloc_tokens else 0.0),
            "util_vs_pool": self._live_tokens / pool_tokens,
        }


# ---------------------------------------------------------------------------
# Graph registration (smoke point for BENCH_graph / test_graphs)
# ---------------------------------------------------------------------------

# b=2 kv_heads=2 g_pad=8 n_pages=4 page=16 d=64 over a 12-block pool;
# block tables drawn from a permutation so the gather is genuinely
# non-contiguous, lengths mixed (one partial page, one full table)
_SMOKE = dict(b=2, kvh=2, g_pad=8, n_pages=4, page=16, d=64, nb=12)


def _paged_build(*, depth: int = 2, streams: int = 1):
    c = _SMOKE
    return build_paged_decode_graph(
        b=c["b"], kvh=c["kvh"], g_pad=c["g_pad"], n_pages=c["n_pages"],
        page=c["page"], d=c["d"], dtype=jnp.float32, depth=depth,
        streams=streams)


def _paged_inputs(key):
    """Operands in CompiledGraph.arg_names order:
    (gather.idx, gather.table, attn.lengths, attn.q)."""
    c = _SMOKE
    n_rows = c["nb"] * 2 * c["page"] * c["kvh"]
    table = jax.random.normal(key, (n_rows, c["d"]), jnp.float32)
    perm = jax.random.permutation(
        jax.random.fold_in(key, 1), c["nb"])[:c["b"] * c["n_pages"]]
    bt = perm.reshape(c["b"], c["n_pages"]).astype(jnp.int32)
    idx = gather_indices(bt, page=c["page"], kv_heads=c["kvh"],
                         n_blocks=c["nb"])
    lens = jnp.array([37, c["n_pages"] * c["page"]], jnp.int32)
    q = 0.3 * jax.random.normal(jax.random.fold_in(key, 2),
                                (c["b"], c["kvh"], c["g_pad"], c["d"]),
                                jnp.float32)
    return (idx, table, lens, q)


def _paged_ref(idx, table, lengths, q):
    """Masked-softmax oracle over the gathered row stream."""
    c = _SMOKE
    b, kvh, g_pad, d = q.shape
    s = c["n_pages"] * c["page"]
    kv = table[idx].reshape(b, kvh, c["n_pages"], 2, c["page"], d)
    k = kv[:, :, :, 0].reshape(b, kvh, s, d)
    v = kv[:, :, :, 1].reshape(b, kvh, s, d)
    s_ = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * (1.0 / (d ** 0.5))
    cols = jnp.arange(s)
    s_ = jnp.where(cols[None, None, None] < lengths[:, None, None, None],
                   s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _paged_unfused(idx, table, lengths, q):
    """Gather then decode-attention as two separate repro.ops calls — the
    gathered [n_rows, d] page stream round-trips HBM (the BENCH_graph
    staged baseline). block_kv is pinned to the page size so the
    comparison isolates the lowering, not the tiling."""
    import repro

    c = _SMOKE
    b, kvh, g_pad, d = q.shape
    s = c["n_pages"] * c["page"]
    rows = repro.ops.gather(table, idx)
    kv = rows.reshape(b, kvh, c["n_pages"], 2, c["page"], d)
    k = kv[:, :, :, 0].reshape(b, kvh, s, d)
    v = kv[:, :, :, 1].reshape(b, kvh, s, d)
    out = repro.ops.decode_attention(
        q.reshape(b, kvh * g_pad, d), k, v, lengths, block_kv=c["page"])
    return out.reshape(b, kvh, g_pad, d)


def _paged_sweep_inputs(key, site):
    """Rebuild paged_decode_attention operands at a recorded call-site
    shape (plan sweep). ``dtype`` is the resolve dtype (the KV pool's);
    ``q_dtype`` rides along in the recorded site dict."""
    b, h, kvh = int(site["b"]), int(site["h"]), int(site["kvh"])
    n_pages, page = int(site["n_pages"]), int(site["page"])
    d, nb = int(site["d"]), int(site["n_blocks"])
    kv_dt = jnp.dtype(site.get("dtype", "float32"))
    q_dt = jnp.dtype(site.get("q_dtype", "float32"))
    q = 0.3 * jax.random.normal(key, (b, h, d), q_dt)
    pool = jax.random.normal(jax.random.fold_in(key, 1),
                             (nb, 2, page, kvh, d), kv_dt)
    bt = (jax.random.permutation(jax.random.fold_in(key, 2),
                                 max(nb, b * n_pages))[:b * n_pages]
          % nb).reshape(b, n_pages).astype(jnp.int32)
    lens = jnp.full((b,), n_pages * page, jnp.int32)
    return (q, pool, bt, lens), {}


def _register_paged_graph():
    from repro.kernels.registry import register_graph

    register_graph(
        name="paged_decode_attention",
        build=_paged_build,
        make_inputs=_paged_inputs,
        ref=_paged_ref,
        unfused=_paged_unfused,
        # no tile candidates: the page size is the pool's storage layout,
        # not a per-call knob — the joint tuner still searches (depth,
        # streams) for the fused pair
        tile_options=(),
        tol=2e-4,
        doc="block-table KV page gather -> paged decode attention; the "
            "gathered pages stream through a VMEM ring (continuous-"
            "batching serving's irregular decode path)",
        # plan-service sweep: resolve at call-site shapes through the real
        # entrypoint, not run_graph's fixed smoke point
        op=paged_decode_attention,
        sweep_inputs=_paged_sweep_inputs,
    )


_register_paged_graph()
