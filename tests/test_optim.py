"""Optimizers + gradient compression: convergence on a quadratic, clipping,
schedule shape, int8 error-feedback bounds (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim import adafactor, adamw
from repro.optim.compression import (
    QuantizedAccumulator,
    dequantize,
    quantize,
)


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizers_descend_quadratic(opt):
    params = {"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))}
    if opt == "adamw":
        cfg = adamw.AdamWConfig(lr_peak=0.1, warmup_steps=5, total_steps=200,
                                weight_decay=0.0)
        state = adamw.init(params)
        upd = lambda g, s, p: adamw.update(cfg, g, s, p)
    else:
        cfg = adafactor.AdafactorConfig(lr_peak=0.5, warmup_steps=5,
                                        total_steps=200)
        state = adafactor.init(params)
        upd = lambda g, s, p: adafactor.update(cfg, g, s, p)
    l0 = float(quad_loss(params))
    for _ in range(200):
        g = jax.grad(quad_loss)(params)
        params, state, _ = upd(g, state, params)
    l1 = float(quad_loss(params))
    assert l1 < 0.05 * l0, (opt, l0, l1)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0 * np.sqrt(10), rel=1e-5)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert lrs[10] == pytest.approx(1e-3, rel=1e-3)
    assert max(lrs) <= 1e-3 * 1.001
    assert lrs[100] == pytest.approx(1e-4, rel=1e-2)
    assert all(b <= a * 1.001 for a, b in zip(lrs[10:], lrs[11:]))


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=8,
                max_size=64))
@settings(max_examples=100, deadline=None)
def test_quantize_roundtrip_bound(vals):
    x = jnp.asarray(vals, jnp.float32).reshape(-1)
    q, s = quantize(x)
    err = np.max(np.abs(np.asarray(dequantize(q, s)) - np.asarray(x)))
    bound = max(np.max(np.abs(np.asarray(x))) / 127.0, 1e-6)
    assert err <= bound * 0.5 + 1e-6      # round-to-nearest: half a step


def test_error_feedback_unbiased_over_steps():
    """Sum of decoded accumulator tracks the true sum: error feedback keeps
    the residual bounded by one quantization step, not O(n_steps)."""
    key = jax.random.key(0)
    params = {"w": jnp.zeros((32, 32))}
    acc = QuantizedAccumulator.init(params)
    total = jnp.zeros((32, 32))
    for i in range(50):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (32, 32))}
        acc = QuantizedAccumulator.add(acc, g)
        total = total + g["w"]
    decoded = QuantizedAccumulator.read(acc)["w"]
    err = float(jnp.max(jnp.abs(decoded - total)))
    step_bound = float(jnp.max(jnp.abs(total))) / 127.0 + \
        float(jnp.max(jnp.abs(decoded - total)) * 0)  # one-step bound
    assert err <= 2.0 * (float(jnp.max(jnp.abs(total))) / 127.0) + 1e-4, err


def test_quantized_accum_in_train_step():
    """steps.make_train_step(quantized_accum=True) trains (loss decreases)."""
    from repro.configs.base import smoke_config
    from repro.launch import steps as steps_lib
    from repro.models import build_model

    cfg = smoke_config("qwen1_5_0p5b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt_cfg = adamw.AdamWConfig(lr_peak=5e-3, warmup_steps=5, total_steps=80)
    step = jax.jit(steps_lib.make_train_step(
        model, opt_cfg=opt_cfg, accum_steps=2, quantized_accum=True))
    opt_state = adamw.init(params)
    from repro.data import SyntheticSpec, batch_at
    spec = SyntheticSpec(vocab=cfg.vocab, seq_len=32, global_batch=4)
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in batch_at(spec, i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses[:5]
