"""Pipeline parallelism over the ``pod`` axis: the paper's pipes at pod
scale, expressed as a Stream producer/consumer schedule.

GPipe-style schedule under shard_map: each pod holds a contiguous stage of
layers; activations flow stage->stage through a :class:`StageHandoff` —
the pod-scale analogue of a *staged* :class:`repro.core.graph.GraphEdge`
(the intermediate leaves the producer stage, crosses the interconnect, and
lands in the consumer stage's buffer; one microbatch per pipe word). With
M microbatches and S stages the bubble is (S-1)/(M+S-1) — the driver picks
M >= 4*S.

Each tick runs the same acquire → consume → release word schedule the
kernel emitter runs (:mod:`repro.core.emitter`):

* **acquire** — select this stage's input word for tick ``t`` (stage 0
  reads microbatch ``t`` from the feed; later stages read the handoff
  buffer their upstream released last tick);
* **consume** — ``stage_fn`` computes on the word. A ``policy`` threads
  the mesh-tagged session :class:`~repro.core.program.PipePolicy` around
  the stage body, so stream kernels inside the stage plan at local shard
  shapes with topology-keyed caches;
* **release** — push the output one hop down the ring
  (:meth:`StageHandoff.push`) while the next tick's compute proceeds —
  compute/comm overlap identical in shape to the kernel DAE schedule.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.runtime.collectives import axis_size


@dataclasses.dataclass(frozen=True)
class StageHandoff:
    """The inter-stage pipe: a staged GraphEdge across the mesh axis.

    ``push`` is the release step of the word schedule — it moves every
    stage's freshly produced word to its successor's buffer (stage s ->
    s+1; the last stage's word leaves the pipeline and is banked by the
    caller). Double-buffering falls out of the schedule: the ppermute of
    tick t is in flight while tick t+1's compute runs.
    """

    axis_name: str

    def n_stages(self) -> int:
        return axis_size(self.axis_name)

    def stage(self):
        return jax.lax.axis_index(self.axis_name)

    def push(self, y: jnp.ndarray) -> jnp.ndarray:
        perm = [(i, i + 1) for i in range(self.n_stages() - 1)]
        return jax.lax.ppermute(y, self.axis_name, perm)


def pipeline_apply(stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                   stage_params: Any,
                   microbatches: jnp.ndarray,
                   axis_name: str,
                   policy=None) -> jnp.ndarray:
    """Run a GPipe pipeline under shard_map.

    stage_fn(params, x) -> x           one stage's forward
    stage_params                       this device's stage params (sharded)
    microbatches: [M, mb, ...]         this *pipeline's* input, replicated
                                       (stage 0 consumes them in order)
    policy                             optional PipePolicy installed (mesh-
                                       tagged) around the stage body, so
                                       stream kernels inside it plan per
                                       shard with topology-keyed caches
    Returns [M, mb, ...] final-stage outputs (valid on the last stage;
    replicated back by the caller if needed).
    """
    pipe = StageHandoff(axis_name)
    n_stage = pipe.n_stages()
    stage = pipe.stage()
    m = microbatches.shape[0]
    ticks = m + n_stage - 1

    if policy is not None:
        from repro.core.program import policy as policy_ctx
        from repro.runtime.streams import mesh_policy
        pol = mesh_policy(policy)

        def consume(p, x):
            with policy_ctx(pol):
                return stage_fn(p, x)
    else:
        consume = stage_fn

    buf = jnp.zeros_like(microbatches[0])     # this stage's handoff slot
    outs = jnp.zeros_like(microbatches)

    def tick(t, carry):
        buf, outs = carry
        mb_idx = t - stage                    # word at this stage this tick
        # -- acquire: stage 0 pulls from the feed, others from the handoff
        feed = jax.lax.dynamic_index_in_dim(
            microbatches, jnp.clip(t, 0, m - 1), keepdims=False)
        x_in = jnp.where(stage == 0, feed, buf)
        active = (mb_idx >= 0) & (mb_idx < m)
        # -- consume: the stage's compute kernel
        y = consume(stage_params, x_in)
        y = jnp.where(active, y, buf)
        # -- release: last stage banks its word; others push it one hop
        outs = jax.lax.cond(
            active & (stage == n_stage - 1),
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, y, jnp.clip(mb_idx, 0, m - 1), 0),
            lambda o: o, outs)
        buf = pipe.push(y)
        return buf, outs

    _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
    return outs
