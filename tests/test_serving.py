"""Prefill->decode consistency: for every family, incremental decode with a
cache must reproduce the logits of the full (teacher-forced) forward pass.
This is the strictest cache-correctness test: any off-by-one in lengths,
positions, token shift, or state carry fails it."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.models import build_model

KEY = jax.random.key(7)

# Prompt length chosen to avoid colliding with any other dim in the smoke
# configs (so cache padding by shape match stays unambiguous).
PROMPT, TOTAL = 24, 29


def pad_cache_seq(cache, s_from, s_to):
    def pad(x):
        for axis in range(x.ndim):
            if x.shape[axis] == s_from:
                pads = [(0, 0)] * x.ndim
                pads[axis] = (0, s_to - s_from)
                return jnp.pad(x, pads)
        return x
    return jax.tree.map(pad, cache)


def full_logits(model, params, batch):
    """Teacher-forced logits at every position via prefill of prefixes."""
    outs = []
    for t in range(PROMPT, TOTAL):
        b = dict(batch)
        b["tokens"] = batch["tokens"][:, :t]
        logits, _ = model.prefill(params, b)
        outs.append(logits)
    return jnp.stack(outs, axis=1)        # [B, TOTAL-PROMPT, V]


@pytest.mark.parametrize("arch_id", [
    "llama3_2_1b",            # dense + tied embeddings
    "qwen1_5_0p5b",           # dense MHA + bias
    "deepseek_v2_lite_16b",   # MLA + MoE
    "grok1_314b",             # MoE
    "zamba2_2p7b",            # hybrid mamba2 + shared attn
    "rwkv6_7b",               # rwkv6
    "whisper_tiny",           # enc-dec
    "internvl2_1b",           # vlm
])
def test_decode_matches_full_forward(arch_id):
    cfg = smoke_config(arch_id).replace(remat="none")
    model = build_model(cfg)
    params = model.init(KEY)
    b = 2
    tokens = jax.random.randint(KEY, (b, TOTAL), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.n_frames, cfg.d_model), cfg.cdtype)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            KEY, (b, cfg.n_patches, cfg.d_model), cfg.cdtype)

    ref = full_logits(model, params, batch)

    pre = dict(batch)
    pre["tokens"] = tokens[:, :PROMPT]
    logits, cache = model.prefill(params, pre)
    extra = cfg.n_patches if cfg.family == "vlm" else 0
    cache = pad_cache_seq(cache, PROMPT + extra, TOTAL + extra)
    got = [logits]
    lengths = jnp.full((b,), PROMPT + extra, jnp.int32)
    for t in range(PROMPT, TOTAL - 1):
        logits, cache = model.decode_step(
            params, {"token": tokens[:, t], "lengths": lengths}, cache)
        got.append(logits)
        lengths = lengths + 1
    got = jnp.stack(got, axis=1)

    # moderate tolerance: decode recomputes attention in a different order
    np.testing.assert_allclose(np.float32(got), np.float32(ref),
                               rtol=2e-2, atol=2e-2)
    # argmax agreement (what serving actually consumes)
    agree = np.mean(np.argmax(np.float32(got), -1) ==
                    np.argmax(np.float32(ref), -1))
    assert agree > 0.95, (arch_id, agree)
