"""Measured autotuner + planner-satellite tests.

Covers: PlanError (no bare asserts), skipped-candidate recording in
Plan.rationale, model monotonicity in depth, the planner's in-memory plan
cache, the autotuner's persistent on-disk plan cache (round-trip, fresh-
process reload without re-measuring, corrupt-file fallback), the analytic
fallback for unmeasurable call sites, and mode="autotune" end to end on a
real registry kernel.
"""

import dataclasses
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    TPU_V5E,
    Pipe,
    PipePolicy,
    PlanError,
    Workload,
    autotune,
    estimate_feedforward,
    plan_cache_clear,
    plan_cache_info,
    plan_pipe,
    planned_pipe,
)
from repro.core.autotune import (
    PLAN_FORMAT_VERSION,
    TunedChoice,
    resolve_call,
    tuned_cache_clear,
    tuning_config,
)

KEY = jax.random.key(3)

W_REGULAR = Workload(n_words=512, word_bytes=128 * 128 * 4.0,
                     flops_per_word=2.0 * 128 * 128 * 128, regular=True)
W_IRREGULAR = Workload(n_words=512, word_bytes=8 * 128 * 4.0,
                       flops_per_word=0.0, regular=False)
TILE = (128, 128)


@pytest.fixture
def plan_cache(tmp_path, monkeypatch):
    """Point the persistent plan cache at a tmpdir and start cold."""
    path = os.path.join(tmp_path, "plans.json")
    monkeypatch.setenv("REPRO_PLAN_CACHE", path)
    tuned_cache_clear()
    yield path
    tuned_cache_clear()


# ---------------------------------------------------------------------------
# Planner satellites: PlanError + skipped candidates
# ---------------------------------------------------------------------------

def test_plan_error_replaces_assert():
    with pytest.raises(PlanError) as ei:
        plan_pipe(W_REGULAR, TILE, jnp.float32, vmem_budget_bytes=64)
    err = ei.value
    assert isinstance(err, RuntimeError)      # catchable, not an assert
    assert err.workload == W_REGULAR
    assert err.vmem_budget_bytes == 64
    assert err.rejected and all("vmem" in r for r in err.rejected)
    assert "VMEM" in str(err)


def test_plan_records_skipped_candidates():
    plan = plan_pipe(W_REGULAR, TILE, jnp.float32,
                     stream_options=(1, 2, 3, 4))
    # streams=3 does not divide tile[0]=128: must be recorded, not silent
    assert any("streams=3" in s for s in plan.skipped)
    assert "skipped" in plan.rationale and "streams=3" in plan.rationale


def test_plan_without_skips_has_clean_rationale():
    plan = plan_pipe(W_REGULAR, TILE, jnp.float32, stream_options=(1, 2))
    assert plan.skipped == ()
    assert "skipped" not in plan.rationale


# ---------------------------------------------------------------------------
# Model monotonicity: deeper pipes never predict a slower steady state
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w", [W_REGULAR, W_IRREGULAR],
                         ids=["regular", "irregular"])
def test_deeper_pipes_never_slow_steady_state(w):
    """The paper's 'depth does not significantly affect performance':
    past depth=1, the modeled steady-state word time is non-increasing in
    depth (only the one-off fill grows)."""
    word_times = []
    for depth in range(2, 12):
        pipe = Pipe(tile=TILE, dtype=jnp.float32, depth=depth, streams=2)
        est = estimate_feedforward(w, TPU_V5E, pipe)
        word_times.append(max(est.t_mem_word_s, est.t_comp_word_s))
    for shallow, deep in zip(word_times, word_times[1:]):
        assert deep <= shallow * (1 + 1e-12)


# ---------------------------------------------------------------------------
# Planner plan cache: hits on repeated call sites
# ---------------------------------------------------------------------------

def test_plan_cache_hits_on_repeat_call_sites():
    plan_cache_clear()
    p1 = planned_pipe("ff_test_cache", W_REGULAR, TILE, jnp.float32)
    misses = plan_cache_info().misses
    p2 = planned_pipe("ff_test_cache", W_REGULAR, TILE, jnp.float32)
    info = plan_cache_info()
    assert p1 == p2
    assert info.hits >= 1 and info.misses == misses


# ---------------------------------------------------------------------------
# The measured tuner against a synthetic runner (no Pallas, no flakiness)
# ---------------------------------------------------------------------------

def _synthetic_runner(best=(3, 2)):
    """A runner whose 'kernel' is fastest at (depth, streams) == best."""
    def runner(tile_kwargs, depth, streams):
        cost = abs(depth - best[0]) + abs(streams - best[1])
        return lambda: jnp.float32(cost)
    return runner


def _fake_measure(monkeypatch, best=(3, 2)):
    """Deterministic stand-in for wall-clock timing."""
    def measure(fn, *, warmup=1, iters=3):
        return 1e-3 * (1.0 + float(fn()))
    monkeypatch.setattr(autotune, "measure", measure)


def _resolve(policy=None, runner="default", **kw):
    policy = policy or PipePolicy(mode="autotune")
    if runner == "default":
        runner = _synthetic_runner()
    return resolve_call(
        "ff_synth", policy, workload=W_REGULAR, tile=TILE,
        dtype=jnp.float32,
        workload_fn=lambda tk: (W_REGULAR, TILE), runner=runner, **kw)


def test_tuned_plan_is_measured_and_persisted(plan_cache, monkeypatch):
    _fake_measure(monkeypatch)
    choice = _resolve()
    assert choice.source == "measured"
    assert (choice.depth, choice.streams) == (3, 2)   # argmin of measurement
    # persisted: the on-disk record equals the returned choice
    plans = json.load(open(plan_cache))
    assert plans["format"] == PLAN_FORMAT_VERSION
    (rec,) = plans["plans"].values()
    assert (rec["depth"], rec["streams"]) == (3, 2)
    assert rec["measured_s"] is not None
    assert rec["analytic"]["measured_s"] is not None
    # tuned is argmin over a set containing the analytic config
    assert rec["measured_s"] <= rec["analytic"]["measured_s"]


def test_disk_cache_roundtrip_without_remeasuring(plan_cache, monkeypatch):
    _fake_measure(monkeypatch)
    tuned = _resolve()
    # fresh process: in-memory cache gone, disk cache present
    tuned_cache_clear()

    def exploding_runner(tile_kwargs, depth, streams):
        raise AssertionError("must not re-measure on a cache hit")

    monkeypatch.setattr(autotune, "measure", exploding_runner)
    again = _resolve(runner=exploding_runner)
    assert again.source == "disk"
    assert (again.depth, again.streams) == (tuned.depth, tuned.streams)
    # and the next lookup is served from memory
    assert _resolve(runner=exploding_runner).source == "memory"


def test_corrupt_cache_falls_back_to_analytic_with_warning(plan_cache):
    with open(plan_cache, "w") as f:
        f.write("{not json")
    with pytest.warns(RuntimeWarning, match="corrupt plan cache"):
        choice = _resolve(runner=None)    # unmeasurable call site
    assert choice.source == "analytic-fallback"
    # the analytic plan for this workload is what plan_pipe picks
    plan = plan_pipe(W_REGULAR, TILE, jnp.float32)
    assert (choice.depth, choice.streams) == (plan.pipe.depth,
                                              plan.pipe.streams)


def test_unmeasurable_call_site_warns_and_uses_analytic(plan_cache):
    autotune._warned_fallback_ops.clear()    # keyed by (op, plan_key)
    with pytest.warns(RuntimeWarning, match="not measurable"):
        choice = _resolve(runner=None)
    assert choice.source == "analytic-fallback"
    assert not os.path.exists(plan_cache)     # nothing persisted


def test_analytic_policies_bypass_the_tuner(plan_cache):
    choice = _resolve(policy=PipePolicy())    # depth/streams "auto"
    assert choice.source == "analytic"
    assert not os.path.exists(plan_cache)


def test_pinned_ints_survive_tuning(plan_cache, monkeypatch):
    _fake_measure(monkeypatch)
    choice = _resolve(policy=PipePolicy(mode="autotune", streams=1))
    assert choice.streams == 1                # explicit int is pinned
    assert choice.depth == 3                  # depth still measured


def test_auto_fields_stay_planner_sized_under_measured(plan_cache,
                                                       monkeypatch):
    """depth="measured", streams="auto": only depth is searched — "auto"
    keeps its documented planner-sized meaning and is pinned to the
    analytic resolution, even when another streams value measures faster."""
    def runner(tile_kwargs, depth, streams):
        cost = abs(depth - 3) + abs(streams - 4)    # fastest at streams=4
        return lambda: jnp.float32(cost)

    _fake_measure(monkeypatch)
    choice = _resolve(policy=PipePolicy(depth="measured", streams="auto"),
                      runner=runner)
    plan = plan_pipe(W_REGULAR, TILE, jnp.float32)
    assert choice.source == "measured"
    assert choice.streams == plan.pipe.streams    # planner's choice, pinned
    assert choice.depth == 3                      # measured argmin


def test_memory_cache_keyed_by_cache_path(tmp_path, monkeypatch):
    """Redirecting the plan cache mid-process must not serve plans tuned
    against the previously selected file from the in-memory front."""
    _fake_measure(monkeypatch)
    tuned_cache_clear()
    try:
        with tuning_config(cache_path=os.path.join(tmp_path, "a.json")):
            assert _resolve().source == "measured"
            assert _resolve().source == "memory"
        with tuning_config(cache_path=os.path.join(tmp_path, "b.json")):
            assert _resolve().source == "measured"    # not "memory"
    finally:
        tuned_cache_clear()


def test_wants_measured_semantics():
    assert autotune.wants_measured(PipePolicy(mode="autotune"))
    assert autotune.wants_measured(PipePolicy(depth="measured"))
    assert autotune.wants_measured(PipePolicy(streams="measured"))
    assert not autotune.wants_measured(PipePolicy())
    assert not autotune.wants_measured(
        PipePolicy(mode="baseline", depth="measured"))


def test_measured_policy_validates():
    p = PipePolicy(depth="measured", streams="measured")
    assert p.depth == "measured"
    with pytest.raises(ValueError, match="measured"):
        PipePolicy(depth="bogus")


# ---------------------------------------------------------------------------
# Mesh topology in the plan caches (PR 5: mesh-aware streams)
# ---------------------------------------------------------------------------

def test_planner_cache_keyed_by_mesh():
    """Same workload under a different mesh topology is a different plan
    cache entry (plans must never leak across topologies)."""
    from repro.core import MeshSpec, last_plan

    plan_cache_clear()
    m = MeshSpec(axes=(("data", 8),))
    p1 = planned_pipe("ff_mesh_key", W_REGULAR, TILE, jnp.float32)
    misses = plan_cache_info().misses
    p2 = planned_pipe("ff_mesh_key", W_REGULAR, TILE, jnp.float32, mesh=m)
    assert plan_cache_info().misses == misses + 1     # new key
    assert p1.pipe == p2.pipe                         # same analytic sizing
    assert p1.mesh.token == "single" and p2.mesh.token == "data8"
    assert last_plan("ff_mesh_key").mesh == m
    assert last_plan("ff_mesh_key").workload == W_REGULAR


def test_plan_key_carries_mesh_topology():
    from repro.core import MeshSpec

    m = MeshSpec(axes=(("data", 4), ("model", 2)))
    k_single = autotune.plan_key("op", W_REGULAR, jnp.float32, TPU_V5E)
    k_mesh = autotune.plan_key("op", W_REGULAR, jnp.float32, TPU_V5E,
                               mesh=m)
    assert k_single != k_mesh
    assert "meshsingle|dev1" in k_single
    assert "meshdata4.model2|dev8" in k_mesh
    assert f"fmt{PLAN_FORMAT_VERSION}" in k_mesh


def test_mesh_scopes_tuned_plan_cache(plan_cache, monkeypatch):
    """Tuned plans reload from disk under the same mesh but never serve a
    different topology (the staleness hazard the format bump closes)."""
    from repro.core import MeshSpec

    _fake_measure(monkeypatch)
    mesh8 = MeshSpec(axes=(("data", 8),))
    pol8 = PipePolicy(mode="autotune", mesh=mesh8)
    first = _resolve(policy=pol8)
    assert first.source == "measured"
    rec = json.load(open(plan_cache))
    assert all("meshdata8|dev8" in k for k in rec["plans"])
    (stored,) = rec["plans"].values()
    assert stored["mesh"] == "data8" and stored["devices"] == 8

    # fresh process, same mesh: disk hit, measurement must not run
    tuned_cache_clear()

    def exploding(*a, **k):
        raise AssertionError("same-mesh reload must not re-measure")

    monkeypatch.setattr(autotune, "measure", exploding)
    again = _resolve(policy=pol8)
    assert again.source == "disk"
    assert (again.depth, again.streams) == (first.depth, first.streams)

    # a different topology misses the cache and re-measures
    _fake_measure(monkeypatch)
    other = _resolve(policy=PipePolicy(mode="autotune",
                                       mesh=MeshSpec(axes=(("data", 4),))))
    assert other.source == "measured"


def test_old_format_cache_entries_fall_back_and_remeasure(plan_cache,
                                                          monkeypatch):
    """A v1-format plan file (pre-mesh keys) is ignored with a warning and
    replaced by freshly measured v2 records — stale plans never replay."""
    _fake_measure(monkeypatch)
    with open(plan_cache, "w") as f:
        json.dump({"format": PLAN_FORMAT_VERSION - 1,
                   "plans": {"stale-v1-key": {"depth": 9, "streams": 9}}}, f)
    with pytest.warns(RuntimeWarning, match="corrupt plan cache"):
        choice = _resolve()
    assert choice.source == "measured"
    assert (choice.depth, choice.streams) == (3, 2)   # measured, not stale
    plans = json.load(open(plan_cache))
    assert plans["format"] == PLAN_FORMAT_VERSION
    assert "stale-v1-key" not in plans["plans"]


# ---------------------------------------------------------------------------
# End to end on a real registry kernel (tiny shapes, interpret mode)
# ---------------------------------------------------------------------------

def test_autotune_mode_end_to_end(plan_cache):
    """mode="autotune" on ff_gather: correct output, plan measured and
    persisted, reload served from disk without re-measuring."""
    from repro.kernels.registry import get_kernel, run_smoke

    spec = get_kernel("ff_gather")
    with tuning_config(warmup=1, iters=1, top_k=2, budget_s=30):
        out, ref, err = run_smoke(spec, policy=PipePolicy(mode="autotune"))
    assert err <= spec.tol
    rec = autotune.last_record("ff_gather")
    assert rec["source"] == "measured"
    assert rec["measured_s"] <= rec["analytic"]["measured_s"]
    assert os.path.exists(plan_cache)

    # a "fresh process": reload from disk, measurement must not run
    tuned_cache_clear()
    with tuning_config(warmup=1, iters=1, top_k=2):
        orig_measure = autotune.measure

        def no_measure(*a, **k):
            raise AssertionError("reloaded plan must not re-measure")

        autotune.measure = no_measure
        try:
            out2, _, err2 = run_smoke(spec,
                                      policy=PipePolicy(mode="autotune"))
        finally:
            autotune.measure = orig_measure
    assert err2 <= spec.tol
    assert autotune.last_record("ff_gather")["source"] == "disk"
    np.testing.assert_array_equal(out, out2)


def test_registry_declares_tile_options():
    from repro.kernels.registry import all_kernels, get_kernel

    matmul = get_kernel("ff_matmul")
    assert matmul.tile_options, "matmul must declare tile candidates"
    # the program builder accepts each declared tile candidate
    for tk in matmul.tile_options:
        prog = matmul.program(depth=2, streams=1, tile=tk)
        assert prog.n_words >= 1
    for spec in all_kernels():
        prog = spec.program(depth=2, streams=1, tile=None)
        assert prog.name == spec.name


def test_compile_program_pipe_overrides():
    """compile_program resizes pipes per stream without re-declaring, and
    rejects overrides that would change the word geometry."""
    from repro.core import compile_program
    from repro.kernels.registry import get_kernel

    spec = get_kernel("ff_matmul")
    prog = spec.program(depth=2, streams=1)
    a = jax.random.normal(KEY, (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (256, 256),
                          jnp.float32)
    base = compile_program(prog)(a, b)
    deep = compile_program(
        prog, pipe_overrides={
            "a": dataclasses.replace(prog.streams[0].spec, depth=4,
                                     streams=2)})(a, b)
    np.testing.assert_allclose(np.float32(base), np.float32(deep),
                               atol=1e-5)
    with pytest.raises(KeyError, match="unknown stream"):
        compile_program(prog, pipe_overrides={"zzz": prog.streams[0].spec})
    with pytest.raises(ValueError, match="tile"):
        bad = Pipe(tile=(64, 64), dtype=jnp.float32, depth=2)
        compile_program(prog, pipe_overrides={"a": bad})
