"""qwen2-72b [dense] — GQA, QKV bias, SwiGLU, RMSNorm.
[arXiv:2407.10671; hf]  80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2_72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    rule_overrides={"kv_heads": None},   # 8 kv heads vs 16-way model axis
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    compute_dtype="float32",
)


# §Perf-winning preset (EXPERIMENTS.md hillclimb A): sequence-parallel
# residual saves + collective-saving remat. RF 0.129 -> 0.158.
OPTIMIZED = CONFIG.replace(
    remat="collectives",
    rule_overrides={**(CONFIG.rule_overrides or {}), "seq_sp": "model"},
)
