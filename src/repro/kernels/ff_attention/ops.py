"""Public op wrapper + cost model for ff_attention (prefill)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dae import cdiv, pad_to
from repro.kernels.ff_attention.kernel import flash_attention_ff
from repro.kernels.ff_attention.ref import attention_ref
from repro.kernels.ff_matmul.ops import KernelCost


def attention_cost(bh: int, s: int, d: int, *, causal: bool = True,
                   block_kv: int = 128, depth: int = 2,
                   dtype=jnp.bfloat16) -> KernelCost:
    """Exact stream costs for one prefill attention call (per the kernel's
    tile schedule). Causal halves the live score blocks."""
    frac = 0.5 if causal else 1.0
    flops = 4.0 * bh * s * s * d * frac            # qk^T and pv matmuls
    itemsize = jnp.dtype(dtype).itemsize
    nq = cdiv(s, 128)
    # K and V are re-streamed once per live q block; q,o move once.
    kv_stream = 2 * s * d * itemsize * nq * frac
    hbm = bh * (kv_stream + 2 * s * d * itemsize)
    vmem = 2 * depth * block_kv * d * itemsize + 128 * d * 4 * 3
    return KernelCost(flops=flops, hbm_bytes=float(hbm), vmem_bytes=vmem)


def attention(q, k, v, *, kv_groups: int = 1, causal: bool = True,
              block_q: int = 128, block_kv: int = 128, depth: int = 2,
              streams: int = 1, mode: str = "ff", interpret: bool = True):
    """Flash attention over [BH, S, D] tensors (wrapper pads S to blocks).

    mode="ff"|"baseline"(depth=1)|"ref".
    """
    if mode == "ref":
        return attention_ref(q, k, v, kv_groups=kv_groups, causal=causal)
    bh, s, d = q.shape
    skv = k.shape[1]
    qp = pad_to(q, block_q, 1)
    kp = pad_to(k, block_kv, 1)
    vp = pad_to(v, block_kv, 1)
    if kp.shape[1] > skv and not causal:
        raise ValueError(
            "non-causal attention requires Skv to be a block multiple "
            "(padded keys would receive softmax mass)")
    if mode == "baseline":
        depth = 1
    out = flash_attention_ff(
        qp, kp, vp, kv_groups=kv_groups, block_q=block_q, block_kv=block_kv,
        depth=depth, streams=streams, causal=causal, interpret=interpret)
    return out[:, :s, :]
