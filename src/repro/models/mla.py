"""Multi-head Latent Attention (deepseek-v2) mixer.

KV is compressed to a ``kv_lora_rank`` latent plus a single shared RoPE key;
the decode cache stores only ``[B, S, kv_lora + rope]`` — ~10x smaller than a
GQA cache at these dims. Paper mapping: the latent cache is a *small regular
stream* (the paper's favourable prefetching-LSU case); decode cells for
deepseek are the least memory-bound of the MoE archs in the roofline table.

Shapes (lite defaults): d=2048, H=16, kv_lora=512, nope=128, rope=64, v=128.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.runtime.sharding import constrain


def mla_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d, h = cfg.d_model, cfg.n_heads
    r, nope, rope_d, vd = (cfg.kv_lora_rank, cfg.qk_nope_dim,
                           cfg.qk_rope_dim, cfg.v_head_dim)
    return {
        "wq": L.ParamSpec((d, h, nope + rope_d), ("embed", "heads", None)),
        "wdkv": L.ParamSpec((d, r + rope_d), ("embed", None)),
        "kv_norm": L.norm_specs("rmsnorm", r),
        "wuk": L.ParamSpec((r, h, nope), (None, "heads", None)),
        "wuv": L.ParamSpec((r, h, vd), (None, "heads", None)),
        "wo": L.ParamSpec((h, vd, d), ("heads", None, "embed")),
    }


def _compress(cfg: ArchConfig, p, x):
    """x: [B,S,D] -> latent c_kv [B,S,r], k_rope [B,S,rope]."""
    dt = x.dtype
    ckv = x @ p["wdkv"].astype(dt)
    c, k_rope = jnp.split(ckv, [cfg.kv_lora_rank], axis=-1)
    c = L.rmsnorm(c, p["kv_norm"]["w"])
    return c, k_rope


def _decompress(cfg: ArchConfig, p, c, k_rope, positions):
    """latent -> per-head k [B,S,H,nope+rope], v [B,S,H,vd]."""
    dt = c.dtype
    k_nope = jnp.einsum("bsr,rhk->bshk", c, p["wuk"].astype(dt))
    v = jnp.einsum("bsr,rhk->bshk", c, p["wuv"].astype(dt))
    k_rope = L.rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    h = cfg.n_heads
    k_rope = jnp.broadcast_to(k_rope, (*k_nope.shape[:3], cfg.qk_rope_dim))
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    return k, v


def mla_apply(cfg: ArchConfig, p, x, *, positions, cache=None,
              lengths=None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = L.rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    if cache is None:
        c, k_rope = _compress(cfg, p, x)
        k, v = _decompress(cfg, p, c, k_rope, positions)
        q = constrain(q, ("batch", "seq", "heads", None))
        out = L.attention_op(q, k, v, causal=True, impl=cfg.attn_impl)
        new_cache = {"c": c, "k_rope": k_rope}
    else:
        c_new, k_rope_new = _compress(cfg, p, x)
        cc = jax.vmap(lambda cch, u, i: jax.lax.dynamic_update_slice_in_dim(
            cch, u, i, axis=0))(cache["c"], c_new, lengths)
        cr = jax.vmap(lambda cch, u, i: jax.lax.dynamic_update_slice_in_dim(
            cch, u, i, axis=0))(cache["k_rope"], k_rope_new, lengths)
        # decompress the whole cached latent stream (explicit form)
        s_max = cc.shape[1]
        pos = jnp.arange(s_max)[None, :]
        k, v = _decompress(cfg, p, cc, cr, pos)
        out = L.decode_attention_op(q[:, 0], k, v, lengths + 1,
                                    impl="xla")[:, None]
        new_cache = {"c": cc, "k_rope": cr}
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), new_cache


def mla_cache_spec(cfg: ArchConfig, batch: int, s_max: int):
    spec = {
        "c": jax.ShapeDtypeStruct((batch, s_max, cfg.kv_lora_rank), cfg.cdtype),
        "k_rope": jax.ShapeDtypeStruct((batch, s_max, cfg.qk_rope_dim),
                                       cfg.cdtype),
    }
    axes = {"c": ("batch", "kv", None), "k_rope": ("batch", "kv", None)}
    return spec, axes
