"""Microbenchmark sweep: explore the feed-forward design space (depth x
streams x access pattern x divergence) with the analytic model, the way the
paper's §4.2 sweeps channel depths and producer counts — then validate the
matching generated kernels in interpret mode.

Run:  PYTHONPATH=src python examples/microbench_sweep.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ARRIA_CX, TPU_V5E, Pipe, Workload,
                        estimate_baseline, estimate_feedforward)


def sweep(hw, name):
    print(f"== {name}: FF speedup over baseline (depth x streams) ==")
    for regular in (True, False):
        for div in (0.0, 0.8):
            w = Workload(n_words=1 << 20, word_bytes=128,
                         flops_per_word=256, regular=regular,
                         divergence=div, dlcd_cycles=8,
                         false_mlcd_ii=120.0)
            base = estimate_baseline(w, hw)
            cells = []
            for depth in (2, 4, 8, 16):
                for streams in (1, 2, 4):
                    ff = estimate_feedforward(
                        w, hw, Pipe(tile=(8, 128), depth=depth,
                                    streams=streams))
                    cells.append((depth, streams, base.total_s / ff.total_s))
            best = max(cells, key=lambda c: c[2])
            row = " ".join(f"d{d}s{s}={x:5.2f}x" for d, s, x in cells[:6])
            print(f" {'reg' if regular else 'irr'} div={div:.1f}: {row} ...")
            print(f"   best: depth={best[0]} streams={best[1]} "
                  f"-> {best[2]:.2f}x")


def kernel_check():
    print("== generated kernels vs oracles (interpret) ==")
    import repro
    k = jax.random.key(0)
    q = 0.5 * jax.random.normal(k, (2, 128, 32))
    kk = 0.5 * jax.random.normal(jax.random.fold_in(k, 1), (2, 128, 32))
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, 128, 64))
    lw = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 3), (2, 128, 32)))
    with repro.policy(mode="ref"):
        ref = repro.ops.chunk_scan(q, kk, v, lw)
    for mode in ("xla", "ff"):
        with repro.policy(mode=mode):
            out = repro.ops.chunk_scan(q, kk, v, lw)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(f" chunk_scan[{mode}] max|err| = {err:.2e}")


if __name__ == "__main__":
    sweep(ARRIA_CX, "paper board (Arria CX)")
    sweep(TPU_V5E, "target (TPU v5e)")
    kernel_check()
