"""qwen1.5-0.5b [dense] — MHA (kv=16H=16), QKV bias.
[hf:Qwen/Qwen1.5-0.5B; hf]  24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen1_5_0p5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    compute_dtype="float32",
)
