"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode). Every ff_* kernel must match its ref for all pipe depths,
stream counts, and the baseline (depth=1) mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ff_attention import attention, attention_ref
from repro.kernels.ff_chunk_scan import chunk_scan
from repro.kernels.ff_decode_attention import decode_attention
from repro.kernels.ff_gather import gather, gather_ref
from repro.kernels.ff_matmul import matmul, matmul_ref

KEY = jax.random.key(42)


def k(i):
    return jax.random.fold_in(KEY, i)


# ---------------------------------------------------------------------------
# ff_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 128),
                                   (200, 120, 72), (64, 640, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode,depth,streams", [
    ("ff", 2, 1), ("ff", 3, 2), ("ff", 4, 4), ("baseline", 1, 1)])
def test_matmul(shape, dtype, mode, depth, streams):
    m, kk, n = shape
    a = jax.random.normal(k(0), (m, kk), jnp.float32).astype(dtype)
    b = jax.random.normal(k(1), (kk, n), jnp.float32).astype(dtype)
    ref = matmul_ref(a, b)
    out = matmul(a, b, mode=mode, depth=depth, streams=streams)
    # f32 tolerance covers k-dim accumulation-order differences vs jnp.dot
    tol = (1e-5, 5e-4) if dtype == jnp.float32 else (2e-2, 2e-1)
    np.testing.assert_allclose(np.float32(out), np.float32(ref),
                               rtol=tol[0], atol=tol[1])


# ---------------------------------------------------------------------------
# ff_attention (prefill)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,kvg,s,d", [(4, 2, 256, 128), (2, 1, 200, 64),
                                        (6, 3, 128, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("mode,depth", [("ff", 2), ("ff", 4), ("baseline", 1)])
def test_attention(bh, kvg, s, d, causal, mode, depth):
    if not causal and s % 128 != 0:
        pytest.skip("non-causal requires block-multiple skv")
    q = jax.random.normal(k(2), (bh, s, d), jnp.float32)
    kk = jax.random.normal(k(3), (bh // kvg, s, d), jnp.float32)
    vv = jax.random.normal(k(4), (bh // kvg, s, d), jnp.float32)
    ref = attention_ref(q, kk, vv, kv_groups=kvg, causal=causal)
    out = attention(q, kk, vv, kv_groups=kvg, causal=causal, mode=mode,
                    depth=depth, block_q=64)
    np.testing.assert_allclose(np.float32(out), np.float32(ref),
                               rtol=2e-5, atol=2e-5)


def test_attention_bf16():
    q = jax.random.normal(k(5), (2, 128, 128), jnp.bfloat16)
    kv = jax.random.normal(k(6), (2, 128, 128), jnp.bfloat16)
    ref = attention_ref(q, kv, kv, causal=True)
    out = attention(q, kv, kv, causal=True, mode="ff")
    np.testing.assert_allclose(np.float32(out), np.float32(ref),
                               rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# ff_decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kvh,s,d", [(2, 8, 2, 256, 128), (3, 4, 4, 384, 64),
                                         (1, 16, 2, 128, 128)])
@pytest.mark.parametrize("mode,depth,streams", [("ff", 2, 1), ("ff", 3, 2),
                                                ("baseline", 1, 1)])
def test_decode_attention(b, h, kvh, s, d, mode, depth, streams):
    q = jax.random.normal(k(7), (b, h, d), jnp.float32)
    kk = jax.random.normal(k(8), (b, kvh, s, d), jnp.float32)
    vv = jax.random.normal(k(9), (b, kvh, s, d), jnp.float32)
    lens = jax.random.randint(k(10), (b,), 1, s + 1)
    ref = decode_attention(q, kk, vv, lens, mode="ref")
    out = decode_attention(q, kk, vv, lens, mode=mode, depth=depth,
                           streams=streams)
    np.testing.assert_allclose(np.float32(out), np.float32(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ff_chunk_scan (Mamba2 inclusive / RWKV6 exclusive+bonus)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,s,n,p", [(2, 128, 32, 64), (3, 200, 64, 64),
                                      (1, 64, 16, 32)])
@pytest.mark.parametrize("inclusive", [True, False])
@pytest.mark.parametrize("mode", ["xla", "xla_tiled", "ff", "baseline"])
def test_chunk_scan(bh, s, n, p, inclusive, mode):
    q = 0.5 * jax.random.normal(k(11), (bh, s, n), jnp.float32)
    kk = 0.5 * jax.random.normal(k(12), (bh, s, n), jnp.float32)
    vv = jax.random.normal(k(13), (bh, s, p), jnp.float32)
    lw = -0.5 * jnp.exp(jax.random.normal(k(14), (bh, s, n)))
    u = None if inclusive else 0.3 * jax.random.normal(k(15), (bh, n))
    ref = chunk_scan(q, kk, vv, lw, u, inclusive=inclusive, mode="ref")
    out = chunk_scan(q, kk, vv, lw, u, inclusive=inclusive, mode=mode,
                     depth=2, streams=1)
    scale = np.max(np.abs(np.float32(ref))) + 1e-6
    assert np.max(np.abs(np.float32(out) - np.float32(ref))) / scale < 3e-5


def test_chunk_scan_strong_decay_stability():
    """Strong decay (w ~ 1e-30 per chunk) must not overflow/NaN — the
    decay-to-boundary factorization keeps all exponents <= 0."""
    bh, s, n, p = 1, 128, 16, 16
    q = jnp.ones((bh, s, n))
    kk = jnp.ones((bh, s, n))
    vv = jnp.ones((bh, s, p))
    lw = jnp.full((bh, s, n), -3.0)     # total chunk decay e^-192
    for mode in ("xla", "ff"):
        out = chunk_scan(q, kk, vv, lw, inclusive=True, mode=mode)
        assert np.isfinite(np.float32(out)).all(), mode
    ref = chunk_scan(q, kk, vv, lw, inclusive=True, mode="ref")
    np.testing.assert_allclose(
        np.float32(chunk_scan(q, kk, vv, lw, inclusive=True, mode="ff")),
        np.float32(ref), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# ff_gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,cols,n", [(64, 128, 40), (100, 256, 64),
                                         (16, 128, 7)])
@pytest.mark.parametrize("mode,depth", [("ff", 4), ("ff", 2), ("baseline", 1)])
def test_gather(rows, cols, n, mode, depth):
    tab = jax.random.normal(k(16), (rows, cols), jnp.float32)
    idx = jax.random.randint(k(17), (n,), 0, rows)
    ref = gather_ref(tab, idx)
    out = gather(tab, idx, mode=mode, depth=depth)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# cost models sanity
# ---------------------------------------------------------------------------

def test_cost_models_positive():
    from repro.kernels.ff_attention import attention_cost
    from repro.kernels.ff_chunk_scan import chunk_scan_cost
    from repro.kernels.ff_decode_attention import decode_attention_cost
    from repro.kernels.ff_gather import gather_cost
    from repro.kernels.ff_matmul import matmul_cost
    for c in (matmul_cost(512, 512, 512), attention_cost(8, 1024, 128),
              decode_attention_cost(8, 16, 4, 2048, 128),
              chunk_scan_cost(8, 1024, 64, 64), gather_cost(1024, 512)):
        assert c.hbm_bytes > 0 and c.vmem_bytes > 0
