"""Bandwidth-utilization accounting: modeled bytes ÷ measured seconds.

The paper's claim is about achieved memory bandwidth — pipes win because
the access kernel streams at a rate the fused baseline cannot sustain —
and the claim is only falsifiable if achieved GB/s and its fraction of
the roofline are *measured*, per kernel and per graph edge (Memory
Controller Wall / MKPipe, PAPERS.md). This module makes the join:

* modeled bytes come from the same :class:`~repro.core.pipeline_model`
  objects the planner used (``Workload`` for a single kernel,
  ``GraphEstimate.per_stage`` for graphs — each stage's estimate encodes
  ``bytes = achieved_bw * total_s`` exactly, so post-fusion traffic with
  fused-edge savings already applied is recoverable without recompiling);
* measured seconds come from the caller (``autotune.measure`` wall time);
* utilization is ``achieved / hw.hbm_bw``, reported clamped to 1.0 with
  the raw ratio kept — interpret-mode CPU runs land far below 1, a real
  accelerator should not exceed it, and a ratio > 1 flags a broken byte
  model rather than crashing the report.

Graph wall time is one number per compiled graph; stages get it
attributed proportionally to their modeled ``total_s`` share, and each
edge combines its producer+consumer stages (a stage shared by several
edges — a multi-consumer producer — is split evenly across them so edge
rows stay summable). ``hbm_bytes_saved`` per edge
is carried through so fused edges show the traffic they *removed* next
to the bandwidth they achieved.
"""

from __future__ import annotations

from typing import Dict, List

_EPS = 1e-30


def _utilization(achieved: float, roofline: float) -> Dict[str, float]:
    raw = achieved / max(roofline, _EPS)
    return {
        "achieved_gb_s": achieved / 1e9,
        "roofline_gb_s": roofline / 1e9,
        "utilization": min(raw, 1.0),
        "utilization_raw": raw,
    }


def kernel_utilization(workload, hw, measured_s: float) -> Dict[str, float]:
    """Achieved GB/s and roofline fraction for one kernel invocation.

    ``workload`` is the :class:`~repro.core.pipeline_model.Workload` the
    kernel planned with, ``hw`` the :class:`HardwareModel` roofline, and
    ``measured_s`` the measured wall seconds for one call.
    """
    bytes_moved = workload.n_words * (
        workload.word_bytes + workload.store_bytes_per_word)
    out = {"hbm_bytes": bytes_moved, "measured_s": measured_s}
    out.update(_utilization(bytes_moved / max(measured_s, _EPS), hw.hbm_bw))
    return out


def graph_utilization(estimate, hw, measured_s: float) -> Dict[str, object]:
    """Per-stage and per-edge achieved bandwidth for one compiled graph.

    ``estimate`` is the compiled graph's
    :class:`~repro.core.pipeline_model.GraphEstimate` (``compiled.plan
    .estimate``); ``measured_s`` is the measured wall seconds for one
    end-to-end run. Stage bytes are recovered from each stage's modeled
    ``achieved_bw * total_s`` (post-fusion traffic); the measured wall is
    attributed to stages by modeled-time share.
    """
    stage_bytes: Dict[str, float] = {}
    stage_model_s: Dict[str, float] = {}
    for name, est in estimate.per_stage:
        stage_bytes[name] = est.achieved_bw * est.total_s
        stage_model_s[name] = est.total_s
    model_total = sum(stage_model_s.values()) or _EPS

    stages: Dict[str, Dict[str, float]] = {}
    for name in stage_bytes:
        attributed_s = measured_s * stage_model_s[name] / model_total
        d = {"hbm_bytes": stage_bytes[name], "attributed_s": attributed_s}
        d.update(_utilization(
            stage_bytes[name] / max(attributed_s, _EPS), hw.hbm_bw))
        stages[name] = d

    # A stage may sit on several edges (multi-consumer producers like the
    # decode layer's oproj feeding both gateup and the down residual, or a
    # consumer with two planned inputs). Splitting each stage's bytes/wall
    # evenly across its edge memberships keeps the edge rows summable: the
    # shared stage is counted once across the graph, not once per edge.
    membership: Dict[str, int] = {}
    edge_names: List[List[str]] = []
    for e in estimate.edges:
        producer, _, consumer = e.edge.partition("->")
        names = [n for n in (producer, consumer) if n in stage_bytes]
        edge_names.append(names)
        for n in names:
            membership[n] = membership.get(n, 0) + 1

    edges: List[Dict[str, object]] = []
    for e, names in zip(estimate.edges, edge_names):
        e_bytes = sum(stage_bytes[n] / membership[n] for n in names)
        e_attr = sum(stages[n]["attributed_s"] / membership[n] for n in names)
        d: Dict[str, object] = {
            "edge": e.edge,
            "mode": e.mode,
            "hbm_bytes": e_bytes,
            "hbm_bytes_saved": e.hbm_bytes_saved,
            "attributed_s": e_attr,
            "rationale": e.rationale,
        }
        d.update(_utilization(e_bytes / max(e_attr, _EPS), hw.hbm_bw))
        edges.append(d)

    total_bytes = sum(stage_bytes.values())
    graph = {"hbm_bytes": total_bytes, "measured_s": measured_s,
             "modeled_s": estimate.total_s,
             "hbm_bytes_saved": estimate.hbm_bytes_saved}
    graph.update(_utilization(
        total_bytes / max(measured_s, _EPS), hw.hbm_bw))
    return {"graph": graph, "stages": stages, "edges": edges}
