"""Regenerate the §Dry-run and §Roofline tables inside EXPERIMENTS.md from
the dry-run artifacts. Idempotent: content between the marker comments is
replaced."""

import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.roofline import analyze_cell, load_all, markdown_table

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "experiments", "dryrun")


def dryrun_table(results):
    rows = ["| cell | mesh | status | lower (s) | compile (s) | HBM GiB/dev "
            "| params |",
            "|---|---|---|---|---|---|---|"]
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if "__it" in r["cell"] or "__" + "tag" in r["cell"]:
            continue
        if r.get("skipped"):
            rows.append(f"| {r['arch']} × {r['shape']} | {r['mesh']} | "
                        f"SKIP ({r['reason'].split(':')[0]}) | | | | |")
            continue
        status = "OK" if r.get("ok") else f"FAIL: {r.get('error', '')[:40]}"
        t = r.get("timings", {})
        mem = r.get("memory", {}).get("peak_bytes_est", 0) / 2 ** 30
        rows.append(
            f"| {r['arch']} × {r['shape']} | {r['mesh']} | {status} "
            f"| {t.get('lower_s', 0):.1f} | {t.get('compile_s', 0):.1f} "
            f"| {mem:.2f} | {r.get('n_params', 0):,} |")
    return "\n".join(rows)


def inject(md, marker, content):
    pat = re.compile(rf"<!-- {marker} -->.*?(?=\n## |\Z)", re.S)
    repl = f"<!-- {marker} -->\n\n{content}\n"
    assert pat.search(md), marker
    return pat.sub(repl, md)


def main():
    results = [r for r in load_all(DRY)
               if "__it" not in r["cell"] and "__base" not in r["cell"]]
    base = [r for r in results if r["cell"].count("__") == 2]
    analyzed = [a for a in (analyze_cell(r) for r in base) if a]
    analyzed.sort(key=lambda a: (a["arch"], a["shape"], a["mesh"]))

    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        md = f.read()
    md = inject(md, "DRYRUN_TABLE", dryrun_table(base))
    md = inject(md, "ROOFLINE_TABLE", markdown_table(analyzed))
    with open(path, "w") as f:
        f.write(md)
    print(f"updated EXPERIMENTS.md with {len(base)} cells, "
          f"{len(analyzed)} roofline rows")


if __name__ == "__main__":
    main()
