"""Mixture-of-experts FFN (grok-1, deepseek-v2-lite).

Capacity-based top-k routing with scatter dispatch / gather combine:
tokens are placed into a ``[E, C, d]`` dispatch buffer (expert-sharded under
the "expert" rule — EP over the model axis), experts run as one batched
einsum, and results gather back weighted by router probs. Overflow beyond
capacity ``C = ceil(T/E * k * capacity_factor)`` is dropped (standard
token-dropping MoE).

Paper mapping: the dispatch/combine *is* the irregular-gather microbenchmark
at system scale — under EP sharding XLA materializes it as all-to-alls, which
the roofline's collective term picks up (deepseek/grok are the most
collective-bound cells in EXPERIMENTS.md).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.runtime.sharding import constrain, current


def moe_ffn_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    s = {
        "router": L.ParamSpec((d, e), ("embed", None), scale=0.02),
        "w1": L.ParamSpec((e, d, 2 * f), ("expert", "embed", "mlp")),
        "w2": L.ParamSpec((e, f, d), ("expert", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * cfg.moe_d_ff
        s["shared"] = L.mlp_specs(d, fs, "swiglu")
    return s


def _dispatch_indices(gates: jnp.ndarray, top_k: int, capacity: int):
    """gates: [T, E] router probs. Returns (expert_idx [T,k], probs [T,k],
    slot [T,k], keep [T,k]) with capacity-ranked slots per expert."""
    t, e = gates.shape
    probs, idx = jax.lax.top_k(gates, top_k)                    # [T,k]
    probs = probs / (jnp.sum(probs, axis=-1, keepdims=True) + 1e-9)
    count = jnp.zeros((e,), jnp.int32)
    slots = []
    for k in range(top_k):
        oh = jax.nn.one_hot(idx[:, k], e, dtype=jnp.int32)       # [T,E]
        rank = jnp.cumsum(oh, axis=0) - 1                        # [T,E]
        r = jnp.take_along_axis(rank, idx[:, k:k + 1], axis=1)[:, 0]
        slots.append(r + count[idx[:, k]])
        count = count + jnp.sum(oh, axis=0)
    slot = jnp.stack(slots, axis=1)                              # [T,k]
    keep = slot < capacity
    return idx, probs, slot, keep


def _batch_shards() -> int:
    """How many ways the token (batch) dim is sharded under current rules."""
    ctx = current()
    if ctx is None:
        return 1
    target = ctx.rules.get("batch")
    if target is None:
        return 1
    tgt = (target,) if isinstance(target, str) else target
    n = 1
    for a in tgt:
        n *= ctx.axis_size(a)
    return n


def _local_dispatch_apply(cfg: ArchConfig, p, x
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Hierarchical dispatch (§Perf, 'MoE local dispatch'): slot ranks and
    capacity are computed *per data shard*, and the dispatch buffer's
    capacity dim is laid out [E, shards, C_local] with the shard dim aligned
    to the token sharding — the scatter/gather becomes shard-local and the
    only cross-device movement is the expert-parallel all-to-all, instead of
    the global-buffer all-gathers of the naive path."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    shards = _batch_shards()
    if t % shards:
        shards = 1
    tl = t // shards
    xf = x.reshape(t, d)

    gates = jax.nn.softmax(
        (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)), axis=-1)
    probs_k, idx = jax.lax.top_k(gates, k)
    probs_k = probs_k / (jnp.sum(probs_k, axis=-1, keepdims=True) + 1e-9)

    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0)
    aux = e * jnp.sum(me * ce)

    cap_l = int(tl // e * k * cfg.capacity_factor) + 1
    cap_l = -(-cap_l // 8) * 8
    idx_s = idx.reshape(shards, tl, k)
    count = jnp.zeros((shards, e), jnp.int32)
    slots = []
    for kk in range(k):
        oh = jax.nn.one_hot(idx_s[:, :, kk], e, dtype=jnp.int32)  # [D,tl,E]
        rank = jnp.cumsum(oh, axis=1) - 1
        r = jnp.take_along_axis(rank, idx_s[:, :, kk:kk + 1], axis=2)[..., 0]
        base = jnp.take_along_axis(count, idx_s[:, :, kk], axis=1)
        slots.append(r + base)
        count = count + jnp.sum(oh, axis=1)
    slot = jnp.stack(slots, axis=2)                               # [D,tl,k]
    keep = slot < cap_l

    # vmapped shard-local scatter: the buffer is *born* sharded on its
    # leading (data) dim, so the partitioner never materializes a global
    # buffer (the naive path all-gathers the whole [E,C,d] buffer — the
    # 181 GiB/layer pathology in the baseline grok HLO)
    flat_local = idx_s * cap_l + slot                             # [D,tl,k]
    contrib = xf.reshape(shards, tl, 1, d) * keep[..., None].astype(x.dtype)
    contrib = jnp.broadcast_to(contrib, (shards, tl, k, d))
    buf_s = jnp.zeros((shards, e * cap_l, d), x.dtype)
    buf_s = constrain(buf_s, ("batch", None, "embed"))
    buf_s = jax.vmap(
        lambda bb, ix, cc: bb.at[ix.reshape(-1)].add(
            cc.reshape(-1, d), mode="drop"))(buf_s, flat_local, contrib)
    buf = buf_s.reshape(shards, e, cap_l, d).transpose(1, 0, 2, 3) \
        .reshape(e, shards * cap_l, d)
    buf = constrain(buf, ("expert", "exp_cap", "embed"))

    dt = x.dtype
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(dt))
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    y = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(dt))
    y = constrain(y, ("expert", "exp_cap", "embed"))

    y_s = y.reshape(e, shards, cap_l, d).transpose(1, 0, 2, 3) \
        .reshape(shards, e * cap_l, d)
    y_s = constrain(y_s, ("batch", None, "embed"))
    picked = jax.vmap(lambda yy, ix: yy[ix.reshape(-1)])(
        y_s, flat_local).reshape(t, k, d)
    w = (probs_k.reshape(t, k) *
         keep.reshape(t, k).astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("tkd,tk->td", picked, w).reshape(b, s, d)
    if cfg.n_shared_experts:
        out = out + L.mlp_apply(p["shared"], x, "swiglu")
    return out, aux.astype(jnp.float32)


def moe_ffn_apply(cfg: ArchConfig, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B,S,D] -> (out [B,S,D], aux load-balance loss)."""
    if cfg.moe_local_dispatch:
        return _local_dispatch_apply(cfg, p, x)
    b, s, d = x.shape
    e, k, f = cfg.n_experts, cfg.top_k, cfg.moe_d_ff
    t = b * s
    xf = x.reshape(t, d)
    capacity = int(t // e * k * cfg.capacity_factor) + 1
    # round capacity so the buffer's capacity dim stays mesh-divisible
    gran = 2048 if t >= (1 << 17) else 8
    capacity = -(-capacity // gran) * gran

    gates = jax.nn.softmax(
        (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)), axis=-1)
    idx, probs, slot, keep = _dispatch_indices(gates, k, capacity)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(gates, axis=0)                                  # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0)
    aux = e * jnp.sum(me * ce)

    # scatter tokens into the expert-sharded dispatch buffer
    flat_idx = (idx * capacity + slot)                            # [T,k]
    buf = jnp.zeros((e * capacity, d), x.dtype)
    contrib = xf[:, None, :] * keep[:, :, None].astype(x.dtype)   # [T,k,D]
    buf = buf.at[flat_idx.reshape(-1)].add(
        contrib.reshape(t * k, d), mode="drop")
    # "exp_cap" shards the capacity dim when experts themselves cannot be
    # sharded (grok: 8 experts vs 16-way model axis)
    buf = constrain(buf.reshape(e, capacity, d), ("expert", "exp_cap", "embed"))

    # batched expert FFN (swiglu)
    dt = x.dtype
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(dt))
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    y = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(dt))
    y = constrain(y, ("expert", "exp_cap", "embed"))

    # gather/combine
    flat_y = y.reshape(e * capacity, d)
    picked = flat_y[flat_idx.reshape(-1)].reshape(t, k, d)
    w = (probs * keep.astype(jnp.float32)).astype(x.dtype)        # [T,k]
    out = jnp.einsum("tkd,tk->td", picked, w).reshape(b, s, d)

    if cfg.n_shared_experts:
        out = out + L.mlp_apply(p["shared"], x, "swiglu")
    return out, aux.astype(jnp.float32)
