"""Kernel registry + planner auto-sizing tests: the five built-in kernels
are enumerable with sane cost/workload models, "auto" resolves to plans
inside the VMEM budget, and repeat call sites hit the plan cache."""

import math

import jax.numpy as jnp
import pytest

from repro.core import (
    TPU_V5E,
    Workload,
    plan_cache_clear,
    plan_cache_info,
    planned_pipe,
    resolve_auto,
    vmem_budget_ok,
)
from repro.kernels.registry import all_kernels, get_kernel, kernel_names

EXPECTED = {"ff_matmul", "ff_attention", "ff_decode_attention",
            "ff_chunk_scan", "ff_gather"}


def test_all_five_kernels_enumerable():
    assert set(kernel_names()) == EXPECTED
    for spec in all_kernels():
        assert callable(spec.op) and callable(spec.ref)
        assert callable(spec.cost) and callable(spec.workload)


def test_get_kernel_unknown_raises():
    with pytest.raises(KeyError, match="ff_nonexistent"):
        get_kernel("ff_nonexistent")


def test_cost_models_finite_positive():
    for spec in all_kernels():
        c = spec.cost(**spec.bench_kwargs)
        assert math.isfinite(c.flops) and c.flops >= 0, spec.name
        assert math.isfinite(c.hbm_bytes) and c.hbm_bytes > 0, spec.name
        assert c.vmem_bytes > 0, spec.name


def test_workload_builders():
    for spec in all_kernels():
        w, tile = spec.workload(**spec.bench_kwargs)
        assert isinstance(w, Workload), spec.name
        assert w.n_words > 0 and w.word_bytes > 0, spec.name
        assert w.regular == spec.regular, spec.name
        assert len(tile) >= 2 and all(t > 0 for t in tile), spec.name


def test_auto_plans_satisfy_vmem_budget():
    for spec in all_kernels():
        kw = dict(spec.bench_kwargs)
        dtype = kw.get("dtype", jnp.float32)
        w, tile = spec.workload(**kw)
        plan = planned_pipe(spec.name, w, tile, dtype, TPU_V5E)
        assert vmem_budget_ok([plan.pipe]), (spec.name, plan)
        assert plan.pipe.depth >= 1 and plan.pipe.streams >= 1
        assert plan.predicted_s > 0 and plan.predicted_bw > 0


def test_resolve_auto_passthrough_and_planning():
    spec = get_kernel("ff_matmul")
    w, tile = spec.workload(512, 512, 512)
    # explicit ints pass through without consulting the planner
    assert resolve_auto("ff_matmul", 3, 2, workload=w, tile=tile,
                        dtype=jnp.float32) == (3, 2)
    d, s = resolve_auto("ff_matmul", "auto", "auto", workload=w, tile=tile,
                        dtype=jnp.float32)
    assert d >= 2 and s >= 1
    # mixed: only the "auto" side comes from the plan
    d2, s2 = resolve_auto("ff_matmul", 5, "auto", workload=w, tile=tile,
                          dtype=jnp.float32)
    assert d2 == 5 and s2 == s


def test_plan_cache_hits_on_repeat_call_sites():
    plan_cache_clear()
    spec = get_kernel("ff_attention")
    w, tile = spec.workload(8, 1024, 128)
    p1 = planned_pipe(spec.name, w, tile, jnp.bfloat16)
    before = plan_cache_info()
    p2 = planned_pipe(spec.name, w, tile, jnp.bfloat16)
    after = plan_cache_info()
    assert p1 is p2
    assert after.hits == before.hits + 1
    assert after.misses == before.misses
    # a different shape is a different call site -> miss
    w3, tile3 = spec.workload(8, 2048, 128)
    planned_pipe(spec.name, w3, tile3, jnp.bfloat16)
    assert plan_cache_info().misses == after.misses + 1
