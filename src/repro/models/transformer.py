"""Dense decoder-only transformer (GQA + RoPE), the backbone for the
dense/moe/vlm families.

Layer math is injectable (``mixer_specs`` / ``mixer_apply`` for attention or
MLA, ``ffn_specs`` / ``ffn_apply`` for dense or MoE FFNs), so MoE and MLA
variants reuse the same stacked-scan machinery. Layers are stacked along a
leading L dim and iterated with ``lax.scan`` (HLO-compact: one compiled
body), with optional unrolled mode (``cfg.scan_layers=False``) used by the
roofline's per-layer cost extraction.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.runtime.sharding import constrain


# ---------------------------------------------------------------------------
# GQA attention mixer (the default)
# ---------------------------------------------------------------------------


def attn_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = {
        "wq": L.ParamSpec((d, h, hd), ("embed", "heads", None)),
        "wk": L.ParamSpec((d, kvh, hd), ("embed", "kv_heads", None)),
        "wv": L.ParamSpec((d, kvh, hd), ("embed", "kv_heads", None)),
        "wo": L.ParamSpec((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = L.ParamSpec((h, hd), ("heads", None), init="zeros")
        s["bk"] = L.ParamSpec((kvh, hd), ("kv_heads", None), init="zeros")
        s["bv"] = L.ParamSpec((kvh, hd), ("kv_heads", None), init="zeros")
    return s


def _project_qkv(cfg: ArchConfig, p, x):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def attn_apply(cfg: ArchConfig, p, x, *, positions, cache=None,
               lengths=None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x: [B,S,D]. cache (decode): {"k","v": [B,Smax,KVH,hd]}; returns
    (out [B,S,D], new_cache)."""
    q, k, v = _project_qkv(cfg, p, x)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    if cache is None:
        q = constrain(q, ("batch", "seq", "heads", None))
        out = L.attention_op(q, k, v, causal=True, impl=cfg.attn_impl)
        # cache layout: seq dim re-sharded per the "kv" rule (decode shards
        # the cache sequence over the model axis)
        new_cache = {"k": constrain(k, ("batch", "kv", "kv_heads", None)),
                     "v": constrain(v, ("batch", "kv", "kv_heads", None))}
    elif "kv_pool" in cache:
        # paged decode: append this token's K/V through the block table,
        # then attend via the gather->attention stream graph (sentinel
        # table entries drop the write / mask the read, so inactive
        # continuous-batching slots are inert)
        from repro.runtime.paged_kv import scatter_token
        pool = scatter_token(cache["kv_pool"], cache["block_tables"],
                             lengths, k[:, 0], v[:, 0],
                             n_blocks=cache["kv_pool"].shape[0])
        out = L.paged_decode_attention_op(
            q[:, 0], pool, cache["block_tables"], lengths + 1,
            impl=cfg.attn_impl)[:, None]
        new_cache = {"kv_pool": pool,
                     "block_tables": cache["block_tables"]}
    else:
        b = x.shape[0]
        ck = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
            c, u, i, axis=0))(cache["k"], k, lengths)
        cv = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
            c, u, i, axis=0))(cache["v"], v, lengths)
        out = L.decode_attention_op(q[:, 0], ck, cv, lengths + 1,
                                    impl=cfg.attn_impl,
                                    block_kv=cfg.decode_block_kv)[:, None]
        new_cache = {"k": ck, "v": cv}
    dt = x.dtype
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), new_cache


def attn_cache_spec(cfg: ArchConfig, batch: int, s_max: int):
    shape = (batch, s_max, cfg.n_kv_heads, cfg.hd)
    spec = {"k": jax.ShapeDtypeStruct(shape, cfg.cdtype),
            "v": jax.ShapeDtypeStruct(shape, cfg.cdtype)}
    axes = {"k": ("batch", "kv", "kv_heads", None),
            "v": ("batch", "kv", "kv_heads", None)}
    return spec, axes


# ---------------------------------------------------------------------------
# Dense FFN (default ffn hook)
# ---------------------------------------------------------------------------


def ffn_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return L.mlp_specs(cfg.d_model, cfg.d_ff, cfg.act)


def ffn_apply(cfg: ArchConfig, p, x):
    y = L.mlp_apply(p, x, cfg.act)
    return y, jnp.zeros((), jnp.float32)     # (out, aux_loss)


# ---------------------------------------------------------------------------
# Decoder stack
# ---------------------------------------------------------------------------


class DecoderStack:
    """Stacked pre-norm decoder with injectable mixer/ffn."""

    def __init__(self, cfg: ArchConfig,
                 mixer_specs=attn_specs, mixer_apply=attn_apply,
                 mixer_cache_spec=attn_cache_spec,
                 ffn_specs=ffn_specs, ffn_apply=ffn_apply):
        self.cfg = cfg
        self._mixer_specs = mixer_specs
        self._mixer_apply = mixer_apply
        self._mixer_cache_spec = mixer_cache_spec
        self._ffn_specs = ffn_specs
        self._ffn_apply = ffn_apply

    # -- specs ---------------------------------------------------------------

    def layer_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "norm1": L.norm_specs(cfg.norm, cfg.d_model),
            "mixer": self._mixer_specs(cfg),
            "norm2": L.norm_specs(cfg.norm, cfg.d_model),
            "ffn": self._ffn_specs(cfg),
        }

    def specs(self) -> Dict[str, Any]:
        """Params are always stacked [L, ...]; ``cfg.scan_layers`` only
        selects scan vs. indexed-unroll iteration (same param structure, so
        cost-extraction variants restore nothing)."""
        cfg = self.cfg
        one = self.layer_specs()
        stacked = jax.tree.map(
            lambda s: L.ParamSpec((cfg.n_layers, *s.shape),
                                  ("layers", *s.axes), s.dtype, s.init,
                                  s.scale),
            one, is_leaf=L.is_spec)
        return {"layers": stacked}

    def cache_spec(self, batch: int, s_max: int):
        cfg = self.cfg
        one, one_axes = self._mixer_cache_spec(cfg, batch, s_max)
        spec = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_layers, *s.shape), s.dtype),
            one)
        axes = jax.tree.map(lambda a: ("layers", *a), one_axes,
                            is_leaf=lambda x: isinstance(x, tuple))
        return spec, axes

    # -- forward ---------------------------------------------------------------

    def _layer(self, p, x, positions, cache, lengths, want_cache: bool):
        cfg = self.cfg
        if (cfg.layer_graph and cache is not None and "kv_pool" not in cache
                and x.shape[1] == 1 and cfg.norm == "rmsnorm"
                and cfg.act == "swiglu"
                and self._mixer_apply is attn_apply
                and self._ffn_apply is ffn_apply):
            return self._decode_layer_graph(p, x, positions, cache, lengths)
        # NOTE (§Perf it4a, refuted): inserting explicit Megatron-SP
        # all-gather / reduce-scatter constraints around the norms tripled
        # compiled FLOPs — XLA SPMD fell back to replicate-and-repartition
        # ("involuntary full remat"). The single residual-boundary constraint
        # below lets the partitioner place the boundary collectives itself.
        h = L.norm_apply(cfg.norm, x, p["norm1"])
        attn_out, new_cache = self._mixer_apply(
            cfg, p["mixer"], h, positions=positions, cache=cache,
            lengths=lengths)
        # named so remat="collectives" can save the post-all-reduce tensors
        # (backward then re-runs only device-local math, not the TP psums)
        attn_out = checkpoint_name(attn_out, "attn_out")
        x = x + attn_out
        h = L.norm_apply(cfg.norm, x, p["norm2"])
        ffn_out, aux = self._ffn_apply(cfg, p["ffn"], h)
        ffn_out = checkpoint_name(ffn_out, "ffn_out")
        x = x + ffn_out
        # residual saves use the SP axis (None by default; "model" enables
        # Megatron sequence parallelism for layer-boundary activations)
        x = constrain(x, ("batch", "seq_sp", "embed"))
        if cfg.bf16_grads:
            x = L.bf16_grad_cast(x)   # bwd: boundary cotangent in bf16
        if not want_cache and cache is None:
            new_cache = None    # train mode: never stack per-layer caches
        return x, new_cache, aux

    def _decode_layer_graph(self, p, x, positions, cache, lengths):
        """One dense-cache decode step through the whole-layer
        ``decode_layer`` StreamGraph (ROADMAP item 2): q-projection +
        RoPE + attention + out-projection + SwiGLU MLP as one planned
        multi-kernel program, residual adds and RMSNorms folded into the
        consumer bodies. The K/V projection and cache update stay outside
        the graph — the cache write must materialize in HBM regardless."""
        cfg = self.cfg
        dt = x.dtype
        mp = p["mixer"]
        h1 = L.norm_apply(cfg.norm, x, p["norm1"])
        k = jnp.einsum("bsd,dhk->bshk", h1, mp["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", h1, mp["wv"].astype(dt))
        if cfg.qkv_bias:
            k = k + mp["bk"].astype(dt)
            v = v + mp["bv"].astype(dt)
        k = L.rope(k, positions, cfg.rope_theta)
        ck = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
            c, u, i, axis=0))(cache["k"], k, lengths)
        cv = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
            c, u, i, axis=0))(cache["v"], v, lengths)
        d, h_q, hd = cfg.d_model, cfg.n_heads, cfg.hd
        fp = p["ffn"]
        wi = fp["wi"].astype(dt)
        f = wi.shape[1] // 2
        out = L.decode_layer(
            x[:, 0], p["norm1"]["w"],
            mp["wq"].astype(dt).reshape(d, h_q * hd),
            mp["bq"].astype(dt).reshape(h_q * hd) if cfg.qkv_bias else None,
            positions[:, -1],
            ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3),
            lengths + 1,
            mp["wo"].astype(dt).reshape(h_q * hd, d), p["norm2"]["w"],
            wi[:, :f], wi[:, f:], fp["wo"].astype(dt),
            rope_theta=cfg.rope_theta, block_kv=cfg.decode_block_kv)
        return out[:, None], {"k": ck, "v": cv}, jnp.zeros((), jnp.float32)

    def _remat_layer(self):
        cfg = self.cfg
        fn = self._layer
        if cfg.remat == "none":
            return fn
        if cfg.remat == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots
        elif cfg.remat == "collectives":
            policy = jax.checkpoint_policies.save_only_these_names(
                "attn_out", "ffn_out")
        else:
            policy = jax.checkpoint_policies.nothing_saveable
        return jax.checkpoint(fn, policy=policy, static_argnums=(5,))

    def __call__(self, params, x, *, positions, caches=None, lengths=None,
                 want_cache: bool = False):
        """x: [B,S,D]. caches: stacked (scan) or list (unrolled) or None.
        Returns (x, new_caches, aux_loss_sum)."""
        cfg = self.cfg
        layer = self._remat_layer()
        if cfg.scan_layers:
            if caches is None:
                def body_nocache(carry, p):
                    xx, aux = carry
                    xx, new_cache, a = layer(p, xx, positions, None, lengths,
                                             want_cache)
                    return (xx, aux + a), new_cache
                (x, aux), new_caches = jax.lax.scan(
                    body_nocache, (x, jnp.zeros((), jnp.float32)),
                    params["layers"])
                return x, new_caches, aux

            def body(carry, xs):
                xx, aux = carry
                p, cache = xs
                xx, new_cache, a = layer(p, xx, positions, cache, lengths,
                                         want_cache)
                return (xx, aux + a), new_cache
            (x, aux), new_caches = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)),
                (params["layers"], caches))
            return x, new_caches, aux
        # unrolled: index the stacked params (same structure as scan mode)
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            cache = (jax.tree.map(lambda a: a[i], caches)
                     if caches is not None else None)
            x, nc, a = layer(p, x, positions, cache, lengths, want_cache)
            new_caches.append(nc)
            aux = aux + a
        if new_caches and new_caches[0] is not None:
            new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        else:
            new_caches = None
        return x, new_caches, aux
