"""repro.configs — one module per assigned architecture (CONFIG: full dims
from the assignment sheet; SMOKE: reduced same-family config for CPU tests),
plus the shape set in configs.base."""

from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ArchConfig,
    ShapeConfig,
    get_config,
    shape_applicable,
    smoke_config,
)

__all__ = [
    "ARCH_IDS", "SHAPES", "ArchConfig", "ShapeConfig", "get_config",
    "shape_applicable", "smoke_config",
]
