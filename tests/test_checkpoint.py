"""Checkpointer: atomic writes, integrity hashes, GC, restore-into-structure."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save, save_async


def tree(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "b": jnp.zeros((16,))},
        "opt": {"m": jnp.ones((8, 16)), "step": jnp.asarray(7, jnp.int32)},
        "data_step": np.asarray(123, np.int64),
    }


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    save(str(tmp_path), 10, t)
    assert latest_step(str(tmp_path)) == 10
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(
        np.shape(x), np.asarray(x).dtype), t)
    got, step, _ = restore(str(tmp_path), like)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    t = tree()
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, t, keep_last=2)
    assert latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000004", "step_00000005"]


def test_corruption_detected(tmp_path):
    t = tree()
    path = save(str(tmp_path), 1, t)
    # corrupt one array, keep manifest
    data = dict(np.load(os.path.join(path, "arrays.npz")))
    key = next(iter(data))
    data[key] = data[key] + 1.0
    np.savez(os.path.join(path, "arrays.npz"), **data)
    with pytest.raises(IOError, match="checksum"):
        restore(str(tmp_path), t)


def test_partial_write_ignored(tmp_path):
    """A crashed mid-write tmp dir must not be visible as a checkpoint."""
    t = tree()
    save(str(tmp_path), 1, t)
    os.makedirs(os.path.join(tmp_path, "step_00000002.tmp-9999"))
    assert latest_step(str(tmp_path)) == 1
    got, step, _ = restore(str(tmp_path), t)
    assert step == 1


def test_async_save(tmp_path):
    t = tree()
    th = save_async(str(tmp_path), 3, t)
    th.join(10)
    assert latest_step(str(tmp_path)) == 3


def test_restore_missing_leaf_fails(tmp_path):
    t = tree()
    save(str(tmp_path), 1, t)
    t2 = dict(t)
    t2["extra"] = jnp.zeros((3,))
    with pytest.raises(KeyError):
        restore(str(tmp_path), t2)
